"""Strategy-layer benchmarks: contextual entry routing vs the fixed
cascade, and online budget governance under traffic drift.

Three claims, each doubling as a regression check (rows/derived/secs
contract shared with bench_serving):

  * ``bench_contextual_routing`` — on >= 2 synthetic marketplace tasks,
    a contextual entry router (trained on observable query context
    correlated with the latent difficulty) reduces cost vs the fixed
    learned cascade at equal-or-better accuracy: hard queries skip the
    cheap tiers that were dead weight for them.
  * ``bench_budget_governor`` — on a drifting Poisson trace whose query
    mix hardens over time (and is harder in aggregate than the training
    distribution), the online governor keeps the realized $/query
    within +/-10% of the target spend rate, while the fixed cascade
    drifts far over it.
  * ``bench_window_assignment`` — on a bursty Poisson trace over the
    fee-bearing marketplace, the budgeted window solver (one shared
    window meta-model with the greedy baseline, for fairness) matches
    or beats greedy contextual routing's accuracy at lower realized
    cost: the per-window budgets pace the greedy rule's own build-split
    spend rate — which greedy, having no spend feedback, drifts over on
    the bursty mix — and every window's committed (predicted) cost
    respects its budget.

Runnable standalone for the CI bench trajectory:
  PYTHONPATH=src python -m benchmarks.bench_strategy --smoke \\
      --json-out BENCH_strategy.json
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cascade import execute_cascade, replay_tiers
from repro.core.cost import TABLE1
from repro.core.router import RouterConfig, learn_cascade
from repro.core.simulate import MarketData, simulate_market, simulate_scores
from repro.serving.ingress import poisson_arrivals
from repro.serving.strategy import (BudgetGovernor, ContextualRouter,
                                    accept_labels, train_entry_router)

#: the bench marketplace: Table-1 APIs with per-request fees in the mix
#: (J1 tiers) — entry routing pays off when probing a cheap tier costs
#: real money; a marketplace of near-free probes has nothing to skip
FEE_MARKET = ("J1-L", "J1-G", "Cohere", "GPT-3", "GPT-4")


def _context_features(data: MarketData, scores: np.ndarray, seed: int,
                      noise: float = 1.0, d: int = 24) -> np.ndarray:
    """Observable per-query context: a random-Fourier lift of *noisy*
    views of each API's reliability (logit of g(q, a_k) + noise) plus
    the latent difficulty — the offline stand-in for what a deployed
    meta-model reads off the query embedding (Šakota et al.:
    query-side success prediction), informative but far from exact."""
    rng = np.random.default_rng(seed)
    s = np.clip(np.asarray(scores, np.float64), 1e-4, 1.0 - 1e-4)
    z = np.log(s / (1.0 - s)) + noise * rng.normal(size=s.shape)
    z = np.concatenate([z, np.asarray(data.difficulty)[:, None]], axis=1)
    w = rng.normal(size=(z.shape[1], d)) / np.sqrt(z.shape[1])
    b = rng.uniform(0.0, 2.0 * np.pi, size=d)
    return (np.sqrt(2.0 / d) * np.cos(z @ w + b)).astype(np.float32)


def _take(data: MarketData, idx: np.ndarray) -> MarketData:
    return MarketData(data.names, data.correct[idx], data.cost[idx],
                      data.n_in[idx], data.n_out[idx], data.difficulty[idx])


def _replay_cascade(data: MarketData, scores: np.ndarray, cas, thresholds,
                    idx: np.ndarray, entry=None) -> dict:
    """Run the learned cascade over rows ``idx`` of offline data via the
    replay backend; answers are correctness bits, costs are recorded."""
    s = np.asarray(scores)

    def scorer(rows, _ans, j):
        return s[rows, cas.apis[j]]

    return execute_cascade(replay_tiers(data, cas.apis), thresholds,
                           scorer, np.asarray(idx),
                           batch_size=max(1, len(idx)), entry=entry)


#: candidate entry bars the train split selects among — the mis-skip
#: penalty (paying a pricier tier for a query the cheap tier would have
#: answered) is several times the right-skip saving, so profitable bars
#: are conservative: skip only on confident rejection predictions
ENTRY_BARS = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.4)


def bench_contextual_routing(tasks=("HEADLINES", "OVERRULING"),
                             n: int = 4000, budget_frac: float = 0.35,
                             router_steps: int = 500):
    """Contextual entry routing vs the fixed cascade, offline replay.

    Per task: learn (L, tau) on a training half of a fee-bearing
    marketplace, train the entry router on the same artifacts (accept
    labels vs the learned thresholds, on noisy reliability-context
    features), select the entry bar on the *train* split (max cost
    saving subject to no accuracy loss), then serve the held-out half
    both ways. The router must cut cost at equal-or-better accuracy —
    queries it correctly predicts the cheap tiers would fail enter
    higher and skip those tiers' charges entirely.
    """
    t0 = time.time()
    market = {k: TABLE1[k] for k in FEE_MARKET}
    rows = []
    ok = True
    for ti, task in enumerate(tasks):
        seed = 100 + 17 * ti
        data = simulate_market(task, n=n, seed=seed, apis=market)
        scores = np.asarray(simulate_scores(data, seed=seed + 1))
        feats = _context_features(data, scores, seed + 2)
        rng = np.random.default_rng(seed + 3)
        perm = rng.permutation(n)
        tr, te = perm[:n // 2], perm[n // 2:]
        d_tr = _take(data, tr)

        budget = float(np.asarray(data.cost).mean(0).max()) * budget_frac
        cas, _ = learn_cascade(d_tr, scores[tr], budget,
                               RouterConfig(top_lists=15, sample=384,
                                            seed=seed))
        labels = accept_labels(scores[tr], np.asarray(d_tr.correct),
                               cas.apis, cas.thresholds)
        params = train_entry_router(feats[tr], labels, steps=router_steps,
                                    seed=seed)
        router = ContextualRouter(params, len(cas.apis))

        # entry-bar selection on the train split: the saving-vs-mistake
        # asymmetry makes the right bar task-dependent
        res_tr = _replay_cascade(data, scores, cas, cas.thresholds, tr)
        acc_tr = float(np.asarray(res_tr["answers"], np.float64).mean())
        cost_tr = float(res_tr["cost"].mean())
        bar, best_save = ENTRY_BARS[0], -np.inf
        for cand in ENTRY_BARS:
            ent = router.entry_tiers(feats[tr], cand)
            r = _replay_cascade(data, scores, cas, cas.thresholds, tr,
                                entry=ent)
            a = float(np.asarray(r["answers"], np.float64).mean())
            save = cost_tr - float(r["cost"].mean())
            if a >= acc_tr - 1e-3 and save > best_save:
                bar, best_save = cand, save

        res_fix = _replay_cascade(data, scores, cas, cas.thresholds, te)
        entry = router.entry_tiers(feats[te], bar)
        res_ctx = _replay_cascade(data, scores, cas, cas.thresholds, te,
                                  entry=entry)

        acc_fix = float(np.asarray(res_fix["answers"], np.float64).mean())
        acc_ctx = float(np.asarray(res_ctx["answers"], np.float64).mean())
        cost_fix = float(res_fix["cost"].mean())
        cost_ctx = float(res_ctx["cost"].mean())
        saved = 1.0 - cost_ctx / cost_fix
        task_ok = cost_ctx < cost_fix and acc_ctx >= acc_fix - 0.005
        ok = ok and task_ok
        rows.append({
            "task": task, "cascade": cas.describe(data.names),
            "entry_bar": bar,
            "acc_fixed": round(acc_fix, 4), "acc_contextual": round(acc_ctx, 4),
            "cost_fixed": round(cost_fix, 7),
            "cost_contextual": round(cost_ctx, 7),
            "cost_saved_frac": round(saved, 4),
            "entry_hist": np.bincount(entry,
                                      minlength=len(cas.apis)).tolist(),
            "tier_counts_fixed": res_fix["tier_counts"],
            "tier_counts_contextual": res_ctx["tier_counts"],
            "pass": task_ok,
        })
    derived = {
        "claim": "contextual entry routing cuts cost at equal-or-better "
                 "accuracy vs the fixed cascade on every task",
        "cost_saved_frac": [r["cost_saved_frac"] for r in rows],
        "acc_delta": [round(r["acc_contextual"] - r["acc_fixed"], 4)
                      for r in rows],
        "pass": ok,
    }
    return rows, derived, time.time() - t0


def bench_budget_governor(n_trace: int = 4096, pool_n: int = 12000,
                          window: int = 64, budget_frac: float = 0.35,
                          rate: float = 500.0, drift=(0.35, 1.0),
                          tol: float = 0.10):
    """Online budget tracking under a drifting Poisson trace.

    The cascade is learned (and the target spend rate measured) on the
    training mix; the live trace then drifts from easy to hard queries
    and is harder in aggregate, so the fixed cascade overspends. The
    governor observes realized $/query per window and shifts the
    thresholds; the whole-trace realized rate must land within
    ``tol`` (+/-10%) of the target.
    """
    t0 = time.time()
    seed = 7
    data = simulate_market("HEADLINES", n=pool_n, seed=seed)
    scores = np.asarray(simulate_scores(data, seed=seed + 1))
    rng = np.random.default_rng(seed + 2)
    train = rng.permutation(pool_n)[:pool_n // 3]
    d_tr = _take(data, train)
    budget = float(np.asarray(data.cost).mean(0).max()) * budget_frac
    cas, metrics = learn_cascade(d_tr, scores[train], budget,
                                 RouterConfig(top_lists=15, sample=384))
    target = float(metrics["avg_cost"])     # the training-mix spend rate

    # drifting trace: arrival i draws from the difficulty quantile band
    # drift[0] -> drift[1] (jittered), so the mix hardens over time and
    # is harder in aggregate than the uniform training mix
    order = np.argsort(np.asarray(data.difficulty))
    q = np.linspace(drift[0], drift[1], n_trace)
    q = np.clip(q + 0.08 * rng.normal(size=n_trace), 0.0, 1.0)
    trace = order[(q * (pool_n - 1)).astype(np.int64)]
    arrivals = poisson_arrivals(n_trace, rate, seed=seed + 3)

    def run(governed: bool) -> tuple[float, list]:
        gov = BudgetGovernor(target, cas.thresholds, window=window,
                             eta=0.6, max_shift=0.4)
        total = 0.0
        per_window = []
        for i in range(0, n_trace, window):
            idx = trace[i:i + window]
            thr = gov.thresholds() if governed else cas.thresholds
            res = _replay_cascade(data, scores, cas, thr, idx)
            gov.observe_many(res["cost"])
            total += float(res["cost"].sum())
            per_window.append(float(res["cost"].mean()))
        return total / n_trace, per_window

    rate_gov, win_gov = run(governed=True)
    rate_fix, win_fix = run(governed=False)
    dev_gov = abs(rate_gov - target) / target
    dev_fix = abs(rate_fix - target) / target
    rows = [{
        "n_trace": n_trace, "window": window,
        "trace_span_s": round(float(arrivals[-1]), 3),
        "target_rate": round(target, 7),
        "governed_rate": round(rate_gov, 7),
        "fixed_rate": round(rate_fix, 7),
        "governed_dev_frac": round(dev_gov, 4),
        "fixed_dev_frac": round(dev_fix, 4),
        "first_window_rate": round(win_fix[0], 7),
        "last_window_rate_fixed": round(win_fix[-1], 7),
        "last_window_rate_governed": round(win_gov[-1], 7),
    }]
    derived = {
        "claim": f"governor holds realized $/query within +/-{tol:.0%} "
                 "of target on a drifting trace the fixed cascade "
                 "overspends on",
        "governed_dev_frac": rows[0]["governed_dev_frac"],
        "fixed_dev_frac": rows[0]["fixed_dev_frac"],
        "pass": dev_gov <= tol and dev_fix > dev_gov,
    }
    return rows, derived, time.time() - t0


def bench_guarantee(n_trace: int = 4096, pool_n: int = 12000,
                    window: int = 64, budget_frac: float = 0.35,
                    delta: float = 0.05, alpha: float = 0.05,
                    sample_frac: float = 0.5,
                    overconf: float = 0.1, onset: float = 0.125,
                    ramp_frac: float = 0.1):
    """Accuracy-guaranteed frugality under calibration drift, replay.

    The frozen grid's failure mode: the cascade is learned (thresholds
    and all) on the build split, then the deployed scorer's calibration
    erodes — accept scores inflate as ``s ** gamma`` with ``gamma``
    dropping from 1.0 to ``overconf`` over ``ramp_frac`` of the trace
    starting at ``onset``, so the cheap tier keeps clearing its
    threshold on queries it gets wrong.  The fixed cascade silently
    converts that into an accuracy gap vs the reference (top) tier far
    beyond ``delta``.

    The guarantee layer shadow-samples ``sample_frac`` of served
    queries against the reference (charged to its own meter), runs the
    sequential test, and its tighten ladder caps the governor's shift.
    The bench uses the bang-bang configuration (``levels=2``: level 1
    is the full ``-max_shift`` tighten) with persistent evidence
    memory (``stale_after``/``stat_cap`` effectively infinite) — the
    drift here is persistent, so a certified-safe probe back to level 0
    re-escalates on the very next decision instead of re-paying the
    detection latency.

    Replay has correctness bits, so the gap observable is the
    one-sided shortfall ``max(0, ref_correct - cascade_correct)``: on
    this pool the cheap tier's *accepted* rows beat the reference (the
    paper's "improve performance" effect), and a symmetric
    disagreement would count those beneficial flips as violations
    (live serving, which only sees answers, uses disagreement as the
    conservative upper bound instead).

    Claims, stated for the steady state (final quarter of the trace —
    an anytime-valid test cannot act before evidence accrues, so the
    contract certifies the *held configuration*, not the transient):
    the guaranteed run's steady-state shortfall is <= ``delta`` while
    the frozen grid's violates it.
    """
    import jax.numpy as jnp

    from repro.core.cascade import Cascade
    from repro.core.router import _grid_eval
    from repro.serving.guarantee import GuaranteeConfig, GuaranteeController

    t0 = time.time()
    seed = 19
    data = simulate_market("HEADLINES", n=pool_n, seed=seed)
    scores = np.asarray(simulate_scores(data, seed=seed + 1))
    rng = np.random.default_rng(seed + 2)
    train = rng.permutation(pool_n)[:pool_n // 3]
    d_tr = _take(data, train)
    budget = float(np.asarray(data.cost).mean(0).max()) * budget_frac

    # SMART's contract is stated against THE reference model, so the
    # chain is pinned: cheapest API -> best API (the reference); the
    # threshold comes from the repo's own budget-feasible grid search
    # on the build split — exactly the frozen artifact that goes stale
    acc_by_api = np.asarray(d_tr.correct, np.float64).mean(0)
    cost_by_api = np.asarray(d_tr.cost, np.float64).mean(0)
    ref_api = int(np.argmax(acc_by_api))
    cheap_api = int(np.argmin(cost_by_api))
    perm = (cheap_api, ref_api)
    grid = jnp.linspace(0.0, 1.0, 65)
    acc_g, cost_g = _grid_eval(perm, d_tr, scores[train], grid)
    feasible = np.asarray(cost_g) <= budget
    masked = np.where(feasible, np.asarray(acc_g), -1.0)
    cas = Cascade(perm, (float(grid[int(np.argmax(masked))]),))
    target = float(np.asarray(cost_g)[int(np.argmax(masked))])

    ref_correct = np.asarray(data.correct, np.float64)[:, ref_api]
    ref_price = np.asarray(data.cost, np.float64)[:, ref_api]
    s = np.asarray(scores)

    trace = rng.integers(0, pool_n, size=n_trace)
    # calibration drift: gamma 1.0 until ``onset``, then drops to
    # ``overconf`` over ``ramp_frac`` of the trace and stays there —
    # inflating every accept score the thresholds see
    ramp = np.clip((np.arange(n_trace) / n_trace - onset) / ramp_frac,
                   0.0, 1.0)
    gammas = 1.0 - (1.0 - overconf) * ramp

    def replay(idx, thr, gamma):
        def scorer(rows, _ans, j):
            return s[rows, cas.apis[j]] ** gamma
        return execute_cascade(replay_tiers(data, cas.apis), thr,
                               scorer, np.asarray(idx),
                               batch_size=max(1, len(idx)))

    def run(guarded: bool):
        guar = None
        gov = None
        if guarded:
            guar = GuaranteeController(GuaranteeConfig(
                delta=delta, alpha=alpha, sample_frac=sample_frac,
                window=32, levels=2, stale_after=10 ** 9,
                stat_cap=10 ** 9, retrain=False))
            # no cost pressure in this bench: the governor's window
            # never fills, so its raw shift stays 0 and the effective
            # shift IS the guarantee cap — the second dual constraint
            # acting alone
            gov = BudgetGovernor(target, cas.thresholds, window=10 ** 9,
                                 max_shift=0.4, guarantee=guar)
        casc_correct = np.empty(n_trace, np.float64)
        levels = []
        for i in range(0, n_trace, window):
            idx = trace[i:i + window]
            thr = gov.thresholds() if guarded else cas.thresholds
            res = replay(idx, thr, float(gammas[min(i + window // 2,
                                                    n_trace - 1)]))
            ans = np.asarray(res["answers"], np.float64)
            casc_correct[i:i + len(idx)] = ans
            if guarded:
                stopped = np.asarray(res["stopped_at"])
                top = len(cas.apis) - 1
                for k in range(len(idx)):
                    if not guar.should_sample():
                        continue
                    if stopped[k] == top:       # already the reference
                        guar.observe(0.0, 0.0, invoked=False)
                    else:
                        gap = max(0.0, ref_correct[idx[k]] - ans[k])
                        guar.observe(gap, ref_price[idx[k]], invoked=True)
                levels.append(guar.level)
        shortfall = np.maximum(0.0, ref_correct[trace] - casc_correct)
        steady = n_trace - n_trace // 4
        return (float(shortfall.mean()), float(shortfall[steady:].mean()),
                guar, levels)

    gap_fix, steady_fix, _, _ = run(guarded=False)
    gap_guar, steady_guar, guar, levels = run(guarded=True)
    snap = guar.snapshot()
    shadow_frac_cost = guar.shadow_cost / max(
        float(ref_price[trace].sum() * sample_frac), 1e-12)
    ok = bool(steady_guar <= delta and steady_fix > delta)
    rows = [{
        "n_trace": n_trace, "window": window,
        "cascade": cas.describe(data.names),
        "delta": delta, "alpha": alpha, "sample_frac": sample_frac,
        "gamma_final": round(float(gammas[-1]), 3),
        "gap_fixed": round(gap_fix, 4),
        "gap_guaranteed": round(gap_guar, 4),
        "steady_gap_fixed": round(steady_fix, 4),
        "steady_gap_guaranteed": round(steady_guar, 4),
        "final_level": snap["level"],
        "max_level": int(max(levels)) if levels else 0,
        "gap_ucb_final": round(snap["gap_ucb"], 4),
        "certified_final": snap["certified"],
        "n_shadow": snap["n_shadow"], "n_invoked": snap["n_invoked"],
        "shadow_cost": round(snap["shadow_cost"], 7),
        "shadow_cost_vs_full_ref_frac": round(shadow_frac_cost, 4),
        "pass": ok,
    }]
    derived = {
        "claim": f"online guarantee holds the steady-state accuracy "
                 f"shortfall <= {delta} under a calibration drift the "
                 "frozen offline grid violates",
        "steady_gap_fixed": rows[0]["steady_gap_fixed"],
        "steady_gap_guaranteed": rows[0]["steady_gap_guaranteed"],
        "pass": ok,
    }
    return rows, derived, time.time() - t0


def _entry_from_probs(probs: np.ndarray, bar: float) -> np.ndarray:
    """The greedy contextual entry rule (``ContextualRouter.entry_tiers``)
    applied to externally supplied accept probabilities — lets the
    greedy baseline and the window solver share ONE trained meta-model."""
    clears = np.asarray(probs) >= bar
    clears[:, -1] = True                       # final position catches all
    return np.asarray(clears.argmax(1), np.int32)


def _bursty_arrivals(n: int, rate: float, burst: float, regime_len: float,
                     rng) -> np.ndarray:
    """Two-state modulated Poisson process: alternating hot/quiet regimes
    (geometric lengths, mean ``regime_len`` arrivals) at ``rate * burst``
    and ``rate / burst``. Returns (n,) arrival times — fixed-span windows
    carved from this are ragged: packed in bursts, sparse in lulls."""
    gaps = np.empty(n, np.float64)
    i, hot = 0, True
    while i < n:
        j = min(n, i + int(rng.geometric(1.0 / regime_len)))
        r = rate * burst if hot else rate / burst
        gaps[i:j] = rng.exponential(1.0 / r, size=j - i)
        i, hot = j, not hot
    return np.cumsum(gaps)


def bench_window_assignment(task: str = "HEADLINES", n: int = 6000,
                            budget_frac: float = 0.35,
                            meta_steps: int = 400, n_trace: int = 2048,
                            rate: float = 160.0, burst: float = 3.0,
                            window_s: float = 0.2,
                            budget_tighten: float = 1.0):
    """Budgeted window assignment vs greedy contextual routing, offline
    replay over a bursty Poisson trace.

    Build phase (train half of a fee-bearing marketplace): learn
    (L, tau), then train ONE window meta-model — accept head on the
    router's own labels, correct head on recorded correctness. Both
    contenders read that same model: the greedy baseline routes each
    query alone through the entry-bar rule on ``accept_probs`` (bar
    selected on the train split exactly like ``bench_contextual_routing``
    selects it), the solver gets the composed (utility, expected-cost)
    matrices for whole windows, column-calibrated into realized dollars
    on the same split. Any gap between them is therefore the
    *assignment*, not the predictor.

    Serve phase: a bursty two-regime Poisson trace over held-out
    queries, carved into fixed-span wall-clock windows (ragged sizes —
    the pow2-padded solve's natural diet). The global spend target is
    ``budget_tighten`` x the greedy rule's own realized $/query on the
    build split; each window's budget paces that rate by the window's
    predicted least-cost mass (a burst of hard queries gets its
    proportional share; the aggregate is the global rate), with unspent
    slack rolling forward. Claims: every window's committed (predicted)
    cost respects its budget, and the assignment matches/beats greedy
    accuracy at lower realized cost — the greedy rule has no spend
    feedback, so on the harder-than-build bursty mix it drifts over the
    very rate the solver's hard constraint holds.
    """
    from repro.serving.assign import (WindowMeta, correctness_labels,
                                      pow2_rows, solve_assignment,
                                      train_window_meta)

    t0 = time.time()
    seed = 400
    market = {k: TABLE1[k] for k in FEE_MARKET}
    data = simulate_market(task, n=n, seed=seed, apis=market)
    scores = np.asarray(simulate_scores(data, seed=seed + 1))
    feats = _context_features(data, scores, seed + 2)
    rng = np.random.default_rng(seed + 3)
    perm = rng.permutation(n)
    tr, te = perm[:n // 2], perm[n // 2:]
    d_tr = _take(data, tr)

    budget = float(np.asarray(data.cost).mean(0).max()) * budget_frac
    cas, _ = learn_cascade(d_tr, scores[tr], budget,
                           RouterConfig(top_lists=15, sample=384,
                                        seed=seed))
    apis = np.asarray(cas.apis)
    m = len(apis)

    # ONE meta-model for both contenders
    accept = accept_labels(scores[tr], np.asarray(d_tr.correct),
                           cas.apis, cas.thresholds)
    correct = correctness_labels(np.asarray(d_tr.correct), cas.apis)
    meta = train_window_meta(feats[tr], accept, correct,
                             steps=meta_steps, seed=seed)
    prices = np.asarray(data.cost, np.float64)[:, apis]

    # greedy bar selection on the train split (same protocol as
    # bench_contextual_routing), then the spend rate that bar realizes
    # there sets the solver's budget — tightened below it
    probs_tr = meta.accept_probs(feats[tr])
    res_tr = _replay_cascade(data, scores, cas, cas.thresholds, tr)
    acc_tr = float(np.asarray(res_tr["answers"], np.float64).mean())
    cost_tr = float(res_tr["cost"].mean())
    bar, best_save = ENTRY_BARS[0], -np.inf
    for cand in ENTRY_BARS:
        r = _replay_cascade(data, scores, cas, cas.thresholds, tr,
                            entry=_entry_from_probs(probs_tr, cand))
        a = float(np.asarray(r["answers"], np.float64).mean())
        save = cost_tr - float(r["cost"].mean())
        if a >= acc_tr - 1e-3 and save > best_save:
            bar, best_save = cand, save
    res_g_tr = _replay_cascade(data, scores, cas, cas.thresholds, tr,
                               entry=_entry_from_probs(probs_tr, bar))
    greedy_rate_tr = float(res_g_tr["cost"].mean())
    budget_rate = budget_tighten * greedy_rate_tr

    # per-entry-column cost calibration on the train split: the accept
    # head's bias compounds through the reach chain, so predicted
    # downstream cost is systematically off realized cost by a
    # column-dependent factor — measure it once (m replays over build
    # data) and scale the solver's cost matrices into realized dollars
    n_tr_pad = pow2_rows(len(tr))
    emb_tr = np.zeros((n_tr_pad, feats.shape[1]), np.float32)
    emb_tr[:len(tr)] = feats[tr]
    prc_tr = np.zeros((n_tr_pad, m), np.float64)
    prc_tr[:len(tr)] = prices[tr]
    _, ecost_tr = meta.scores(emb_tr, prc_tr)
    kappa = np.empty(m)
    for e in range(m):
        r = _replay_cascade(data, scores, cas, cas.thresholds, tr,
                            entry=np.full(len(tr), e, np.int32))
        kappa[e] = float(r["cost"].mean()) / max(
            float(ecost_tr[:len(tr), e].mean()), 1e-12)
    # the achievable floor (every row at its cheapest calibrated entry)
    # turns the global $/query rate into a *pace* — budget_w below a
    # window's floor is unsatisfiable by any assignment, so windows are
    # budgeted proportionally to their predicted least-cost mass
    floor_rate_tr = float(
        (ecost_tr[:len(tr)] * kappa[None, :]).min(axis=1).mean())
    # a rate below the model's own floor is unsatisfiable by ANY
    # assignment — clamp the pace a hair above break-even so every
    # window stays feasible even when greedy realizes below the floor
    pace = max(budget_rate / floor_rate_tr, 1.005)

    # bursty trace over held-out queries, carved into wall-clock windows
    t_arr = _bursty_arrivals(n_trace, rate, burst, regime_len=64.0,
                             rng=rng)
    trace = rng.choice(te, size=n_trace)
    win_id = (t_arr / window_s).astype(np.int64)

    probs_te = meta.accept_probs(feats[trace])
    res_greedy = _replay_cascade(data, scores, cas, cas.thresholds, trace,
                                 entry=_entry_from_probs(probs_te, bar))

    cost_assign = 0.0
    answers_assign = []
    win_sizes, util_frac = [], []
    budget_ok, n_windows = True, 0
    solver_iters, carry = 0, 0.0
    for w in np.unique(win_id):
        rows = np.flatnonzero(win_id == w)
        idx = trace[rows]
        n_w = len(idx)
        # pow2-pad the meta forward too, so ragged windows share traces
        n_pad = pow2_rows(n_w)
        emb_p = np.zeros((n_pad, feats.shape[1]), np.float32)
        emb_p[:n_w] = feats[idx]
        prc_p = np.zeros((n_pad, m), np.float64)
        prc_p[:n_w] = prices[idx]
        util, ecost = meta.scores(emb_p, prc_p)
        ecost = ecost * kappa[None, :]         # into realized dollars
        # window budget = pace x this window's least-cost mass, plus
        # unspent slack rolled forward (never borrowed) — aggregate
        # committed spend stays at the global rate while every single
        # window stays satisfiable
        budget_w = pace * float(ecost[:n_w].min(axis=1).sum()) + carry
        sol = solve_assignment(util[:n_w], ecost[:n_w], None, budget_w)
        carry = max(0.0, budget_w - sol["predicted_cost"])
        budget_ok = budget_ok and sol["feasible"] and \
            sol["predicted_cost"] <= budget_w * (1.0 + 1e-6)
        r = _replay_cascade(data, scores, cas, cas.thresholds, idx,
                            entry=sol["assignment"])
        cost_assign += float(r["cost"].sum())
        answers_assign.append(np.asarray(r["answers"], np.float64))
        win_sizes.append(n_w)
        util_frac.append(sol["predicted_cost"] / budget_w)
        solver_iters += sol["iterations"]
        n_windows += 1

    acc_assign = float(np.concatenate(answers_assign).mean())
    acc_greedy = float(np.asarray(res_greedy["answers"], np.float64).mean())
    rate_assign = cost_assign / n_trace
    rate_greedy = float(res_greedy["cost"].mean())
    beats = ((acc_assign >= acc_greedy - 0.005
              and rate_assign < rate_greedy)
             or (acc_assign > acc_greedy
                 and rate_assign <= rate_greedy * (1.0 + 1e-3)))
    ok = bool(budget_ok and beats)
    rows = [{
        "task": task, "cascade": cas.describe(data.names),
        "entry_bar": bar, "n_trace": n_trace, "n_windows": n_windows,
        "window_min": int(min(win_sizes)),
        "window_max": int(max(win_sizes)),
        "budget_per_q": round(budget_rate, 7),
        "floor_per_q_train": round(floor_rate_tr, 7),
        "pace": round(pace, 4),
        "greedy_rate_train": round(greedy_rate_tr, 7),
        "acc_greedy": round(acc_greedy, 4),
        "acc_assign": round(acc_assign, 4),
        "cost_greedy": round(rate_greedy, 7),
        "cost_assign": round(rate_assign, 7),
        "cost_saved_frac": round(1.0 - rate_assign / rate_greedy, 4),
        "budget_utilization_max": round(float(np.max(util_frac)), 4),
        "solver_moves_per_window": round(solver_iters / n_windows, 2),
        "pass": ok,
    }]
    derived = {
        "claim": "window assignment matches/beats greedy contextual "
                 "routing's accuracy at lower realized cost, every "
                 "window's committed cost within its budget (paced at "
                 "the spend rate greedy itself drifts over)",
        "acc_delta": round(acc_assign - acc_greedy, 4),
        "cost_saved_frac": rows[0]["cost_saved_frac"],
        "budget_respected": bool(budget_ok),
        "pass": ok,
    }
    return rows, derived, time.time() - t0


# -- standalone driver (CI bench trajectory) --------------------------------

#: (name, fn, smoke-mode kwargs) — smoke shrinks sizes so the sweep fits
#: a CPU CI runner in a couple of minutes
BENCHES = [
    # the full sizes already fit a CPU CI runner in seconds, and the
    # claims need the full train half (bar selection) and the full
    # window count (controller lag) to hold — smoke == full here
    ("contextual_routing", bench_contextual_routing, {}),
    ("budget_governor", bench_budget_governor, {}),
    # controller lag needs the full trace to amortize; the pool shrink
    # alone makes smoke fit the runner budget
    ("guarantee", bench_guarantee,
     {"pool_n": 6000, "n_trace": 2048, "sample_frac": 1.0}),
    # build cost (market sim + cascade + meta training) dominates the
    # window sweep, so shrinking the trace saves nothing: smoke == full
    ("window_assignment", bench_window_assignment, {}),
]


def main(argv=None) -> int:
    """Run the strategy benches and write one JSON record — CI runs this
    with ``--smoke`` and uploads the file alongside the serving sweep.
    Claim-check failures only fail the process in full (non-smoke) mode:
    smoke sizes on shared CI runners are trend lines, not gates."""
    import argparse
    import json
    import platform

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI: trend data, non-gating")
    ap.add_argument("--json-out", default="BENCH_strategy.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    results = {"smoke": args.smoke,
               "platform": platform.platform(),
               "benches": {}}
    failures = []
    for name, fn, smoke_kw in BENCHES:
        if only is not None and name not in only:
            continue
        rows, derived, secs = fn(**(smoke_kw if args.smoke else {}))
        results["benches"][name] = {"rows": rows, "derived": derived,
                                    "secs": round(secs, 3)}
        print(f"{name},{secs * 1e6:.1f},{json.dumps(derived, default=str)}")
        if not derived.get("pass", True):
            failures.append(name)

    with open(args.json_out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\n# wrote {args.json_out}; "
          f"{len(failures)} claim-check failures: {failures or 'none'}")
    return 0 if (args.smoke or not failures) else 1


if __name__ == "__main__":
    raise SystemExit(main())
