"""Strategy-layer benchmarks: contextual entry routing vs the fixed
cascade, and online budget governance under traffic drift.

Two claims, each doubling as a regression check (rows/derived/secs
contract shared with bench_serving):

  * ``bench_contextual_routing`` — on >= 2 synthetic marketplace tasks,
    a contextual entry router (trained on observable query context
    correlated with the latent difficulty) reduces cost vs the fixed
    learned cascade at equal-or-better accuracy: hard queries skip the
    cheap tiers that were dead weight for them.
  * ``bench_budget_governor`` — on a drifting Poisson trace whose query
    mix hardens over time (and is harder in aggregate than the training
    distribution), the online governor keeps the realized $/query
    within +/-10% of the target spend rate, while the fixed cascade
    drifts far over it.

Runnable standalone for the CI bench trajectory:
  PYTHONPATH=src python -m benchmarks.bench_strategy --smoke \\
      --json-out BENCH_strategy.json
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cascade import execute_cascade, replay_tiers
from repro.core.cost import TABLE1
from repro.core.router import RouterConfig, learn_cascade
from repro.core.simulate import MarketData, simulate_market, simulate_scores
from repro.serving.ingress import poisson_arrivals
from repro.serving.strategy import (BudgetGovernor, ContextualRouter,
                                    accept_labels, train_entry_router)

#: the bench marketplace: Table-1 APIs with per-request fees in the mix
#: (J1 tiers) — entry routing pays off when probing a cheap tier costs
#: real money; a marketplace of near-free probes has nothing to skip
FEE_MARKET = ("J1-L", "J1-G", "Cohere", "GPT-3", "GPT-4")


def _context_features(data: MarketData, scores: np.ndarray, seed: int,
                      noise: float = 1.0, d: int = 24) -> np.ndarray:
    """Observable per-query context: a random-Fourier lift of *noisy*
    views of each API's reliability (logit of g(q, a_k) + noise) plus
    the latent difficulty — the offline stand-in for what a deployed
    meta-model reads off the query embedding (Šakota et al.:
    query-side success prediction), informative but far from exact."""
    rng = np.random.default_rng(seed)
    s = np.clip(np.asarray(scores, np.float64), 1e-4, 1.0 - 1e-4)
    z = np.log(s / (1.0 - s)) + noise * rng.normal(size=s.shape)
    z = np.concatenate([z, np.asarray(data.difficulty)[:, None]], axis=1)
    w = rng.normal(size=(z.shape[1], d)) / np.sqrt(z.shape[1])
    b = rng.uniform(0.0, 2.0 * np.pi, size=d)
    return (np.sqrt(2.0 / d) * np.cos(z @ w + b)).astype(np.float32)


def _take(data: MarketData, idx: np.ndarray) -> MarketData:
    return MarketData(data.names, data.correct[idx], data.cost[idx],
                      data.n_in[idx], data.n_out[idx], data.difficulty[idx])


def _replay_cascade(data: MarketData, scores: np.ndarray, cas, thresholds,
                    idx: np.ndarray, entry=None) -> dict:
    """Run the learned cascade over rows ``idx`` of offline data via the
    replay backend; answers are correctness bits, costs are recorded."""
    s = np.asarray(scores)

    def scorer(rows, _ans, j):
        return s[rows, cas.apis[j]]

    return execute_cascade(replay_tiers(data, cas.apis), thresholds,
                           scorer, np.asarray(idx),
                           batch_size=max(1, len(idx)), entry=entry)


#: candidate entry bars the train split selects among — the mis-skip
#: penalty (paying a pricier tier for a query the cheap tier would have
#: answered) is several times the right-skip saving, so profitable bars
#: are conservative: skip only on confident rejection predictions
ENTRY_BARS = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.4)


def bench_contextual_routing(tasks=("HEADLINES", "OVERRULING"),
                             n: int = 4000, budget_frac: float = 0.35,
                             router_steps: int = 500):
    """Contextual entry routing vs the fixed cascade, offline replay.

    Per task: learn (L, tau) on a training half of a fee-bearing
    marketplace, train the entry router on the same artifacts (accept
    labels vs the learned thresholds, on noisy reliability-context
    features), select the entry bar on the *train* split (max cost
    saving subject to no accuracy loss), then serve the held-out half
    both ways. The router must cut cost at equal-or-better accuracy —
    queries it correctly predicts the cheap tiers would fail enter
    higher and skip those tiers' charges entirely.
    """
    t0 = time.time()
    market = {k: TABLE1[k] for k in FEE_MARKET}
    rows = []
    ok = True
    for ti, task in enumerate(tasks):
        seed = 100 + 17 * ti
        data = simulate_market(task, n=n, seed=seed, apis=market)
        scores = np.asarray(simulate_scores(data, seed=seed + 1))
        feats = _context_features(data, scores, seed + 2)
        rng = np.random.default_rng(seed + 3)
        perm = rng.permutation(n)
        tr, te = perm[:n // 2], perm[n // 2:]
        d_tr = _take(data, tr)

        budget = float(np.asarray(data.cost).mean(0).max()) * budget_frac
        cas, _ = learn_cascade(d_tr, scores[tr], budget,
                               RouterConfig(top_lists=15, sample=384,
                                            seed=seed))
        labels = accept_labels(scores[tr], np.asarray(d_tr.correct),
                               cas.apis, cas.thresholds)
        params = train_entry_router(feats[tr], labels, steps=router_steps,
                                    seed=seed)
        router = ContextualRouter(params, len(cas.apis))

        # entry-bar selection on the train split: the saving-vs-mistake
        # asymmetry makes the right bar task-dependent
        res_tr = _replay_cascade(data, scores, cas, cas.thresholds, tr)
        acc_tr = float(np.asarray(res_tr["answers"], np.float64).mean())
        cost_tr = float(res_tr["cost"].mean())
        bar, best_save = ENTRY_BARS[0], -np.inf
        for cand in ENTRY_BARS:
            ent = router.entry_tiers(feats[tr], cand)
            r = _replay_cascade(data, scores, cas, cas.thresholds, tr,
                                entry=ent)
            a = float(np.asarray(r["answers"], np.float64).mean())
            save = cost_tr - float(r["cost"].mean())
            if a >= acc_tr - 1e-3 and save > best_save:
                bar, best_save = cand, save

        res_fix = _replay_cascade(data, scores, cas, cas.thresholds, te)
        entry = router.entry_tiers(feats[te], bar)
        res_ctx = _replay_cascade(data, scores, cas, cas.thresholds, te,
                                  entry=entry)

        acc_fix = float(np.asarray(res_fix["answers"], np.float64).mean())
        acc_ctx = float(np.asarray(res_ctx["answers"], np.float64).mean())
        cost_fix = float(res_fix["cost"].mean())
        cost_ctx = float(res_ctx["cost"].mean())
        saved = 1.0 - cost_ctx / cost_fix
        task_ok = cost_ctx < cost_fix and acc_ctx >= acc_fix - 0.005
        ok = ok and task_ok
        rows.append({
            "task": task, "cascade": cas.describe(data.names),
            "entry_bar": bar,
            "acc_fixed": round(acc_fix, 4), "acc_contextual": round(acc_ctx, 4),
            "cost_fixed": round(cost_fix, 7),
            "cost_contextual": round(cost_ctx, 7),
            "cost_saved_frac": round(saved, 4),
            "entry_hist": np.bincount(entry,
                                      minlength=len(cas.apis)).tolist(),
            "tier_counts_fixed": res_fix["tier_counts"],
            "tier_counts_contextual": res_ctx["tier_counts"],
            "pass": task_ok,
        })
    derived = {
        "claim": "contextual entry routing cuts cost at equal-or-better "
                 "accuracy vs the fixed cascade on every task",
        "cost_saved_frac": [r["cost_saved_frac"] for r in rows],
        "acc_delta": [round(r["acc_contextual"] - r["acc_fixed"], 4)
                      for r in rows],
        "pass": ok,
    }
    return rows, derived, time.time() - t0


def bench_budget_governor(n_trace: int = 4096, pool_n: int = 12000,
                          window: int = 64, budget_frac: float = 0.35,
                          rate: float = 500.0, drift=(0.35, 1.0),
                          tol: float = 0.10):
    """Online budget tracking under a drifting Poisson trace.

    The cascade is learned (and the target spend rate measured) on the
    training mix; the live trace then drifts from easy to hard queries
    and is harder in aggregate, so the fixed cascade overspends. The
    governor observes realized $/query per window and shifts the
    thresholds; the whole-trace realized rate must land within
    ``tol`` (+/-10%) of the target.
    """
    t0 = time.time()
    seed = 7
    data = simulate_market("HEADLINES", n=pool_n, seed=seed)
    scores = np.asarray(simulate_scores(data, seed=seed + 1))
    rng = np.random.default_rng(seed + 2)
    train = rng.permutation(pool_n)[:pool_n // 3]
    d_tr = _take(data, train)
    budget = float(np.asarray(data.cost).mean(0).max()) * budget_frac
    cas, metrics = learn_cascade(d_tr, scores[train], budget,
                                 RouterConfig(top_lists=15, sample=384))
    target = float(metrics["avg_cost"])     # the training-mix spend rate

    # drifting trace: arrival i draws from the difficulty quantile band
    # drift[0] -> drift[1] (jittered), so the mix hardens over time and
    # is harder in aggregate than the uniform training mix
    order = np.argsort(np.asarray(data.difficulty))
    q = np.linspace(drift[0], drift[1], n_trace)
    q = np.clip(q + 0.08 * rng.normal(size=n_trace), 0.0, 1.0)
    trace = order[(q * (pool_n - 1)).astype(np.int64)]
    arrivals = poisson_arrivals(n_trace, rate, seed=seed + 3)

    def run(governed: bool) -> tuple[float, list]:
        gov = BudgetGovernor(target, cas.thresholds, window=window,
                             eta=0.6, max_shift=0.4)
        total = 0.0
        per_window = []
        for i in range(0, n_trace, window):
            idx = trace[i:i + window]
            thr = gov.thresholds() if governed else cas.thresholds
            res = _replay_cascade(data, scores, cas, thr, idx)
            gov.observe_many(res["cost"])
            total += float(res["cost"].sum())
            per_window.append(float(res["cost"].mean()))
        return total / n_trace, per_window

    rate_gov, win_gov = run(governed=True)
    rate_fix, win_fix = run(governed=False)
    dev_gov = abs(rate_gov - target) / target
    dev_fix = abs(rate_fix - target) / target
    rows = [{
        "n_trace": n_trace, "window": window,
        "trace_span_s": round(float(arrivals[-1]), 3),
        "target_rate": round(target, 7),
        "governed_rate": round(rate_gov, 7),
        "fixed_rate": round(rate_fix, 7),
        "governed_dev_frac": round(dev_gov, 4),
        "fixed_dev_frac": round(dev_fix, 4),
        "first_window_rate": round(win_fix[0], 7),
        "last_window_rate_fixed": round(win_fix[-1], 7),
        "last_window_rate_governed": round(win_gov[-1], 7),
    }]
    derived = {
        "claim": f"governor holds realized $/query within +/-{tol:.0%} "
                 "of target on a drifting trace the fixed cascade "
                 "overspends on",
        "governed_dev_frac": rows[0]["governed_dev_frac"],
        "fixed_dev_frac": rows[0]["fixed_dev_frac"],
        "pass": dev_gov <= tol and dev_fix > dev_gov,
    }
    return rows, derived, time.time() - t0


# -- standalone driver (CI bench trajectory) --------------------------------

#: (name, fn, smoke-mode kwargs) — smoke shrinks sizes so the sweep fits
#: a CPU CI runner in a couple of minutes
BENCHES = [
    # the full sizes already fit a CPU CI runner in seconds, and the
    # claims need the full train half (bar selection) and the full
    # window count (controller lag) to hold — smoke == full here
    ("contextual_routing", bench_contextual_routing, {}),
    ("budget_governor", bench_budget_governor, {}),
]


def main(argv=None) -> int:
    """Run the strategy benches and write one JSON record — CI runs this
    with ``--smoke`` and uploads the file alongside the serving sweep.
    Claim-check failures only fail the process in full (non-smoke) mode:
    smoke sizes on shared CI runners are trend lines, not gates."""
    import argparse
    import json
    import platform

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI: trend data, non-gating")
    ap.add_argument("--json-out", default="BENCH_strategy.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    results = {"smoke": args.smoke,
               "platform": platform.platform(),
               "benches": {}}
    failures = []
    for name, fn, smoke_kw in BENCHES:
        if only is not None and name not in only:
            continue
        rows, derived, secs = fn(**(smoke_kw if args.smoke else {}))
        results["benches"][name] = {"rows": rows, "derived": derived,
                                    "secs": round(secs, 3)}
        print(f"{name},{secs * 1e6:.1f},{json.dumps(derived, default=str)}")
        if not derived.get("pass", True):
            failures.append(name)

    with open(args.json_out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\n# wrote {args.json_out}; "
          f"{len(failures)} claim-check failures: {failures or 'none'}")
    return 0 if (args.smoke or not failures) else 1


if __name__ == "__main__":
    raise SystemExit(main())
