"""Serving-path benchmarks: the unified 3-strategy pipeline and the
bucketed prefill compilation cache.

Each function returns (rows, derived, secs) like bench_paper — derived
carries a pass/fail claim check so benchmarks double as regressions.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.core.approx import CompletionCache
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.models import transformer as T
from repro.serving.engine import GenerationEngine
from repro.serving.ingress import ContinuousBatcher, poisson_arrivals
from repro.serving.pipeline import ServingPipeline, TierSpec


def _toy_pipeline(n_tiers: int = 3, batch_size: int = 256):
    """Callable tiers (no model training) so the benchmark isolates the
    pipeline's own overhead: cache lookup, compaction, accounting."""
    rng = np.random.default_rng(0)
    tiers = []
    for j in range(n_tiers):
        price = ApiCost(10.0 * 10 ** j, 10.0 * 10 ** j, 0.0)
        tiers.append(TierSpec(
            f"tier{j}",
            lambda t, j=j: np.full(len(t), j, np.int32),
            price, prompt=PromptSpec(tuple(range(j + 1)), 100, 40)))
    thresholds = [0.5] * (n_tiers - 1)

    def scorer(t, ans):
        return rng.uniform(size=len(t))

    def embed(tokens):
        e = np.zeros((len(tokens), 128), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 128] = 1.0
        return e

    return ServingPipeline(
        tiers=tiers, thresholds=thresholds, scorer=scorer,
        cache=CompletionCache(capacity=4096, threshold=0.99), embed=embed,
        full_prompt_tokens=840, pad_token=-1, batch_size=batch_size)


def bench_pipeline_throughput(n: int = 4096, repeat_frac: float = 0.5):
    """Unified pipeline over a repetition-heavy stream: the cache should
    absorb the repeats and total cost should undercut the baseline."""
    t0 = time.time()
    pipe = _toy_pipeline()
    uniq = int(n * (1 - repeat_frac))
    toks = np.arange(uniq * 8, dtype=np.int32).reshape(uniq, 8)
    toks[:, 0] = np.arange(uniq)
    warm = pipe.serve(toks)                        # populate the cache
    idx = np.random.default_rng(1).integers(0, uniq, size=n)
    t1 = time.time()
    res = pipe.serve(toks[idx])
    serve_s = time.time() - t1
    rows = [{
        "n": n, "qps": n / serve_s,
        "cache_hit_rate": res.cache_hit_rate,
        "tier_counts": res.tier_counts,
        "savings_frac": res.savings_frac,
        "stage_ms": {k: round(v * 1e3, 2) for k, v in res.latency.items()},
    }]
    derived = {
        "claim": "cache absorbs repeats; cost beats top-tier baseline",
        "qps": rows[0]["qps"],
        "hit_rate": res.cache_hit_rate,
        "pass": res.cache_hit_rate > 0.9 and res.savings_frac > 0.5
        and warm.cache_hit_rate == 0.0,
    }
    return rows, derived, time.time() - t0


def bench_continuous_batching(n: int = 128, max_chunk: int = 8,
                              span_factor: float = 1.5, repeats: int = 2):
    """Continuous batching vs batch-at-a-time on a mixed-length Poisson
    arrival stream over generation-backed tiers (real decode work).

    Batch-at-a-time must wait for the last arrival before it can serve
    the closed batch; the continuous batcher overlaps tier chunks with
    the arrival window, so its throughput (requests / time-to-drain,
    measured from the first arrival) should come out >= the batch path,
    with far lower per-request p50/p95. Both paths take the best of
    ``repeats`` runs (and a ``gc.collect()`` beforehand) so one stray
    scheduler/GC hiccup doesn't decide the comparison.
    """
    import gc

    t0 = time.time()
    cfg = ARCHS["gemma3-1b"].reduced()
    rng = np.random.default_rng(4)

    def gen_tier(name, seed, price):
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
        eng = GenerationEngine(cfg, params)

        def answer(t, eng=eng):
            return np.asarray(eng.generate(t, n_new=2)[:, 0] % 3)

        return TierSpec(name, answer, price, n_out=2)

    tiers = [gen_tier("small", 0, ApiCost(10.0, 10.0, 0.0)),
             gen_tier("large", 1, ApiCost(100.0, 100.0, 0.0))]

    # mixed-length stream: true lengths 6..16, right-padded to width 16
    width = 16
    toks = rng.integers(1, cfg.vocab, size=(n, width)).astype(np.int32)
    for i, ln in enumerate(rng.integers(6, width + 1, size=n)):
        toks[i, ln:] = 0
    pipe = ServingPipeline(
        tiers=tiers, thresholds=[0.5],
        scorer=lambda t, a: np.where(t[:, 0] % 2 == 0, 0.9, 0.1),
        full_prompt_tokens=200, pad_token=0, batch_size=max_chunk)

    pipe.serve(toks)                               # warm the jit caches
    serve_s = float("inf")
    for _ in range(repeats):
        gc.collect()
        t1 = time.time()
        res_batch = pipe.serve(toks)
        serve_s = min(serve_s, time.time() - t1)

    # Poisson trace spanning ~span_factor x the measured batch serve time
    arrivals = poisson_arrivals(n, n / (span_factor * serve_s), seed=5)
    res_cont = None
    for _ in range(repeats):
        gc.collect()
        r = ContinuousBatcher(pipe, max_chunk=max_chunk).run_trace(
            toks, arrivals)
        if res_cont is None or r.latency["total"] < res_cont.latency["total"]:
            res_cont = r

    t_last = float(arrivals[-1])
    qps_batch = n / (t_last + serve_s)             # wait for trace, then serve
    qps_cont = n / res_cont.latency["total"]
    lat_batch = (t_last + serve_s) - arrivals      # finish-all minus arrival
    lat_cont = res_cont.ingress["request_latency"]
    rows = [{
        "n": n, "trace_span_s": round(t_last, 4),
        "batch_serve_s": round(serve_s, 4),
        "qps_batch": round(qps_batch, 1), "qps_continuous": round(qps_cont, 1),
        "p50_ms_batch": round(float(np.percentile(lat_batch, 50)) * 1e3, 2),
        "p95_ms_batch": round(float(np.percentile(lat_batch, 95)) * 1e3, 2),
        "p50_ms_continuous": round(float(np.percentile(lat_cont, 50)) * 1e3, 2),
        "p95_ms_continuous": round(float(np.percentile(lat_cont, 95)) * 1e3, 2),
        "chunks_per_tier": res_cont.ingress["chunks_per_tier"],
        "chunk_occupancy": round(res_cont.ingress["chunk_occupancy"], 3),
    }]
    answers_match = bool(np.array_equal(res_batch.answers, res_cont.answers)
                         and (res_batch.cost == res_cont.cost).all())
    derived = {
        "claim": "continuous batching >= batch-at-a-time throughput on a "
                 "Poisson stream; answers/costs bit-identical",
        "qps_continuous": rows[0]["qps_continuous"],
        "qps_batch": rows[0]["qps_batch"],
        "p95_ms_continuous": rows[0]["p95_ms_continuous"],
        "p95_ms_batch": rows[0]["p95_ms_batch"],
        "answers_match": answers_match,
        "pass": qps_cont >= qps_batch and answers_match,
    }
    return rows, derived, time.time() - t0


def bench_bucketed_prefill(n_shapes: int = 12):
    """Bucketed compilation: a sweep of distinct request shapes must
    compile far fewer prefill variants than the per-shape jit cache the
    engine replaced (which compiled once per (seq, max_len))."""
    t0 = time.time()
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params)
    rng = np.random.default_rng(2)
    shapes = [(int(b), int(s)) for b, s in
              zip(rng.integers(1, 9, n_shapes), rng.integers(9, 17, n_shapes))]
    for b, s in shapes:
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(b * 31 + s),
                                             (b, s), 0, cfg.vocab))
        eng.generate(toks, n_new=4)
    stats = eng.compile_stats
    rows = [{"distinct_shapes": len(set(shapes)), "calls": stats["prefill_calls"],
             "compiles": stats["prefill_compiles"]}]
    derived = {
        "claim": "compiles << distinct request shapes",
        "compiles": stats["prefill_compiles"],
        "distinct_shapes": len(set(shapes)),
        "pass": stats["prefill_compiles"] <= 2
        and stats["prefill_calls"] == n_shapes,
    }
    return rows, derived, time.time() - t0
