"""Serving-path benchmarks: the unified 3-strategy pipeline and the
bucketed prefill compilation cache.

Each function returns (rows, derived, secs) like bench_paper — derived
carries a pass/fail claim check so benchmarks double as regressions.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.core.approx import CompletionCache
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.models import transformer as T
from repro.serving.engine import GenerationEngine
from repro.serving.pipeline import ServingPipeline, TierSpec


def _toy_pipeline(n_tiers: int = 3, batch_size: int = 256):
    """Callable tiers (no model training) so the benchmark isolates the
    pipeline's own overhead: cache lookup, compaction, accounting."""
    rng = np.random.default_rng(0)
    tiers = []
    for j in range(n_tiers):
        price = ApiCost(10.0 * 10 ** j, 10.0 * 10 ** j, 0.0)
        tiers.append(TierSpec(
            f"tier{j}",
            lambda t, j=j: np.full(len(t), j, np.int32),
            price, prompt=PromptSpec(tuple(range(j + 1)), 100, 40)))
    thresholds = [0.5] * (n_tiers - 1)

    def scorer(t, ans):
        return rng.uniform(size=len(t))

    def embed(tokens):
        e = np.zeros((len(tokens), 128), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 128] = 1.0
        return e

    return ServingPipeline(
        tiers=tiers, thresholds=thresholds, scorer=scorer,
        cache=CompletionCache(capacity=4096, threshold=0.99), embed=embed,
        full_prompt_tokens=840, pad_token=-1, batch_size=batch_size)


def bench_pipeline_throughput(n: int = 4096, repeat_frac: float = 0.5):
    """Unified pipeline over a repetition-heavy stream: the cache should
    absorb the repeats and total cost should undercut the baseline."""
    t0 = time.time()
    pipe = _toy_pipeline()
    uniq = int(n * (1 - repeat_frac))
    toks = np.arange(uniq * 8, dtype=np.int32).reshape(uniq, 8)
    toks[:, 0] = np.arange(uniq)
    warm = pipe.serve(toks)                        # populate the cache
    idx = np.random.default_rng(1).integers(0, uniq, size=n)
    t1 = time.time()
    res = pipe.serve(toks[idx])
    serve_s = time.time() - t1
    rows = [{
        "n": n, "qps": n / serve_s,
        "cache_hit_rate": res.cache_hit_rate,
        "tier_counts": res.tier_counts,
        "savings_frac": res.savings_frac,
        "stage_ms": {k: round(v * 1e3, 2) for k, v in res.latency.items()},
    }]
    derived = {
        "claim": "cache absorbs repeats; cost beats top-tier baseline",
        "qps": rows[0]["qps"],
        "hit_rate": res.cache_hit_rate,
        "pass": res.cache_hit_rate > 0.9 and res.savings_frac > 0.5
        and warm.cache_hit_rate == 0.0,
    }
    return rows, derived, time.time() - t0


def bench_bucketed_prefill(n_shapes: int = 12):
    """Bucketed compilation: a sweep of distinct request shapes must
    compile far fewer prefill variants than the per-shape jit cache the
    engine replaced (which compiled once per (seq, max_len))."""
    t0 = time.time()
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params)
    rng = np.random.default_rng(2)
    shapes = [(int(b), int(s)) for b, s in
              zip(rng.integers(1, 9, n_shapes), rng.integers(9, 17, n_shapes))]
    for b, s in shapes:
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(b * 31 + s),
                                             (b, s), 0, cfg.vocab))
        eng.generate(toks, n_new=4)
    stats = eng.compile_stats
    rows = [{"distinct_shapes": len(set(shapes)), "calls": stats["prefill_calls"],
             "compiles": stats["prefill_compiles"]}]
    derived = {
        "claim": "compiles << distinct request shapes",
        "compiles": stats["prefill_compiles"],
        "distinct_shapes": len(set(shapes)),
        "pass": stats["prefill_compiles"] <= 2
        and stats["prefill_calls"] == n_shapes,
    }
    return rows, derived, time.time() - t0
