"""Serving-path benchmarks: the unified 3-strategy pipeline, the
bucketed prefill compilation cache, and the SLO-aware parallel tier
scheduler (serial vs concurrent dispatch, overload behaviour).

Each function returns (rows, derived, secs) like bench_paper — derived
carries a pass/fail claim check so benchmarks double as regressions.

Runnable standalone for the CI bench trajectory:
  PYTHONPATH=src python -m benchmarks.bench_serving --smoke \\
      --json-out BENCH_serving.json
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.core.approx import CompletionCache
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.models import transformer as T
from repro.serving.engine import GenerationEngine
from repro.serving.ingress import ContinuousBatcher, poisson_arrivals
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.resilience import (BreakerConfig, FaultSpec, RetryPolicy,
                                      TierFault)
from repro.serving.sched import SLOConfig, TierScheduler


def _toy_pipeline(n_tiers: int = 3, batch_size: int = 256):
    """Callable tiers (no model training) so the benchmark isolates the
    pipeline's own overhead: cache lookup, compaction, accounting."""
    rng = np.random.default_rng(0)
    tiers = []
    for j in range(n_tiers):
        price = ApiCost(10.0 * 10 ** j, 10.0 * 10 ** j, 0.0)
        tiers.append(TierSpec(
            f"tier{j}",
            lambda t, j=j: np.full(len(t), j, np.int32),
            price, prompt=PromptSpec(tuple(range(j + 1)), 100, 40)))
    thresholds = [0.5] * (n_tiers - 1)

    def scorer(t, ans):
        return rng.uniform(size=len(t))

    def embed(tokens):
        e = np.zeros((len(tokens), 128), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 128] = 1.0
        return e

    return ServingPipeline(
        tiers=tiers, thresholds=thresholds, scorer=scorer,
        cache=CompletionCache(capacity=4096, threshold=0.99), embed=embed,
        full_prompt_tokens=840, pad_token=-1, batch_size=batch_size)


def bench_pipeline_throughput(n: int = 4096, repeat_frac: float = 0.5):
    """Unified pipeline over a repetition-heavy stream: the cache should
    absorb the repeats and total cost should undercut the baseline."""
    t0 = time.time()
    pipe = _toy_pipeline()
    uniq = int(n * (1 - repeat_frac))
    toks = np.arange(uniq * 8, dtype=np.int32).reshape(uniq, 8)
    toks[:, 0] = np.arange(uniq)
    warm = pipe.serve(toks)                        # populate the cache
    idx = np.random.default_rng(1).integers(0, uniq, size=n)
    t1 = time.time()
    res = pipe.serve(toks[idx])
    serve_s = time.time() - t1
    rows = [{
        "n": n, "qps": n / serve_s,
        "cache_hit_rate": res.cache_hit_rate,
        "tier_counts": res.tier_counts,
        "savings_frac": res.savings_frac,
        "stage_ms": {k: round(v * 1e3, 2) for k, v in res.latency.items()},
    }]
    derived = {
        "claim": "cache absorbs repeats; cost beats top-tier baseline",
        "qps": rows[0]["qps"],
        "hit_rate": res.cache_hit_rate,
        "pass": res.cache_hit_rate > 0.9 and res.savings_frac > 0.5
        and warm.cache_hit_rate == 0.0,
    }
    return rows, derived, time.time() - t0


def bench_continuous_batching(n: int = 128, max_chunk: int = 8,
                              span_factor: float = 1.5, repeats: int = 2):
    """Continuous batching vs batch-at-a-time on a mixed-length Poisson
    arrival stream over generation-backed tiers (real decode work).

    Batch-at-a-time must wait for the last arrival before it can serve
    the closed batch; the continuous batcher overlaps tier chunks with
    the arrival window, so its throughput (requests / time-to-drain,
    measured from the first arrival) should come out >= the batch path,
    with far lower per-request p50/p95. Both paths take the best of
    ``repeats`` runs (and a ``gc.collect()`` beforehand) so one stray
    scheduler/GC hiccup doesn't decide the comparison.
    """
    import gc

    t0 = time.time()
    cfg = ARCHS["gemma3-1b"].reduced()
    rng = np.random.default_rng(4)

    def gen_tier(name, seed, price):
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
        eng = GenerationEngine(cfg, params)

        def answer(t, eng=eng):
            return np.asarray(eng.generate(t, n_new=2)[:, 0] % 3)

        return TierSpec(name, answer, price, n_out=2)

    tiers = [gen_tier("small", 0, ApiCost(10.0, 10.0, 0.0)),
             gen_tier("large", 1, ApiCost(100.0, 100.0, 0.0))]

    # mixed-length stream: true lengths 6..16, right-padded to width 16
    width = 16
    toks = rng.integers(1, cfg.vocab, size=(n, width)).astype(np.int32)
    for i, ln in enumerate(rng.integers(6, width + 1, size=n)):
        toks[i, ln:] = 0
    pipe = ServingPipeline(
        tiers=tiers, thresholds=[0.5],
        scorer=lambda t, a: np.where(t[:, 0] % 2 == 0, 0.9, 0.1),
        full_prompt_tokens=200, pad_token=0, batch_size=max_chunk)

    pipe.serve(toks)                               # warm the jit caches
    serve_s = float("inf")
    for _ in range(repeats):
        gc.collect()
        t1 = time.time()
        res_batch = pipe.serve(toks)
        serve_s = min(serve_s, time.time() - t1)

    # Poisson trace spanning ~span_factor x the measured batch serve time
    arrivals = poisson_arrivals(n, n / (span_factor * serve_s), seed=5)
    res_cont = None
    for _ in range(repeats):
        gc.collect()
        r = ContinuousBatcher(pipe, max_chunk=max_chunk).run_trace(
            toks, arrivals)
        if res_cont is None or r.latency["total"] < res_cont.latency["total"]:
            res_cont = r

    t_last = float(arrivals[-1])
    qps_batch = n / (t_last + serve_s)             # wait for trace, then serve
    qps_cont = n / res_cont.latency["total"]
    lat_batch = (t_last + serve_s) - arrivals      # finish-all minus arrival
    lat_cont = res_cont.ingress["request_latency"]
    rows = [{
        "n": n, "trace_span_s": round(t_last, 4),
        "batch_serve_s": round(serve_s, 4),
        "qps_batch": round(qps_batch, 1), "qps_continuous": round(qps_cont, 1),
        "p50_ms_batch": round(float(np.percentile(lat_batch, 50)) * 1e3, 2),
        "p95_ms_batch": round(float(np.percentile(lat_batch, 95)) * 1e3, 2),
        "p50_ms_continuous": round(float(np.percentile(lat_cont, 50)) * 1e3, 2),
        "p95_ms_continuous": round(float(np.percentile(lat_cont, 95)) * 1e3, 2),
        "chunks_per_tier": res_cont.ingress["chunks_per_tier"],
        "chunk_occupancy": round(res_cont.ingress["chunk_occupancy"], 3),
    }]
    answers_match = bool(np.array_equal(res_batch.answers, res_cont.answers)
                         and (res_batch.cost == res_cont.cost).all())
    derived = {
        "claim": "continuous batching >= batch-at-a-time throughput on a "
                 "Poisson stream; answers/costs bit-identical",
        "qps_continuous": rows[0]["qps_continuous"],
        "qps_batch": rows[0]["qps_batch"],
        "p95_ms_continuous": rows[0]["p95_ms_continuous"],
        "p95_ms_batch": rows[0]["p95_ms_batch"],
        "answers_match": answers_match,
        "pass": qps_cont >= qps_batch and answers_match,
    }
    return rows, derived, time.time() - t0


def bench_parallel_tiers(n: int = 128, max_chunk: int = 16,
                         n_new: int = 8, span_factor: float = 0.4,
                         holdback: float = 0.05, repeats: int = 3):
    """Parallel tier scheduler vs the serial continuous batcher on a
    Poisson stream over THREE generation-backed tiers (real decode).

    The serial batcher runs one chunk at a time on one thread, so its
    wall clock is the SUM of every tier's chunks; the scheduler gives
    each tier its own worker, so tier 1/2 decode escalated chunks while
    tier 0 decodes later arrivals — wall clock approaches the busiest
    tier's, and per-tier utilizations overlap (their sum exceeding 1.0
    is the direct evidence of concurrent decode). The cascade routes
    ~25% / ~37% / ~38% of queries to the three tiers, keeping every
    worker loaded. Both paths must stay bit-identical to the
    closed-batch ``serve``. Best-of-``repeats`` per path so a stray GC
    or scheduler hiccup doesn't decide the comparison.
    """
    import gc

    t0 = time.time()
    cfg = ARCHS["gemma3-1b"].reduced()
    rng = np.random.default_rng(7)

    def gen_tier(name, seed, price):
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
        eng = GenerationEngine(cfg, params)

        def answer(t, eng=eng):
            return np.asarray(eng.generate(t, n_new=n_new)[:, 0] % 3)

        return TierSpec(name, answer, price, n_out=n_new)

    tiers = [gen_tier("small", 0, ApiCost(10.0, 10.0, 0.0)),
             gen_tier("mid", 1, ApiCost(30.0, 30.0, 0.0)),
             gen_tier("large", 2, ApiCost(100.0, 100.0, 0.0))]
    width = 32
    toks = rng.integers(1, cfg.vocab, size=(n, width)).astype(np.int32)

    def scorer(t, a):
        # three reliability bands -> tier 0 keeps 25%, tier 1 half the
        # rest, remainder lands on tier 2: all three tiers stay busy
        return np.where(t[:, 0] % 4 == 0, 0.9,
                        np.where(t[:, 0] % 2 == 0, 0.6, 0.1))

    pipe = ServingPipeline(
        tiers=tiers, thresholds=[0.8, 0.5], scorer=scorer,
        full_prompt_tokens=200, pad_token=0, batch_size=max_chunk)

    res_ref = pipe.serve(toks)                     # warm jits + reference
    serve_s = time.time()
    pipe.serve(toks)
    serve_s = time.time() - serve_s
    arrivals = poisson_arrivals(n, n / (span_factor * serve_s), seed=8)

    def best_of(mk_backend):
        best = None
        for _ in range(repeats):
            gc.collect()
            r = mk_backend().run_trace(toks, arrivals)
            if best is None or r.latency["total"] < best.latency["total"]:
                best = r
        return best

    res_ser = best_of(lambda: ContinuousBatcher(pipe, max_chunk=max_chunk,
                                                holdback=holdback))
    res_par = best_of(lambda: TierScheduler(
        pipe, max_chunk=max_chunk, slo=SLOConfig(max_holdback_s=holdback)))

    qps_ser = n / res_ser.latency["total"]
    qps_par = n / res_par.latency["total"]
    match = bool(
        np.array_equal(res_ref.answers, res_par.answers)
        and (res_ref.cost == res_par.cost).all()
        and np.array_equal(res_ser.answers, res_par.answers)
        and (res_ser.cost == res_par.cost).all())
    util = res_par.ingress["tier_utilization"]
    rows = [{
        "n": n, "trace_span_s": round(float(arrivals[-1]), 4),
        "qps_serial": round(qps_ser, 1), "qps_parallel": round(qps_par, 1),
        "speedup": round(qps_par / qps_ser, 3),
        "p95_ms_serial": round(float(np.percentile(
            res_ser.ingress["request_latency"], 95)) * 1e3, 2),
        "p95_ms_parallel": round(float(np.percentile(
            res_par.ingress["request_latency"], 95)) * 1e3, 2),
        "tier_utilization": [round(u, 3) for u in util],
        "utilization_sum": round(float(sum(util)), 3),
        "chunks_per_tier": res_par.ingress["chunks_per_tier"],
    }]
    derived = {
        "claim": "parallel tier workers beat serial dispatch on a 3-tier "
                 "generation Poisson trace; answers/costs bit-identical",
        "speedup": rows[0]["speedup"],
        "qps_parallel": rows[0]["qps_parallel"],
        "qps_serial": rows[0]["qps_serial"],
        "utilization_sum": rows[0]["utilization_sum"],
        "answers_match": match,
        "pass": qps_par > qps_ser and match
        and rows[0]["utilization_sum"] > 1.0,
    }
    return rows, derived, time.time() - t0


def bench_overload_shedding(n: int = 160, max_chunk: int = 8,
                            queue_cap: int = 8, service_ms: float = 15.0):
    """Graceful degradation under a Poisson overload trace: arrivals at
    ~4x the service rate against bounded queues with the ``degrade``
    policy. The stream must complete (no deadlock), queues must respect
    their caps, and every request must be accounted — served, degraded
    to the cheap tier, or shed with the shed count in telemetry.
    """
    t0 = time.time()
    service_s = service_ms / 1e3

    def mk_tier(v):
        def answer(t):
            time.sleep(service_s)              # emulated decode time
            return np.full(len(t), v, np.int32)
        return answer

    pipe = ServingPipeline(
        tiers=[TierSpec("cheap", mk_tier(0), ApiCost(10.0, 10.0, 0.0)),
               TierSpec("pricey", mk_tier(1), ApiCost(100.0, 100.0, 0.0))],
        thresholds=[0.5],
        scorer=lambda t, a: np.where(t[:, 0] % 2 == 0, 0.9, 0.1),
        full_prompt_tokens=200, pad_token=-1, batch_size=max_chunk)
    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)
    # service rate ~ max_chunk / service_s requests/s; arrive at ~4x that
    rate = 4.0 * max_chunk / service_s
    arrivals = poisson_arrivals(n, rate, seed=9)
    slo = SLOConfig(deadline_s=8 * service_s, queue_cap=queue_cap,
                    overload="degrade", max_holdback_s=service_s / 4)
    res = TierScheduler(pipe, max_chunk=max_chunk, slo=slo).run_trace(
        toks, arrivals)

    shed = int((res.stopped_at == -2).sum())
    served = n - shed
    bounded = (res.ingress["queue_peak"][0] <= 2 * queue_cap
               and res.ingress["queue_peak"][1] <= queue_cap)
    rows = [{
        "n": n, "arrival_rate": round(rate, 1),
        "trace_span_s": round(float(arrivals[-1]), 4),
        "drain_s": round(res.latency["total"], 4),
        "served": served, "shed": res.ingress["shed"],
        "degraded": res.ingress["degraded"],
        "queue_peak": res.ingress["queue_peak"],
        "deadline_hit_rate": res.ingress["deadline_hit_rate"],
        "tier_utilization": [round(u, 3) for u in
                             res.ingress["tier_utilization"]],
    }]
    derived = {
        "claim": "overload completes with bounded queues; shed/degraded "
                 "requests accounted in telemetry",
        "shed": shed, "degraded": res.ingress["degraded"],
        "queue_peak": res.ingress["queue_peak"],
        "pass": (res.n == n and bounded
                 and res.ingress["shed"] == shed
                 and shed + served == n
                 and (res.ingress["shed"] > 0
                      or res.ingress["degraded"] > 0)),
    }
    return rows, derived, time.time() - t0


def bench_resilience(n: int = 160, max_chunk: int = 8,
                     service_ms: float = 6.0, error_rate: float = 0.2):
    """Goodput and availability under a seeded fault schedule — the
    fault-tolerant scheduler vs the no-resilience baseline.

    The schedule (deterministic, ``repro.serving.resilience.faults``)
    injects transient errors on the mid tier for the whole trace plus a
    sustained outage from a quarter of the way in through the end of
    the drain (open-ended: the drain time depends on host load, so a
    mid-trace *window* could be missed entirely by a slow run — an
    open-ended outage makes the breaker trip load-independent). Three
    legs:

      * **baseline** — same faults, no retry/breaker: the first
        unabsorbed ``TierFault`` kills the stream (availability ~0);
      * **resilient** — retry + per-tier breakers: the outage trips the
        mid tier's breaker, rows fail over past it, every request
        resolves (availability 1.0, trips visible in telemetry);
      * **zero-fault** — dials on, nothing injected: bit-identical to
        the plain scheduler (the equivalence claim from ISSUE 8).
    """
    t0 = time.time()
    service_s = service_ms / 1e3

    def mk_tier(v):
        def answer(t):
            time.sleep(service_s)              # emulated decode time
            return np.full(len(t), v, np.int32)
        return answer

    def mk_pipe(faults=None, retry=None, breaker=None):
        return ServingPipeline(
            tiers=[TierSpec("cheap", mk_tier(0), ApiCost(10.0, 10.0, 0.0)),
                   TierSpec("mid", mk_tier(1), ApiCost(30.0, 30.0, 0.0)),
                   TierSpec("pricey", mk_tier(2),
                            ApiCost(100.0, 100.0, 0.0))],
            thresholds=[0.8, 0.5],
            scorer=lambda t, a: np.where(t[:, 0] % 4 == 0, 0.9,
                                         np.where(t[:, 0] % 2 == 0,
                                                  0.6, 0.1)),
            full_prompt_tokens=200, pad_token=-1, batch_size=max_chunk,
            faults=faults, retry=retry, breaker=breaker)

    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)
    rate = 2.0 * max_chunk / service_s
    arrivals = poisson_arrivals(n, rate, seed=9)
    span = float(arrivals[-1])
    # transient errors all trace long + a sustained outage on the mid
    # tier from 0.25*span to end-of-drain (stream-clock seconds; the
    # open end keeps the trip independent of how slowly the host drains)
    faults = [None,
              FaultSpec(error_rate=error_rate,
                        outage=(0.25 * span, 1e9), seed=13),
              None]
    retry = RetryPolicy(max_attempts=3, backoff_s=service_s / 8)
    breaker = BreakerConfig(window=8, fail_rate=0.5, min_samples=4,
                            cooldown_s=0.25 * span)
    slo = SLOConfig(max_holdback_s=service_s / 4, retry=retry,
                    breaker=breaker)

    # baseline: same fault schedule, no resilience — the stream dies
    base_served = 0
    base_crashed = False
    try:
        r = TierScheduler(mk_pipe(faults=faults), max_chunk=max_chunk,
                          slo=SLOConfig(max_holdback_s=service_s / 4)
                          ).run_trace(toks, arrivals)
        base_served = int((r.stopped_at >= 0).sum())
    except TierFault:
        base_crashed = True

    # resilient: retry absorbs the transients, the breaker absorbs the
    # outage, failover keeps every request answerable
    res = TierScheduler(mk_pipe(faults=faults, retry=retry,
                                breaker=breaker),
                        max_chunk=max_chunk, slo=slo).run_trace(
        toks, arrivals)
    resolved = int((res.stopped_at != -1).sum())
    served = int((res.stopped_at >= 0).sum())
    rtel = res.ingress["resilience"]

    # zero faults, dials on: bit-identical to the plain scheduler
    ref = TierScheduler(mk_pipe(), max_chunk=max_chunk,
                        slo=SLOConfig(max_holdback_s=service_s / 4)
                        ).run_trace(toks, arrivals)
    idle = TierScheduler(mk_pipe(retry=retry, breaker=breaker),
                         max_chunk=max_chunk, slo=slo).run_trace(
        toks, arrivals)
    identical = bool(np.array_equal(ref.answers, idle.answers)
                     and (ref.cost == idle.cost).all()
                     and np.array_equal(ref.stopped_at, idle.stopped_at))

    rows = [{
        "n": n, "trace_span_s": round(span, 4),
        "drain_s": round(res.latency["total"], 4),
        "availability_baseline": round(base_served / n, 3),
        "baseline_crashed": base_crashed,
        "availability_resilient": round(served / n, 3),
        "goodput_qps": round(served / res.latency["total"], 1),
        "retries": rtel["retries"],
        "backoff_s": round(rtel["backoff_s"], 4),
        "failovers": rtel["failovers"],
        "fallback_answers": rtel["fallback_answers"],
        "shed": rtel["shed"],
        "trips": rtel["trips"], "recoveries": rtel["recoveries"],
        "faults_injected": rtel["faults_injected"],
        "zero_fault_identical": identical,
    }]
    derived = {
        "claim": "seeded faults + outage: resilient scheduler resolves "
                 "every request and trips the breaker; the baseline "
                 "dies; zero-fault dials are bit-identical",
        "availability_resilient": rows[0]["availability_resilient"],
        "availability_baseline": rows[0]["availability_baseline"],
        "trips": rtel["trips"],
        "zero_fault_identical": identical,
        "pass": (resolved == n and rtel["trips"] >= 1
                 and rtel["retries"] > 0 and identical
                 and (base_crashed or base_served < n)),
    }
    return rows, derived, time.time() - t0


def _placement_inner(n: int = 96, max_chunk: int = 16, n_new: int = 8,
                     span_factor: float = 0.4, holdback: float = 0.05,
                     repeats: int = 3) -> dict:
    """The multi-device measurement body: runs inside a forced
    multi-device host (see ``bench_placement_overlap``). Same
    3-generation-tier Poisson trace as ``bench_parallel_tiers``, the
    scheduler once with every tier on the shared default device and
    once with each tier's engine pinned to its own device
    (``sharding.placement``). Returns the comparison dict."""
    import gc

    from repro.sharding.placement import plan_placement

    devices = jax.devices()
    cfg = ARCHS["gemma3-1b"].reduced()
    rng = np.random.default_rng(7)

    def gen_tier(name, seed, price, device=None):
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
        eng = GenerationEngine(cfg, params, device=device)

        def answer(t, eng=eng):
            return np.asarray(eng.generate(t, n_new=n_new)[:, 0] % 3)

        return TierSpec(name, answer, price, n_out=n_new, device=device)

    # traffic share of the scorer below: ~25% stop at tier 0, ~37% at
    # tier 1 — pending counts (the plan_placement signal) are ~n, ~0.75n,
    # ~0.38n, so each tier lands on its own device with 3 tiers x 4 devs
    placement = plan_placement(3, devices=devices,
                               tier_counts=[8, 6, 3])
    specs = [("small", 0, ApiCost(10.0, 10.0, 0.0)),
             ("mid", 1, ApiCost(30.0, 30.0, 0.0)),
             ("large", 2, ApiCost(100.0, 100.0, 0.0))]

    def mk_pipe(pinned: bool):
        tiers = [gen_tier(nm, seed, price,
                          device=placement.for_tier(j) if pinned else None)
                 for j, (nm, seed, price) in enumerate(specs)]
        return ServingPipeline(
            tiers=tiers, thresholds=[0.8, 0.5],
            scorer=lambda t, a: np.where(
                t[:, 0] % 4 == 0, 0.9,
                np.where(t[:, 0] % 2 == 0, 0.6, 0.1)),
            full_prompt_tokens=200, pad_token=0, batch_size=max_chunk)

    width = 32
    toks = rng.integers(1, cfg.vocab, size=(n, width)).astype(np.int32)
    shared, pinned = mk_pipe(False), mk_pipe(True)
    res_ref = shared.serve(toks)                   # warm shared + reference
    res_pin_ref = pinned.serve(toks)               # warm pinned jits
    # warm the PARTIAL-chunk bucket too (the stream ships sub-max_chunk
    # chunks, whose pow2 batch bucket differs from serve's full chunks):
    # otherwise whichever variant hits the XLA compile mid-trace first
    # eats multiple seconds of compile time inside its measured repeat
    shared.serve(toks[: max_chunk // 2])
    pinned.serve(toks[: max_chunk // 2])
    serve_s = time.time()
    shared.serve(toks)
    serve_s = time.time() - serve_s
    arrivals = poisson_arrivals(n, n / (span_factor * serve_s), seed=8)

    # interleave the repeats (shared, pinned, shared, ...) so slow drift
    # in host load lands on both variants equally; best-of per variant
    best = {"shared": None, "pinned": None}
    for _ in range(repeats):
        for label, pipe in (("shared", shared), ("pinned", pinned)):
            gc.collect()
            r = TierScheduler(pipe, max_chunk=max_chunk,
                              slo=SLOConfig(max_holdback_s=holdback)
                              ).run_trace(toks, arrivals)
            if (best[label] is None
                    or r.latency["total"] < best[label].latency["total"]):
                best[label] = r
    res_sh, res_pin = best["shared"], best["pinned"]
    match = bool(
        np.array_equal(res_ref.answers, res_pin_ref.answers)
        and (res_ref.cost == res_pin_ref.cost).all()
        and np.array_equal(res_ref.answers, res_pin.answers)
        and (res_ref.cost == res_pin.cost).all()
        and np.array_equal(res_ref.answers, res_sh.answers)
        and (res_ref.cost == res_sh.cost).all())
    util = res_pin.ingress["tier_utilization"]
    return {
        "n": n, "n_devices": len(devices),
        "trace_span_s": round(float(arrivals[-1]), 4),
        "wall_shared_s": round(res_sh.latency["total"], 4),
        "wall_pinned_s": round(res_pin.latency["total"], 4),
        "qps_shared": round(n / res_sh.latency["total"], 1),
        "qps_pinned": round(n / res_pin.latency["total"], 1),
        "tier_utilization": [round(u, 3) for u in util],
        "utilization_sum": round(float(sum(util)), 3),
        "tier_devices": res_pin.ingress["tier_devices"],
        "distinct_devices": placement.n_distinct,
        "answers_match": match,
    }


def _run_forced_device_inner(inner: str, kwargs: dict, devices: int,
                             timeout: float = 1200) -> dict:
    """Run one ``_INNERS`` measurement body in a subprocess on a FORCED
    ``devices``-device CPU host. The forced device count must land in
    ``XLA_FLAGS`` before jax initializes, so the body cannot run in this
    process (the parent keeps its own device count) — it is re-invoked
    as ``python -m benchmarks.bench_serving --inner NAME`` and returns
    its result dict on stdout as an ``INNER-JSON:`` line."""
    import json as _json
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving",
         "--inner", inner, "--inner-args", _json.dumps(kwargs)],
        env=env, cwd=root, capture_output=True, text=True, timeout=timeout)
    line = next((ln for ln in out.stdout.splitlines()
                 if ln.startswith("INNER-JSON:")), None)
    if line is None:
        raise RuntimeError(f"{inner} subprocess failed:\n"
                           f"{out.stderr[-3000:]}")
    return _json.loads(line[len("INNER-JSON:"):])


def bench_placement_overlap(n: int = 96, max_chunk: int = 16,
                            n_new: int = 8, repeats: int = 3,
                            devices: int = 4):
    """Per-tier device placement vs the shared-device scheduler on the
    3-generation-tier Poisson trace (the PR 3 bench), on a FORCED
    multi-device CPU host (``--xla_force_host_platform_device_count``).

    With every tier's engine pinned to its own device, the tier workers'
    chunks decode on disjoint devices: the per-tier utilization sum must
    show real overlap (> 1.5) and the pinned wall clock must not lose to
    the shared-device scheduler, while answers/costs stay bit-identical
    to the closed-batch ``serve``."""
    t0 = time.time()
    inner = _run_forced_device_inner(
        "placement", dict(n=n, max_chunk=max_chunk, n_new=n_new,
                          repeats=repeats), devices=devices)
    rows = [inner]
    # forced CPU devices timeshare the same physical cores, so pinned
    # can only tie shared here (the structural win needs real devices,
    # where a shared device SERIALIZES concurrently submitted programs);
    # "<=" is therefore judged best-of-repeats with a thread-scheduling
    # jitter allowance, while the utilization sum — the direct evidence
    # of per-device overlap — carries the claim
    wall_tol = 0.05 * inner["wall_shared_s"] + 0.05
    derived = {
        "claim": "per-tier devices: utilization sum > 1.5 and wall-clock "
                 "<= the shared-device scheduler on the 3-tier Poisson "
                 "trace; answers/costs bit-identical",
        "utilization_sum": inner["utilization_sum"],
        "wall_shared_s": inner["wall_shared_s"],
        "wall_pinned_s": inner["wall_pinned_s"],
        "distinct_devices": inner["distinct_devices"],
        "answers_match": inner["answers_match"],
        "pass": (inner["answers_match"]
                 and inner["distinct_devices"] >= 3
                 and inner["utilization_sum"] > 1.5
                 and inner["wall_pinned_s"]
                 <= inner["wall_shared_s"] + wall_tol),
    }
    return rows, derived, time.time() - t0


def _sharded_tiers_inner(batch: int = 64, seq: int = 16, n_new: int = 24,
                         repeats: int = 3, n_periods: int = 6,
                         d_model: int = 256, d_ff: int = 1024) -> dict:
    """The mesh measurement body: runs inside a forced multi-device host
    (see ``bench_sharded_tiers``). A top-tier-sized model with
    homogeneous prefix/suffix (so the fold absorbs the whole depth into
    the scanned stack) decodes one batch on a single device and 2-way
    data-sharded over a (2,1) mesh slice; then the same sharded engine
    is rebuilt at double the depth to pin compile count O(1)."""
    import gc

    from repro.configs.base import LayerSpec, ModelConfig
    from repro.sharding import tier_mesh

    spec = LayerSpec("attn", "dense")

    def mk_cfg(np_):
        return ModelConfig(
            name=f"mesh-bench-{np_}", arch_type="dense",
            n_layers=np_ + 2, d_model=d_model, d_ff=d_ff, vocab=1024,
            n_heads=8, n_kv_heads=4, head_dim=d_model // 8,
            prefix=(spec,), period=(spec,), n_periods=np_,
            suffix=(spec,), max_seq=2048, dtype="float32")

    cfg = mk_cfg(n_periods)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = (np.random.default_rng(3)
            .integers(1, cfg.vocab, (batch, seq)).astype(np.int32))
    mesh2 = tier_mesh.plan_tier_meshes(
        1, mesh_shape=(2, 1), devices=jax.devices()[:2]).for_tier(0)
    eng1 = GenerationEngine(cfg, params, device=jax.devices()[0])
    eng2 = GenerationEngine(cfg, params, mesh=mesh2)
    out1 = np.asarray(eng1.generate(toks, n_new=n_new))    # warm + ref
    out2 = np.asarray(eng2.generate(toks, n_new=n_new))

    def best_of(eng):
        best = float("inf")
        for _ in range(repeats):
            gc.collect()
            t = time.time()
            eng.generate(toks, n_new=n_new)
            best = min(best, time.time() - t)
        return best

    # interleaving matters less than for the trace benches (one call per
    # repeat), but keep best-of so a GC/load spike can't sink a variant
    wall_1dev, wall_2way = best_of(eng1), best_of(eng2)

    # compile count O(1) in depth: a sharded engine on the same bucket
    # at DOUBLE the depth must compile exactly as many prefill variants
    deep = GenerationEngine(mk_cfg(2 * n_periods),
                            T.init_params(jax.random.PRNGKey(1),
                                          mk_cfg(2 * n_periods)),
                            mesh=mesh2)
    deep.generate(toks, n_new=4)
    return {
        "batch": batch, "n_new": n_new, "n_layers": cfg.n_layers,
        "host_cores": os.cpu_count() or 1,
        "n_devices": len(jax.devices()),
        "mesh": tier_mesh.mesh_desc(mesh2),
        "wall_1dev_s": round(wall_1dev, 4),
        "wall_2way_s": round(wall_2way, 4),
        "tok_s_1dev": round(batch * n_new / wall_1dev, 1),
        "tok_s_2way": round(batch * n_new / wall_2way, 1),
        "speedup": round(wall_1dev / wall_2way, 3),
        "answers_match": bool(np.array_equal(out1, out2)),
        "prefill_compiles": eng2.compile_stats["prefill_compiles"],
        "prefill_compiles_2x_depth": deep.compile_stats["prefill_compiles"],
        "compile_o1": (eng2.compile_stats["prefill_compiles"]
                       == deep.compile_stats["prefill_compiles"] == 1),
    }


def bench_sharded_tiers(batch: int = 64, seq: int = 16, n_new: int = 24,
                        repeats: int = 3, n_periods: int = 6,
                        devices: int = 8):
    """2-way data-sharded tier engine vs the same engine on one device,
    at equal batch, on a FORCED 8-device CPU host (``sharding.tier_mesh``
    mesh slices + pjit engines).

    The claims that hold on ANY host: the sharded engine's answers are
    bit-identical to the single-device engine's, and compile count is
    O(1) in depth (doubling the scanned stack adds zero prefill
    compiles). The throughput claim needs hardware: forced CPU devices
    timeshare the host's physical cores, so on a single-core runner the
    2-way engine pays the FSDP all-gathers with no second core to win
    back — ``speedup`` is reported as trend data there and only gated
    when the host has >= 2 cores."""
    t0 = time.time()
    inner = _run_forced_device_inner(
        "sharded_tiers",
        dict(batch=batch, seq=seq, n_new=n_new, repeats=repeats,
             n_periods=n_periods), devices=devices)
    multi_core = inner["host_cores"] >= 2
    derived = {
        "claim": "2-way-sharded decode beats 1-device at equal batch "
                 "(gated on >= 2 host cores), answers bit-identical, "
                 "prefill compiles O(1) in depth",
        "speedup": inner["speedup"],
        "tok_s_2way": inner["tok_s_2way"],
        "host_cores": inner["host_cores"],
        "answers_match": inner["answers_match"],
        "compile_o1": inner["compile_o1"],
        "pass": (inner["answers_match"] and inner["compile_o1"]
                 and (inner["speedup"] > 1.0 if multi_core else True)),
    }
    return [inner], derived, time.time() - t0


class _OracleRouter:
    """Duck-typed stand-in for a trained ``ServingStrategy``: entry is
    always tier 0 (so the cascade itself is unchanged vs the
    non-speculative reference) but the per-tier accept probabilities are
    an *oracle* for the bench's scorer — odd-first-token rows are
    predicted-reject at tier 0. This isolates the speculation machinery
    from router training noise: the candidate set is exactly the rows
    that really escalate."""

    governor = None

    def __init__(self):
        self.router = self              # scheduler checks strat.router

    def route(self, emb):
        hard = (emb[:, 0].astype(np.int64) % 2) == 1
        probs = np.stack([np.where(hard, 0.05, 0.9),
                          np.ones(len(emb))], axis=1)
        return np.zeros(len(emb), np.int64), probs

    def thresholds(self, base):
        return base

    def observe_request(self, cost, **kw):
        pass

    def snapshot(self, m):
        return None


def _speculation_inner(n: int = 64, n_new: int = 8, repeats: int = 3,
                       holdback: float = 0.005) -> dict:
    """The speculation measurement body: runs inside a forced 2-device
    host (see ``bench_speculation``). Two generation tiers on disjoint
    devices, one burst arriving at t=0 as a single chunk: without
    speculation tier 1 waits for tier 0's full decode before starting on
    the escalated (predicted-hard) rows; with it, tier 1 pre-invokes
    them concurrently and commits on the real accept mask — so the hard
    rows' latency approaches the top-tier-only baseline while answers
    and charged cost stay bit-identical."""
    import gc

    devices = jax.devices()
    cfg = ARCHS["gemma3-1b"].reduced()
    rng = np.random.default_rng(11)

    def gen_tier(name, seed, price, device):
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
        eng = GenerationEngine(cfg, params, device=device)

        def answer(t, eng=eng):
            return np.asarray(eng.generate(t, n_new=n_new)[:, 0] % 3)

        return TierSpec(name, answer, price, n_out=n_new,
                        device=device), eng

    t_small, _ = gen_tier("small", 0, ApiCost(10.0, 10.0, 0.0), devices[0])
    t_large, eng_large = gen_tier("large", 1, ApiCost(100.0, 100.0, 0.0),
                                  devices[-1])

    def scorer(t, a):
        # odd-first-token rows escalate (the oracle's predicted-hard set)
        return np.where(t[:, 0] % 2 == 1, 0.1, 0.9)

    def embed(tokens):                  # the router routes on this
        return tokens[:, :2].astype(np.float32)

    def mk_pipe(speculate):
        return ServingPipeline(
            tiers=[t_small, t_large], thresholds=[0.5], scorer=scorer,
            embed=embed, full_prompt_tokens=200, pad_token=0,
            batch_size=n, strategy=_OracleRouter(), speculate=speculate)

    def slo(speculate):
        return SLOConfig(max_holdback_s=holdback, speculate=speculate,
                         spec_depth=1, spec_bar=0.5, spec_idle_frac=None)

    toks = rng.integers(1, cfg.vocab, size=(n, 16)).astype(np.int32)
    hard = (toks[:, 0] % 2) == 1
    pipes = {"nospec": mk_pipe(False), "spec": mk_pipe(True)}
    for label, pipe in pipes.items():   # warm every jit bucket
        TierScheduler(pipe, max_chunk=n, slo=slo(label == "spec")
                      ).run_trace(toks)
    hard_toks = toks[hard]
    eng_large.generate(hard_toks, n_new=n_new)      # warm hard-row bucket

    best = {"nospec": None, "spec": None}
    top_only = float("inf")
    for _ in range(repeats):
        for label, pipe in pipes.items():
            gc.collect()
            r = TierScheduler(pipe, max_chunk=n, slo=slo(label == "spec")
                              ).run_trace(toks)
            if (best[label] is None
                    or r.latency["total"] < best[label].latency["total"]):
                best[label] = r
        gc.collect()
        t0 = time.time()
        eng_large.generate(hard_toks, n_new=n_new)
        top_only = min(top_only, time.time() - t0)

    ref, res = best["nospec"], best["spec"]
    spec = res.ingress["speculation"]

    def hard_pct(r, q):
        lat = np.asarray(r.ingress["request_latency"])[hard]
        return float(np.percentile(lat, q))

    return {
        "n": n, "n_hard": int(hard.sum()), "n_new": n_new,
        "n_devices": len(devices),
        "host_cores": os.cpu_count() or 1,
        "wall_nospec_s": round(ref.latency["total"], 4),
        "wall_spec_s": round(res.latency["total"], 4),
        "hard_p50_nospec_s": round(hard_pct(ref, 50), 4),
        "hard_p50_spec_s": round(hard_pct(res, 50), 4),
        "hard_p99_nospec_s": round(hard_pct(ref, 99), 4),
        "hard_p99_spec_s": round(hard_pct(res, 99), 4),
        "top_tier_only_s": round(top_only, 4),
        "issued": spec["issued"], "committed": spec["committed"],
        "cancelled": spec["cancelled"],
        "wasted_s": round(spec["wasted_s"], 4),
        "overlap_frac": [round(o, 3) for o in spec["overlap_frac"]],
        "answers_match": bool(
            np.array_equal(ref.answers, res.answers)
            and (ref.cost == res.cost).all()
            and np.array_equal(ref.stopped_at, res.stopped_at)
            and list(ref.tier_counts) == list(res.tier_counts)),
        "cost_total": float(res.cost.sum()),
        "cost_total_nospec": float(ref.cost.sum()),
    }


def bench_speculation(n: int = 64, n_new: int = 8, repeats: int = 3,
                      devices: int = 2):
    """Speculative cascade execution vs the plain scheduler on a 2-tier
    burst, tiers pinned to disjoint FORCED CPU devices.

    The claims that hold on ANY host: answers, charged cost,
    ``stopped_at`` and ``tier_counts`` are bit-identical to the
    non-speculative scheduler (speculation only moves wall-clock) and
    speculation actually engages (committed > 0). The latency claim
    needs parallel hardware: forced CPU devices timeshare the host's
    cores, so predicted-hard p50 improving toward the top-tier-only
    baseline is only gated when the host has >= 2 cores and reported as
    trend data otherwise."""
    t0 = time.time()
    inner = _run_forced_device_inner(
        "speculation", dict(n=n, n_new=n_new, repeats=repeats),
        devices=devices)
    multi_core = inner["host_cores"] >= 2
    derived = {
        "claim": "speculative prefill: predicted-hard p50 below the "
                 "non-speculative scheduler, approaching top-tier-only "
                 "(gated on >= 2 host cores), at bit-identical answers "
                 "and charged cost, with committed speculations > 0",
        "hard_p50_nospec_s": inner["hard_p50_nospec_s"],
        "hard_p50_spec_s": inner["hard_p50_spec_s"],
        "hard_p99_spec_s": inner["hard_p99_spec_s"],
        "top_tier_only_s": inner["top_tier_only_s"],
        "committed": inner["committed"],
        "cancelled": inner["cancelled"],
        "host_cores": inner["host_cores"],
        "answers_match": inner["answers_match"],
        "pass": (inner["answers_match"]
                 and inner["committed"] > 0
                 and inner["cost_total"] == inner["cost_total_nospec"]
                 and (inner["hard_p50_spec_s"] < inner["hard_p50_nospec_s"]
                      if multi_core else True)),
    }
    return [inner], derived, time.time() - t0


def bench_bucketed_prefill(n_shapes: int = 12):
    """Bucketed compilation: a sweep of distinct request shapes must
    compile far fewer prefill variants than the per-shape jit cache the
    engine replaced (which compiled once per (seq, max_len))."""
    t0 = time.time()
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params)
    rng = np.random.default_rng(2)
    shapes = [(int(b), int(s)) for b, s in
              zip(rng.integers(1, 9, n_shapes), rng.integers(9, 17, n_shapes))]
    for b, s in shapes:
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(b * 31 + s),
                                             (b, s), 0, cfg.vocab))
        eng.generate(toks, n_new=4)
    stats = eng.compile_stats
    rows = [{"distinct_shapes": len(set(shapes)), "calls": stats["prefill_calls"],
             "compiles": stats["prefill_compiles"]}]
    derived = {
        "claim": "compiles << distinct request shapes",
        "compiles": stats["prefill_compiles"],
        "distinct_shapes": len(set(shapes)),
        "pass": stats["prefill_compiles"] <= 2
        and stats["prefill_calls"] == n_shapes,
    }
    return rows, derived, time.time() - t0


# -- standalone driver (CI bench trajectory) --------------------------------

#: (name, fn, smoke-mode kwargs) — smoke shrinks sizes so the sweep fits
#: a CPU CI runner in a couple of minutes
BENCHES = [
    ("serving_pipeline", bench_pipeline_throughput, {"n": 1024}),
    ("continuous_batching", bench_continuous_batching,
     {"n": 96, "repeats": 1}),
    ("parallel_tiers", bench_parallel_tiers, {"n": 96, "repeats": 2}),
    ("overload_shedding", bench_overload_shedding,
     {"n": 64, "service_ms": 10.0}),
    ("resilience", bench_resilience, {"n": 96, "service_ms": 4.0}),
    ("bucketed_prefill", bench_bucketed_prefill, {"n_shapes": 6}),
    ("placement_overlap", bench_placement_overlap,
     {"n": 64, "repeats": 3}),
    ("sharded_tiers", bench_sharded_tiers,
     {"batch": 32, "n_new": 8, "repeats": 2, "n_periods": 4}),
    ("speculation", bench_speculation,
     {"n": 32, "n_new": 6, "repeats": 2}),
]

#: measurement bodies re-invoked by _run_forced_device_inner inside a
#: forced multi-device subprocess (--inner NAME --inner-args JSON)
_INNERS = {
    "placement": _placement_inner,
    "sharded_tiers": _sharded_tiers_inner,
    "speculation": _speculation_inner,
}


def main(argv=None) -> int:
    """Run the serving benches and write one JSON record — CI runs this
    with ``--smoke`` and uploads the file, so the bench trajectory
    (qps, speedups, shed counts per commit) accumulates as artifacts.
    Claim-check failures are reported in the JSON but only fail the
    process in full (non-smoke) mode: smoke sizes on shared CI runners
    are for trend lines, not for gating."""
    import argparse
    import json
    import platform
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI: trend data, non-gating")
    ap.add_argument("--json-out", default="BENCH_serving.json")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    # internal: a multi-device measurement body, re-invoked by
    # _run_forced_device_inner inside a forced multi-device subprocess
    ap.add_argument("--inner", default=None, choices=sorted(_INNERS),
                    help=argparse.SUPPRESS)
    ap.add_argument("--inner-args", default="{}",
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.inner is not None:
        inner = _INNERS[args.inner](**json.loads(args.inner_args))
        print("INNER-JSON:" + json.dumps(inner))
        return 0

    only = set(args.only.split(",")) if args.only else None
    results = {"smoke": args.smoke,
               "platform": platform.platform(),
               "benches": {}}
    failures = []
    for name, fn, smoke_kw in BENCHES:
        if only is not None and name not in only:
            continue
        rows, derived, secs = fn(**(smoke_kw if args.smoke else {}))
        results["benches"][name] = {"rows": rows, "derived": derived,
                                    "secs": round(secs, 3)}
        print(f"{name},{secs * 1e6:.1f},{json.dumps(derived, default=str)}")
        if not derived.get("pass", True):
            failures.append(name)

    with open(args.json_out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\n# wrote {args.json_out}; "
          f"{len(failures)} claim-check failures: {failures or 'none'}")
    return 0 if (args.smoke or not failures) else 1


if __name__ == "__main__":
    raise SystemExit(main())
