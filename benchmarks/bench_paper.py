"""One benchmark per paper table/figure (DESIGN.md §6).

Each function returns (rows, derived) where rows are CSV-able dicts and
derived is the headline number validated against the paper's claim.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.cascade import evaluate_offline
from repro.core.cost import TABLE1
from repro.core.router import RouterConfig, cost_to_match, frontier, learn_cascade
from repro.core.simulate import (DATASETS, MarketData, mpi_matrix,
                                 simulate_market, simulate_scores)

RCFG = RouterConfig(top_lists=30, sample=512, grid=24)

PAPER_TABLE3 = {  # best-LLM total $, FrugalGPT total $, savings %
    "HEADLINES": (33.1, 0.6, 98.3),
    "OVERRULING": (9.7, 2.6, 73.3),
    "COQA": (72.5, 29.6, 59.2),
}


def _split(data: MarketData, scores, seed=2):
    from repro.core.simulate import split_market
    return split_market(data, scores, 0.5, seed)


def bench_table1_costs():
    """Table 1: heterogeneous pricing, 2-OOM spread on 10M input tokens."""
    t0 = time.time()
    rows = []
    for name, api in TABLE1.items():
        rows.append({"api": name,
                     "usd_10m_input": float(api.query_cost(1e7, 0)),
                     "usd_10m_output": float(api.query_cost(0, 1e7)),
                     "fixed": api.per_request})
    nonzero = [r["usd_10m_input"] for r in rows if r["usd_10m_input"] > 0]
    spread = max(nonzero) / min(nonzero)
    derived = {"price_spread_x": spread, "claim": ">=100x (2 OOM)",
               "pass": spread >= 100}
    return rows, derived, time.time() - t0


def bench_fig4_mpi():
    """Fig 4: cheap LLMs fix ~6% (HEADLINES) / 13% (COQA) of the best
    LLM's errors."""
    t0 = time.time()
    rows = []
    derived = {}
    for ds in DATASETS:
        data = simulate_market(ds, seed=0)
        mpi = np.asarray(mpi_matrix(data.correct))
        best = int(np.asarray(data.accuracy()).argmax())
        cheap_fix = float(mpi[best].max())
        rows.append({"dataset": ds, "best": data.names[best],
                     "max_mpi_over_best": cheap_fix})
        derived[ds] = cheap_fix
    derived["claim"] = "MPI over best LLM ~6-13%"
    derived["pass"] = all(0.02 < v < 0.25 for k, v in derived.items()
                          if k in DATASETS)
    return rows, derived, time.time() - t0


def bench_table3_savings():
    """Table 3: cost to match the best individual LLM's accuracy."""
    t0 = time.time()
    rows = []
    all_pass = True
    for ds, (paper_best, paper_frugal, paper_sav) in PAPER_TABLE3.items():
        data = simulate_market(ds, seed=0)
        scores = simulate_scores(data, seed=1)
        tr, te, str_, ste = _split(data, scores)
        accs = np.asarray(data.accuracy())
        best = int(accs.argmax())
        best_avg = float(data.cost[:, best].mean())
        m = cost_to_match(tr, str_, te, ste, float(accs[best]), RCFG)
        sav = 100 * (1 - m["avg_cost"] / best_avg) if m else 0.0
        ok = m is not None and sav >= 50.0      # paper range: 59-98%
        all_pass &= ok
        rows.append({
            "dataset": ds, "best_llm": data.names[best],
            "best_total_usd": best_avg * data.n,
            "frugal_total_usd": m["avg_cost"] * data.n if m else float("nan"),
            "savings_pct": sav, "paper_savings_pct": paper_sav,
            "acc": m["acc"] if m else 0.0, "best_acc": float(accs[best]),
            "cascade": m["cascade"].describe(data.names) if m else "-",
        })
    derived = {"claim": "50-98% cost reduction at matched accuracy",
               "pass": all_pass}
    return rows, derived, time.time() - t0


def bench_fig3_case_study():
    """Fig 3: HEADLINES, budget = 1/5 of GPT-4's cost -> cost down ~80%,
    accuracy >= GPT-4."""
    t0 = time.time()
    data = simulate_market("HEADLINES", seed=0)
    scores = simulate_scores(data, seed=1)
    tr, te, str_, ste = _split(data, scores)
    g4 = data.names.index("GPT-4")
    g4_avg = float(data.cost[:, g4].mean())
    g4_acc = float(data.correct[:, g4].mean())
    cas, _ = learn_cascade(tr, str_, g4_avg / 5.0, RCFG)
    m = evaluate_offline(cas, te, ste)
    rows = [{
        "cascade": cas.describe(data.names),
        "acc": m["acc"], "gpt4_acc": g4_acc,
        "cost_reduction_pct": 100 * (1 - m["avg_cost"] / g4_avg),
        "acc_gain_pt": 100 * (m["acc"] - g4_acc),
        "stop_fracs": m["stop_fracs"],
    }]
    derived = {"claim": "~80% cost cut AND accuracy >= GPT-4 at b=cost/5",
               "cost_reduction_pct": rows[0]["cost_reduction_pct"],
               "acc_gain_pt": rows[0]["acc_gain_pt"],
               "pass": rows[0]["cost_reduction_pct"] >= 70
               and m["acc"] >= g4_acc - 0.002}
    return rows, derived, time.time() - t0


def bench_fig5_tradeoff():
    """Fig 5: smooth accuracy-cost frontier; up to ~5% gain at equal cost."""
    t0 = time.time()
    rows = []
    derived = {}
    ok = True
    for ds in DATASETS:
        data = simulate_market(ds, seed=0)
        scores = simulate_scores(data, seed=1)
        tr, te, str_, ste = _split(data, scores)
        accs = np.asarray(data.accuracy())
        best = int(accs.argmax())
        best_avg = float(data.cost[:, best].mean())
        budgets = np.geomspace(best_avg / 100, best_avg, 7)
        pts = frontier(tr, str_, budgets, RCFG)
        test_pts = [evaluate_offline(p["cascade"], te, ste) for p in pts]
        for b, p in zip(budgets, test_pts):
            rows.append({"dataset": ds, "budget_avg_usd": float(b),
                         "acc": p["acc"], "avg_cost": p["avg_cost"]})
        gain = 100 * (test_pts[-1]["acc"] - accs[best])
        derived[ds + "_equal_cost_gain_pt"] = gain
        # frontier should be roughly monotone and end >= best individual
        accs_curve = [p["acc"] for p in test_pts]
        ok &= accs_curve[-1] >= accs[best] - 0.01
        ok &= gain > 0
    derived["claim"] = "positive accuracy gain at the best LLM's cost"
    derived["pass"] = ok
    return rows, derived, time.time() - t0
