"""Benchmark driver: one function per paper table/figure + kernels +
roofline. Prints ``name,us_per_call,derived`` CSV lines and a summary.

Usage: PYTHONPATH=src python -m benchmarks.run [--skip-roofline]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{json.dumps(derived, default=str)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_kernels, bench_paper, bench_serving,
                            bench_strategy)

    t_all = time.time()
    results = {}
    failures = []

    paper_benches = [
        ("table1_costs", bench_paper.bench_table1_costs),
        ("fig3_case_study", bench_paper.bench_fig3_case_study),
        ("fig4_mpi", bench_paper.bench_fig4_mpi),
        ("table3_savings", bench_paper.bench_table3_savings),
        ("fig5_tradeoff", bench_paper.bench_fig5_tradeoff),
        ("serving_pipeline", bench_serving.bench_pipeline_throughput),
        ("continuous_batching", bench_serving.bench_continuous_batching),
        ("parallel_tiers", bench_serving.bench_parallel_tiers),
        ("overload_shedding", bench_serving.bench_overload_shedding),
        ("bucketed_prefill", bench_serving.bench_bucketed_prefill),
        ("placement_overlap", bench_serving.bench_placement_overlap),
        ("contextual_routing", bench_strategy.bench_contextual_routing),
        ("budget_governor", bench_strategy.bench_budget_governor),
        ("guarantee", bench_strategy.bench_guarantee),
    ]
    for name, fn in paper_benches:
        rows, derived, secs = fn()
        results[name] = {"rows": rows, "derived": derived}
        _emit(name, secs * 1e6, derived)
        if not derived.get("pass", True):
            failures.append(name)

    for fn in (bench_kernels.bench_flash_attention,
               bench_kernels.bench_decode_attention,
               bench_kernels.bench_ssd_scan,
               bench_kernels.bench_moe_gmm):
        rows = fn()
        for r in rows:
            name = r.pop("kernel")
            us = r.pop("us_per_call")
            results[f"kernel_{name}"] = {"us": us, **r}
            _emit(f"kernel_{name}", us, r)

    if not args.skip_roofline and os.path.exists("dryrun_results.json"):
        from repro.launch import roofline
        rows = roofline.analyze("dryrun_results.json")
        dom = {}
        for r in rows:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        results["roofline"] = {"n_pairs": len(rows), "dominant_counts": dom}
        _emit("roofline_summary", 0.0,
              {"pairs": len(rows), "dominant": dom})

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=1, default=str)

    print(f"\n# total {time.time()-t_all:.1f}s; "
          f"{len(failures)} claim-check failures: {failures or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
