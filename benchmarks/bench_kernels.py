"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock numbers characterize the *reference* path only; the structural
numbers (FLOPs, VMEM working set) are the TPU-relevant derived columns.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def bench_flash_attention():
    from repro.kernels.flash_attention.ops import mha
    key = jax.random.PRNGKey(0)
    b, s, h, kvh, d = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(key, (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(key, (b, s, kvh, d), jnp.float32)
    us = _time(lambda *a: mha(*a, causal=True, interpret=True, bq=128,
                              bk=128), q, k, v)
    flops = 4 * b * h * s * s * d / 2
    vmem_kib = (128 * d * 4 * 3 + 128 * 128 * 4) / 1024
    return [{"kernel": "flash_attention", "us_per_call": us,
             "flops": flops, "vmem_tile_kib": vmem_kib}]


def bench_decode_attention():
    from repro.kernels.decode_attention.ops import gqa_decode
    key = jax.random.PRNGKey(0)
    b, s, h, kvh, d = 2, 2048, 8, 2, 128
    q = jax.random.normal(key, (b, 1, h, d), jnp.float32)
    k = jax.random.normal(key, (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(key, (b, s, kvh, d), jnp.float32)
    us = _time(lambda *a: gqa_decode(*a, jnp.int32(s), bk=512,
                                     interpret=True), q, k, v)
    bytes_hbm = 2 * b * s * kvh * d * 4
    return [{"kernel": "decode_attention", "us_per_call": us,
             "cache_bytes": bytes_hbm,
             "arithmetic_intensity": (4 * b * h * s * d) / bytes_hbm}]


def bench_ssd_scan():
    from repro.kernels.ssd_scan.kernel import ssd_scan
    key = jax.random.PRNGKey(0)
    b, s, h, p, n = 1, 512, 4, 64, 32
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    a = -jnp.ones((h,))
    bm = jax.random.normal(key, (b, s, n))
    cm = jax.random.normal(key, (b, s, n))
    us = _time(lambda *args: ssd_scan(*args, chunk=128, interpret=True),
               x, dt, a, bm, cm)
    chunk_flops = 2 * 128 * 128 * (n + p)
    return [{"kernel": "ssd_scan", "us_per_call": us,
             "chunk_flops": chunk_flops,
             "state_vmem_kib": p * n * 4 / 1024}]


def bench_moe_gmm():
    from repro.kernels.moe_gmm.kernel import gmm
    key = jax.random.PRNGKey(0)
    e, c, k, f = 8, 256, 256, 512
    x = jax.random.normal(key, (e, c, k))
    w = jax.random.normal(key, (e, k, f))
    us = _time(lambda *a: gmm(*a, interpret=True), x, w)
    return [{"kernel": "moe_gmm", "us_per_call": us,
             "flops": 2 * e * c * k * f,
             "mxu_tile": "128x128x128"}]
