"""The generation scoring function g(q, a) — DistilBERT-analogue in JAX.

A small transformer encoder with a sigmoid regression head, trained with
BCE on (query ++ SEP ++ answer) -> correct, exactly the paper's recipe
("a simple regression model that learns whether a generation is correct
from the query and a generated answer").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.models.classifier import (classifier_logits, encoder_config,
                                     init_classifier, jitted_logits)
from repro.training.optim import OptConfig, adamw_update, init_opt_state

SCORER_CFG = encoder_config("scorer-distilbert", n_layers=4, d_model=128,
                            n_heads=4, d_ff=256, max_seq=256)


def train_scorer(queries: np.ndarray, answers: np.ndarray,
                 correct: np.ndarray, *, steps: int = 400, batch: int = 128,
                 seed: int = 0, log_every: int = 0):
    """queries (n, L) tokens; answers (n,) class ids; correct (n,) 0/1."""
    cfg = SCORER_CFG
    pairs = synthetic.append_answer(queries, answers)
    key = jax.random.PRNGKey(seed)
    params = init_classifier(key, cfg, 1)
    opt = OptConfig(lr=1e-3, warmup=20, total_steps=steps)
    state = init_opt_state(params)
    n = pairs.shape[0]
    rng = np.random.default_rng(seed)

    @jax.jit
    def step_fn(params, state, toks, y):
        def loss_fn(p):
            logit = classifier_logits(p, toks, cfg)[:, 0]
            loss = jnp.mean(jnp.maximum(logit, 0) - logit * y
                            + jnp.log1p(jnp.exp(-jnp.abs(logit))))
            return loss, jax.nn.sigmoid(logit)
        (loss, s), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, state, om = adamw_update(opt, params, grads, state)
        return params, state, loss

    for i in range(steps):
        idx = rng.choice(n, size=min(batch, n), replace=False)
        params, state, loss = step_fn(params, state, jnp.asarray(pairs[idx]),
                                      jnp.asarray(correct[idx], jnp.float32))
        if log_every and (i + 1) % log_every == 0:
            print(f"  scorer step {i+1}: bce={float(loss):.3f}")
    return params


def score(params, queries: np.ndarray, answers: np.ndarray,
          batch: int = 512) -> np.ndarray:
    """g(q, a) in [0,1] for each (query, answer) pair."""
    cfg = SCORER_CFG
    pairs = synthetic.append_answer(np.asarray(queries), np.asarray(answers))
    fn = jitted_logits(cfg)      # cached: scoring runs per serving batch
    out = []
    for i in range(0, pairs.shape[0], batch):
        logit = fn(params, jnp.asarray(pairs[i:i + batch]))[:, 0]
        out.append(np.asarray(jax.nn.sigmoid(logit)))
    return np.concatenate(out)


def auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """ROC-AUC via rank statistic."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels > 0.5
    n1, n0 = pos.sum(), (~pos).sum()
    if n1 == 0 or n0 == 0:
        return 0.5
    return float((ranks[pos].sum() - n1 * (n1 + 1) / 2) / (n1 * n0))
