from repro.core.cascade import (  # noqa: F401
    Cascade,
    CascadeTier,
    evaluate_offline,
    execute_cascade,
    replay_tiers,
    run_online,
)
from repro.core.cost import TABLE1, ApiCost  # noqa: F401
from repro.core.router import RouterConfig, cost_to_match, frontier, learn_cascade  # noqa: F401
from repro.core.simulate import (  # noqa: F401
    DATASETS,
    MarketData,
    mpi_matrix,
    simulate_market,
    simulate_scores,
)
