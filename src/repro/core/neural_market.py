"""Neural LLM marketplace: real JAX models of different capacity as the
"APIs". This is the end-to-end path — the cascade runs actual forward
passes through tier models (the IRT path in ``simulate.py`` reproduces the
paper's numbers at scale; this path proves the system runs for real).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.cost import TABLE1, ApiCost
from repro.core.simulate import MarketData
from repro.data import synthetic
from repro.models.classifier import encoder_config, jitted_logits
from repro.training.train_loop import train_classifier

# tier name -> (encoder size, train steps, Table-1 price analogue)
TIERS = {
    "GPT-J":   dict(n_layers=1, d_model=32, steps=60, price="GPT-J"),
    "J1-L":    dict(n_layers=2, d_model=48, steps=120, price="J1-L"),
    "GPT-C":   dict(n_layers=2, d_model=64, steps=200, price="GPT-C"),
    "ChatGPT": dict(n_layers=3, d_model=96, steps=320, price="ChatGPT"),
    "GPT-3":   dict(n_layers=4, d_model=128, steps=480, price="GPT-3"),
    "GPT-4":   dict(n_layers=4, d_model=160, steps=800, price="GPT-4"),
}


@dataclasses.dataclass
class NeuralAPI:
    name: str
    cfg: object
    params: dict
    price: ApiCost

    def answer(self, tokens: np.ndarray, batch: int = 512) -> np.ndarray:
        fn = jitted_logits(self.cfg)   # cached: called per serving chunk
        out = []
        for i in range(0, tokens.shape[0], batch):
            logits = fn(self.params, jnp.asarray(tokens[i:i + batch]))
            out.append(np.asarray(jnp.argmax(logits, -1)))
        return np.concatenate(out)

    def query_cost(self, tokens: np.ndarray) -> np.ndarray:
        n_in = (tokens != synthetic.PAD).sum(-1)
        return np.asarray(self.price.query_cost(n_in, np.ones_like(n_in)))


def tier_subset(names, steps_cap: int | None = None) -> dict:
    """A copy of TIERS restricted to ``names`` (order preserved), with
    train steps optionally capped — lets callers build small marketplaces
    without mutating the module-level registry."""
    out = {}
    for name in names:
        if name not in TIERS:
            raise KeyError(f"unknown tier {name!r}; available: "
                           f"{list(TIERS)}")
        spec = dict(TIERS[name])
        if steps_cap is not None:
            spec["steps"] = min(spec["steps"], steps_cap)
        out[name] = spec
    return out


def train_marketplace(task: str, *, seq_len: int = 64, seed: int = 0,
                      verbose: bool = False,
                      tiers: dict | None = None) -> list[NeuralAPI]:
    """Train the tier models on the synthetic task.

    ``tiers``: a TIERS-style dict (see ``tier_subset``); defaults to the
    full module-level registry.
    """
    n_classes = synthetic.N_CLASSES[task]
    apis = []
    for i, (name, spec) in enumerate((tiers or TIERS).items()):
        cfg = encoder_config(f"api-{name}", n_layers=spec["n_layers"],
                             d_model=spec["d_model"],
                             n_heads=max(2, spec["d_model"] // 32),
                             d_ff=2 * spec["d_model"], max_seq=seq_len + 4)
        if verbose:
            print(f"training tier {name} ({spec['n_layers']}L "
                  f"d={spec['d_model']}, {spec['steps']} steps)")
        params, _ = train_classifier(cfg, n_classes, task=task,
                                     steps=spec["steps"], seq_len=seq_len,
                                     seed=seed + i)
        apis.append(NeuralAPI(name, cfg, params, TABLE1[spec["price"]]))
    return apis


def collect_market_data(apis: list[NeuralAPI], tokens: np.ndarray,
                        labels: np.ndarray) -> tuple[MarketData, np.ndarray]:
    """Query every API on every example (the paper's offline collection).

    Returns (MarketData, answers (n, K))."""
    n = tokens.shape[0]
    k = len(apis)
    correct = np.zeros((n, k), np.float32)
    cost = np.zeros((n, k), np.float32)
    answers = np.zeros((n, k), np.int32)
    for j, api in enumerate(apis):
        ans = api.answer(tokens)
        answers[:, j] = ans
        correct[:, j] = (ans == labels).astype(np.float32)
        cost[:, j] = api.query_cost(tokens)
    n_in = (tokens != synthetic.PAD).sum(-1).astype(np.int32)
    data = MarketData([a.name for a in apis], jnp.asarray(correct),
                      jnp.asarray(cost), jnp.asarray(n_in),
                      jnp.asarray(np.ones(n, np.int32)),
                      jnp.asarray(np.zeros(n, np.float32)))
    return data, answers
