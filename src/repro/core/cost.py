"""The paper's 3-term LLM API cost model (Table 1, §2).

c_i(p) = c2 * ||f_i(p)|| + c1 * ||p|| + c0
       = output-token cost + input-token cost + fixed per-request cost.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ApiCost:
    """Prices in USD. input/output rates are per 10M tokens (Table 1)."""

    per_10m_input: float
    per_10m_output: float
    per_request: float = 0.0

    @property
    def c1(self) -> float:          # per input token
        return self.per_10m_input / 1e7

    @property
    def c2(self) -> float:          # per output token
        return self.per_10m_output / 1e7

    @property
    def c0(self) -> float:
        return self.per_request

    def query_cost(self, n_in, n_out):
        """Vectorized: n_in/n_out may be arrays of token counts."""
        return self.c2 * jnp.asarray(n_out, jnp.float32) + \
            self.c1 * jnp.asarray(n_in, jnp.float32) + self.c0


# Table 1 — retrieved March 2023 (USD per 10M tokens; per-request fixed fee).
TABLE1: dict[str, ApiCost] = {
    "GPT-C":     ApiCost(2.0, 2.0, 0.0),        # OpenAI GPT-Curie (6.7B)
    "ChatGPT":   ApiCost(2.0, 2.0, 0.0),
    "GPT-3":     ApiCost(20.0, 20.0, 0.0),      # 175B
    "GPT-4":     ApiCost(30.0, 60.0, 0.0),
    "J1-L":      ApiCost(0.0, 30.0, 0.0003),    # AI21 J1-Large (7.5B)
    "J1-G":      ApiCost(0.0, 80.0, 0.0008),    # J1-Grande (17B)
    "J1-J":      ApiCost(0.0, 250.0, 0.005),    # J1-Jumbo (178B)
    "Cohere":    ApiCost(10.0, 10.0, 0.0),      # Xlarge (52B)
    "FF-QA":     ApiCost(5.8, 5.8, 0.0),        # ForeFrontAI QA (16B)
    "GPT-J":     ApiCost(0.2, 5.0, 0.0),        # Textsynth (6B)
    "FAIRSEQ":   ApiCost(0.6, 15.0, 0.0),       # Textsynth (13B)
    "GPT-Neox":  ApiCost(1.4, 35.0, 0.0),       # Textsynth (20B)
}

MODEL_SIZES_B = {
    "GPT-C": 6.7, "ChatGPT": 20.0, "GPT-3": 175.0, "GPT-4": 300.0,
    "J1-L": 7.5, "J1-G": 17.0, "J1-J": 178.0, "Cohere": 52.0,
    "FF-QA": 16.0, "GPT-J": 6.0, "FAIRSEQ": 13.0, "GPT-Neox": 20.0,
}


def compute_cost_flops(name: str, n_in, n_out):
    """Self-hosted compute-cost analogue: ~2*N FLOPs per token (DESIGN.md §3)."""
    n = MODEL_SIZES_B.get(name, 10.0) * 1e9
    return 2.0 * n * (jnp.asarray(n_in, jnp.float32)
                      + jnp.asarray(n_out, jnp.float32))
