"""LLM approximation (paper §3 Strategy 2b): model fine-tuning.

Collect an expensive API's answers on unlabeled queries, fine-tune a
small model on those answers, and register the student as a new
(near-zero-cost) API in the marketplace. Mirrors Fig. 2(d)'s 3 steps.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost import ApiCost
from repro.core.neural_market import NeuralAPI
from repro.data import synthetic
from repro.models.classifier import encoder_config
from repro.training.train_loop import train_classifier


def distill(teacher: NeuralAPI, task: str, *, n_unlabeled: int = 2048,
            seq_len: int = 64, steps: int = 300, seed: int = 0,
            student_layers: int = 2, student_d: int = 64) -> NeuralAPI:
    """Fine-tune a student on the teacher's answers (not gold labels)."""
    n_classes = synthetic.N_CLASSES[task]
    pool = synthetic.sample(task, n_unlabeled, seq_len=seq_len,
                            seed=seed + 777)
    teacher_ans = teacher.answer(pool.tokens)     # step 1: collect responses

    rng = np.random.default_rng(seed)

    def data_fn(step):                            # step 2: fine-tune student
        idx = rng.choice(n_unlabeled, size=128, replace=False)
        return pool.tokens[idx], teacher_ans[idx]

    cfg = encoder_config(f"student-of-{teacher.name}",
                         n_layers=student_layers, d_model=student_d,
                         n_heads=max(2, student_d // 32), d_ff=2 * student_d,
                         max_seq=seq_len + 4)
    params, _ = train_classifier(cfg, n_classes, data_fn=data_fn,
                                 steps=steps, seed=seed)
    # step 3: serve the student — self-hosted, near-zero marginal cost;
    # we bill it at the cheapest Table-1 rate to stay conservative.
    return NeuralAPI(f"distilled-{teacher.name}", cfg, params,
                     ApiCost(0.2, 5.0, 0.0))
