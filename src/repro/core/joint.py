"""Compositions (paper §3): joint prompt and LLM selection.

"for a given query, it searches for the smallest prompt and most
affordable LLM that achieves satisfactory task performance."

We compose Strategy 1 (prompt selection) with Strategy 3 (LLM cascade):
for each candidate prompt size (number of in-context examples), rebuild
the marketplace costs (shorter prompt -> cheaper queries) and the
accuracy profile (fewer shots -> slightly weaker APIs), learn a cascade
under the budget, and return the (prompt, cascade) pair with the best
held-out accuracy. The search space is the cross product the paper
describes; pruning comes from the router's own list pruning.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.cascade import evaluate_offline
from repro.core.router import RouterConfig, learn_cascade
from repro.core.simulate import DATASETS, MarketData

# accuracy penalty per removed in-context example (measured on the
# synthetic tasks; conservative vs published few-shot scaling curves)
SHOT_PENALTY = 0.008


def reprice_for_prompt(data: MarketData, dataset: str, n_examples: int,
                       seed: int = 0) -> MarketData:
    """Marketplace as it would look with an n_examples-shot prompt."""
    spec = DATASETS[dataset]
    full = spec["n_shot"]
    assert 0 <= n_examples <= full
    tokens_per_example = spec["n_in"] // (full + 2)
    delta_tokens = (full - n_examples) * tokens_per_example
    n_in = jnp.maximum(8, data.n_in - delta_tokens)
    # shorter prompt => cheaper input cost; recompute c1 * n_in exactly
    from repro.core.cost import TABLE1
    cost = np.zeros(np.asarray(data.cost).shape, np.float32)
    for k, name in enumerate(data.names):
        cost[:, k] = np.asarray(TABLE1[name].query_cost(n_in, data.n_out))
    # fewer shots => mild accuracy degradation (stochastic flips)
    rng = np.random.default_rng(seed)
    p_flip = SHOT_PENALTY * (full - n_examples)
    flips = rng.uniform(size=np.asarray(data.correct).shape) < p_flip
    correct = np.asarray(data.correct).copy()
    correct[flips] = np.where(rng.uniform(size=flips.sum()) < 0.25,
                              1.0 - correct[flips], correct[flips] * 0.0)
    return MarketData(data.names, jnp.asarray(correct), jnp.asarray(cost),
                      n_in.astype(jnp.int32), data.n_out, data.difficulty)


def joint_prompt_cascade(data: MarketData, scores, dataset: str,
                         budget: float, cfg: RouterConfig | None = None,
                         prompt_sizes=None, seed: int = 0):
    """Search (prompt size x cascade) jointly. Returns the best combo and
    the per-prompt-size frontier row."""
    spec = DATASETS[dataset]
    prompt_sizes = prompt_sizes or range(spec["n_shot"] + 1)
    cfg = cfg or RouterConfig(top_lists=15, sample=384)
    rows = []
    best = None
    for n_ex in prompt_sizes:
        d = reprice_for_prompt(data, dataset, n_ex, seed=seed)
        cas, m = learn_cascade(d, scores, budget, cfg)
        row = {"n_examples": int(n_ex), "cascade": cas, **m}
        rows.append(row)
        if best is None or m["acc"] > best["acc"]:
            best = row
    return best, rows
