"""LLM approximation (paper §3 Strategy 2a): the completion cache.

Stores (query-embedding, answer) pairs; a new query reuses a cached
answer when its nearest cached neighbour is within a similarity
threshold. Embeddings come from the scorer's encoder (mean-pooled), so
no extra model is needed. Pure-JAX nearest-neighbour over the cache
matrix; the cache itself is a ring buffer of fixed capacity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm
from repro.models.transformer import _apply_stack, _embed_inputs


_EMBED_JIT: dict = {}


def _embed_fn(cfg: ModelConfig):
    """Per-config cached jitted embedder — the cache stage runs on every
    served batch, so it must not re-jit (and retrace) per call. Keyed by
    the (frozen) config itself, not its name: configs sharing a name
    with different hyperparameters must not reuse each other's graph."""
    fn = _EMBED_JIT.get(cfg)
    if fn is None:

        @jax.jit
        def fn(params, toks):
            x, positions = _embed_inputs(params, {"tokens": toks}, cfg,
                                         "train")
            x, _, _ = _apply_stack(params, x, cfg=cfg, mode="train",
                                   positions=positions, cache=None, pos=None,
                                   remat=False)
            h = apply_norm(params["final_norm"], x, cfg).mean(1)
            return h / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6)

        _EMBED_JIT[cfg] = fn
    return fn


def embed_queries(params, tokens, cfg: ModelConfig, batch: int = 512):
    """Mean-pooled encoder embedding, L2-normalized. (n, d)."""
    fn = _embed_fn(cfg)
    out = []
    for i in range(0, tokens.shape[0], batch):
        out.append(np.asarray(fn(params, jnp.asarray(tokens[i:i + batch]))))
    return np.concatenate(out)


@dataclasses.dataclass
class CompletionCache:
    """Fixed-capacity (embedding, answer) store with pluggable eviction.

    ``policy="fifo"`` keeps the original ring buffer (oldest *insert*
    evicted first); ``policy="lru"`` evicts the least-recently-*used*
    entry — a lookup hit refreshes its entry, so hot queries survive a
    skewed stream that would cycle them out of the ring.

    ``min_score`` is a score-confidence floor: ``insert`` drops entries
    whose accept-time reliability score falls below it, so answers the
    scorer distrusted are never served to future near-duplicates. NaN
    scores (the cascade's last tier answers without scoring) are
    treated as trusted.
    """

    capacity: int = 4096
    threshold: float = 0.97
    policy: str = "fifo"            # "fifo" ring | "lru"
    min_score: float | None = None  # score-confidence floor for inserts

    def __post_init__(self):
        if self.policy not in ("fifo", "lru"):
            raise ValueError(f"unknown eviction policy {self.policy!r}; "
                             "expected 'fifo' or 'lru'")
        self._emb = None            # (cap, d)
        self._ans = None            # (cap,)
        self._valid = None
        self._next = 0              # fifo ring head
        self._used = None           # (cap,) last-use tick (lru)
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.skipped_low_score = 0  # inserts dropped by the floor

    def lookup(self, emb: np.ndarray):
        """emb (n, d) -> (hit_mask (n,), answers (n,))."""
        n = emb.shape[0]
        if self._emb is None or not self._valid.any():
            self.misses += n
            return np.zeros(n, bool), np.zeros(n, np.int32)
        sims = emb @ self._emb.T                       # (n, cap)
        sims = np.where(self._valid[None, :], sims, -1.0)
        best = sims.argmax(1)
        best_sim = sims[np.arange(n), best]
        hit = best_sim >= self.threshold
        if self.policy == "lru" and hit.any():
            slots = best[hit]                # refresh hit entries; a slot
            self._used[slots] = self._tick + np.arange(len(slots))
            self._tick += len(slots)         # hit twice keeps the later tick
        self.hits += int(hit.sum())
        self.misses += int((~hit).sum())
        return hit, self._ans[best].astype(np.int32)

    def insert(self, emb: np.ndarray, answers: np.ndarray, scores=None):
        """Insert entries; ``scores`` (optional, (n,)) are accept-time
        reliability scores checked against the ``min_score`` floor."""
        emb = np.asarray(emb)
        answers = np.asarray(answers)
        if self.min_score is not None and scores is not None:
            s = np.asarray(scores, np.float64)
            keep = np.isnan(s) | (s >= self.min_score)
            self.skipped_low_score += int((~keep).sum())
            if not keep.all():
                emb, answers = emb[keep], answers[keep]
        n = len(emb)
        if n == 0:
            return
        if self._emb is None:
            d = emb.shape[1]
            self._emb = np.zeros((self.capacity, d), emb.dtype)
            self._ans = np.zeros(self.capacity, np.int32)
            self._valid = np.zeros(self.capacity, bool)
            self._used = np.zeros(self.capacity, np.int64)
        if self.policy == "fifo":
            # ring semantics: a batch larger than the ring self-overwrites
            # so the NEWEST entries survive and _next keeps advancing
            idx = (self._next + np.arange(n)) % self.capacity
            self._next = int((self._next + n) % self.capacity)
        else:
            if n > self.capacity:            # keep the newest, like the ring
                emb, answers = emb[-self.capacity:], answers[-self.capacity:]
                n = self.capacity
            # victims: empty slots first, then least-recently-used
            prio = np.where(self._valid, self._used, -1)
            idx = np.argsort(prio, kind="stable")[:n]
        self._emb[idx] = emb
        self._ans[idx] = answers
        self._valid[idx] = True
        self._used[idx] = self._tick + np.arange(n)
        self._tick += n

    @property
    def hit_rate(self):
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


def serve_with_cache(cache: CompletionCache, emb: np.ndarray,
                     tokens: np.ndarray, api_answer, api_cost):
    """Answer queries, consulting the cache first (Fig. 2c).

    api_answer(tokens_subset) -> answers; api_cost(tokens_subset) -> costs.
    Returns (answers, total_cost, hit_mask)."""
    hit, cached = cache.lookup(emb)
    n = tokens.shape[0]
    answers = np.zeros(n, np.int32)
    answers[hit] = cached[hit]
    cost = np.zeros(n, np.float64)
    miss = ~hit
    if miss.any():
        fresh = api_answer(tokens[miss])
        answers[miss] = fresh
        cost[miss] = api_cost(tokens[miss])
        cache.insert(emb[miss], fresh)
    return answers, cost, hit
