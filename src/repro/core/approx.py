"""LLM approximation (paper §3 Strategy 2a): the completion cache.

Stores (query-embedding, answer) pairs; a new query reuses a cached
answer when its nearest cached neighbour is within a similarity
threshold. Embeddings come from the scorer's encoder (mean-pooled), so
no extra model is needed. Pure-JAX nearest-neighbour over the cache
matrix; the cache itself is a ring buffer of fixed capacity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm
from repro.models.transformer import _apply_stack, _embed_inputs


_EMBED_JIT: dict = {}


def _embed_fn(cfg: ModelConfig):
    """Per-config cached jitted embedder — the cache stage runs on every
    served batch, so it must not re-jit (and retrace) per call. Keyed by
    the (frozen) config itself, not its name: configs sharing a name
    with different hyperparameters must not reuse each other's graph."""
    fn = _EMBED_JIT.get(cfg)
    if fn is None:

        @jax.jit
        def fn(params, toks):
            x, positions = _embed_inputs(params, {"tokens": toks}, cfg,
                                         "train")
            x, _, _ = _apply_stack(params, x, cfg=cfg, mode="train",
                                   positions=positions, cache=None, pos=None,
                                   remat=False)
            h = apply_norm(params["final_norm"], x, cfg).mean(1)
            return h / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6)

        _EMBED_JIT[cfg] = fn
    return fn


def embed_queries(params, tokens, cfg: ModelConfig, batch: int = 512):
    """Mean-pooled encoder embedding, L2-normalized. (n, d)."""
    fn = _embed_fn(cfg)
    out = []
    for i in range(0, tokens.shape[0], batch):
        out.append(np.asarray(fn(params, jnp.asarray(tokens[i:i + batch]))))
    return np.concatenate(out)


@dataclasses.dataclass
class CompletionCache:
    """Fixed-capacity (embedding, answer) store with pluggable eviction.

    ``policy="fifo"`` keeps the original ring buffer (oldest *insert*
    evicted first); ``policy="lru"`` evicts the least-recently-*used*
    entry — a lookup hit refreshes its entry, so hot queries survive a
    skewed stream that would cycle them out of the ring; ``policy="lfu"``
    evicts the least-frequently-used entry (hit count, ties broken
    least-recently-used), so a steady hot set survives even a long flood
    of one-off queries that would age everything out of an LRU.

    ``ttl`` (seconds) bounds entry lifetime: an entry older than ``ttl``
    at *lookup* time is expired — invalidated and never served — so a
    stale answer can't outlive the world that produced it (tier models
    retrained, prompts reselected). Expiry uses ``time_fn`` (monotonic
    by default, injectable so tests don't sleep).

    ``min_score`` is a score-confidence floor: ``insert`` drops entries
    whose accept-time reliability score falls below it, so answers the
    scorer distrusted are never served to future near-duplicates. NaN
    scores (the cascade's last tier answers without scoring) are
    treated as trusted.
    """

    capacity: int = 4096
    threshold: float = 0.97
    policy: str = "fifo"            # "fifo" ring | "lru" | "lfu"
    min_score: float | None = None  # score-confidence floor for inserts
    ttl: float | None = None        # entry time-to-live, seconds
    time_fn: object = None          # clock for TTL (default time.monotonic)

    def __post_init__(self):
        if self.policy not in ("fifo", "lru", "lfu"):
            raise ValueError(f"unknown eviction policy {self.policy!r}; "
                             "expected 'fifo', 'lru' or 'lfu'")
        if self.ttl is not None and self.ttl <= 0:
            raise ValueError(f"ttl must be > 0 seconds, got {self.ttl}")
        if self.time_fn is None:
            import time
            self.time_fn = time.monotonic
        self._emb = None            # (cap, d)
        self._ans = None            # (cap,)
        self._valid = None
        self._next = 0              # fifo ring head
        self._used = None           # (cap,) last-use tick (lru/lfu ties)
        self._freq = None           # (cap,) hit count (lfu)
        self._born = None           # (cap,) insert time (ttl)
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.skipped_low_score = 0  # inserts dropped by the floor
        self.expired = 0            # entries invalidated by the TTL

    def _expire(self):
        """Invalidate entries older than ``ttl`` (called at lookup, so
        an expired entry is never served even if nothing evicted it)."""
        if self.ttl is None or self._valid is None:
            return
        stale = self._valid & (self.time_fn() - self._born > self.ttl)
        if stale.any():
            self.expired += int(stale.sum())
            self._valid[stale] = False

    def lookup(self, emb: np.ndarray):
        """emb (n, d) -> (hit_mask (n,), answers (n,))."""
        n = emb.shape[0]
        self._expire()
        if self._emb is None or not self._valid.any():
            self.misses += n
            return np.zeros(n, bool), np.zeros(n, np.int32)
        sims = emb @ self._emb.T                       # (n, cap)
        sims = np.where(self._valid[None, :], sims, -1.0)
        best = sims.argmax(1)
        best_sim = sims[np.arange(n), best]
        hit = best_sim >= self.threshold
        if hit.any():
            slots = best[hit]
            if self.policy in ("lru", "lfu"):
                # refresh hit entries; a slot hit twice in this batch
                # keeps the later tick
                self._used[slots] = self._tick + np.arange(len(slots))
                self._tick += len(slots)
            if self.policy == "lfu":
                np.add.at(self._freq, slots, 1)
        self.hits += int(hit.sum())
        self.misses += int((~hit).sum())
        return hit, self._ans[best].astype(np.int32)

    def insert(self, emb: np.ndarray, answers: np.ndarray, scores=None):
        """Insert entries; ``scores`` (optional, (n,)) are accept-time
        reliability scores checked against the ``min_score`` floor."""
        emb = np.asarray(emb)
        answers = np.asarray(answers)
        if self.min_score is not None and scores is not None:
            s = np.asarray(scores, np.float64)
            keep = np.isnan(s) | (s >= self.min_score)
            self.skipped_low_score += int((~keep).sum())
            if not keep.all():
                emb, answers = emb[keep], answers[keep]
        n = len(emb)
        if n == 0:
            return
        # expire before choosing victims: a TTL-stale entry must free
        # its slot rather than sit valid-looking while a LIVE entry
        # (whose tick/frequency merely sorts lower) gets evicted
        self._expire()
        if self._emb is None:
            d = emb.shape[1]
            self._emb = np.zeros((self.capacity, d), emb.dtype)
            self._ans = np.zeros(self.capacity, np.int32)
            self._valid = np.zeros(self.capacity, bool)
            self._used = np.zeros(self.capacity, np.int64)
            self._freq = np.zeros(self.capacity, np.int64)
            self._born = np.zeros(self.capacity, np.float64)
        if self.policy == "fifo":
            # ring semantics: a batch larger than the ring self-overwrites
            # so the NEWEST entries survive and _next keeps advancing
            idx = (self._next + np.arange(n)) % self.capacity
            self._next = int((self._next + n) % self.capacity)
        else:
            if n > self.capacity:            # keep the newest, like the ring
                emb, answers = emb[-self.capacity:], answers[-self.capacity:]
                n = self.capacity
            if self.policy == "lru":
                # victims: empty slots first, then least-recently-used
                prio = np.where(self._valid, self._used, -1)
                idx = np.argsort(prio, kind="stable")[:n]
            else:
                # lfu victims: empty slots first, then lowest hit count,
                # ties least-recently-used (lexsort: last key is primary)
                empty = self._valid.astype(np.int64)        # 0 sorts first
                idx = np.lexsort((self._used, self._freq, empty))[:n]
        self._emb[idx] = emb
        self._ans[idx] = answers
        self._valid[idx] = True
        self._used[idx] = self._tick + np.arange(n)
        self._freq[idx] = 0
        self._born[idx] = self.time_fn()
        self._tick += n

    @property
    def hit_rate(self):
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


def serve_with_cache(cache: CompletionCache, emb: np.ndarray,
                     tokens: np.ndarray, api_answer, api_cost):
    """Answer queries, consulting the cache first (Fig. 2c).

    api_answer(tokens_subset) -> answers; api_cost(tokens_subset) -> costs.
    Returns (answers, total_cost, hit_mask)."""
    hit, cached = cache.lookup(emb)
    n = tokens.shape[0]
    answers = np.zeros(n, np.int32)
    answers[hit] = cached[hit]
    cost = np.zeros(n, np.float64)
    miss = ~hit
    if miss.any():
        fresh = api_answer(tokens[miss])
        answers[miss] = fresh
        cost[miss] = api_cost(tokens[miss])
        cache.insert(emb[miss], fresh)
    return answers, cost, hit
