"""The FrugalGPT router optimizer (paper §3, eq. (1)).

Learning (L, tau) is a mixed-integer program:

    max_{L, tau} E[r(a, f_{L_z}(q))]
    s.t.         E[cascade cost] <= b

The paper's specialized optimizer (i) prunes the list search space by
ignoring lists with small answer disagreement, and (ii) approximates the
objective by interpolating it within a few samples. We implement both:

  * pruning: a candidate list must have every later API fix at least
    ``min_mpi`` of the earlier APIs' errors (MPI-based), and we keep the
    ``top_lists`` lists by union-accuracy potential;
  * approximation: thresholds are grid-searched on a subsample of the
    training queries (vectorized over the (tau_1, tau_2) grid in jnp),
    then the winning (L, tau) is re-scored on the full training set.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax.numpy as jnp
import numpy as np

from repro.core.cascade import Cascade, evaluate_offline
from repro.core.simulate import MarketData, mpi_matrix


@dataclasses.dataclass
class RouterConfig:
    m: int = 3                  # cascade length (paper uses 3)
    grid: int = 24              # threshold grid resolution per position
    sample: int = 512           # subsample size for objective interpolation
    min_mpi: float = 0.01       # disagreement pruning threshold
    top_lists: int = 40         # lists kept after potential ranking
    seed: int = 0


def _candidate_lists(data: MarketData, cfg: RouterConfig):
    """MPI-pruned, potential-ranked candidate lists of length m."""
    k = data.k
    mpi = np.asarray(mpi_matrix(data.correct))
    acc = np.asarray(data.accuracy())
    cand = []
    for perm in itertools.permutations(range(k), cfg.m):
        # disagreement pruning: each later API must fix >= min_mpi of the
        # previous API's errors, else the extra stage is dead weight.
        ok = all(mpi[perm[j], perm[j + 1]] >= cfg.min_mpi
                 for j in range(cfg.m - 1))
        if not ok:
            continue
        # potential = accuracy of the union oracle (upper bound)
        union = np.asarray(data.correct)[:, list(perm)].max(1).mean()
        # cheap first stages are what saves money: sort key prefers
        # potential, then low first-stage cost
        first_cost = float(data.cost[:, perm[0]].mean())
        cand.append((union, -first_cost, perm))
    cand.sort(reverse=True)
    return [c[-1] for c in cand[:cfg.top_lists]]


def _grid_eval(perm, data: MarketData, scores, grid: jnp.ndarray):
    """Vectorized (acc, cost) over the full threshold grid for one list.

    Supports m in {2, 3}. Returns acc, cost arrays of shape grid^(m-1).
    """
    if len(perm) not in (2, 3):
        # jnp's clamping fancy-indexing would otherwise silently mis-index
        # y/c/g columns for longer (or shorter) lists
        raise ValueError(
            f"_grid_eval supports cascade lists of length 2 or 3 (the "
            f"paper's setting); got m={len(perm)} ({perm}). Use a "
            f"RouterConfig with m <= 3 or extend the threshold grid "
            f"search before raising m.")
    y = data.correct[:, list(perm)]          # (n, m)
    c = data.cost[:, list(perm)]             # (n, m)
    g = scores[:, list(perm)]                # (n, m)
    if len(perm) == 2:
        stop1 = g[:, 0][None] >= grid[:, None]            # (G, n)
        acc = jnp.where(stop1, y[:, 0][None], y[:, 1][None]).mean(-1)
        cost = (c[:, 0][None] + jnp.where(stop1, 0.0, c[:, 1][None])).mean(-1)
        return acc, cost
    stop1 = g[:, 0][None] >= grid[:, None]                # (G1, n)
    stop2 = g[:, 1][None] >= grid[:, None]                # (G2, n)
    s1 = stop1[:, None, :]                                # (G1, 1, n)
    s2 = (~stop1)[:, None, :] & stop2[None, :, :]         # (G1, G2, n)
    s3 = (~stop1)[:, None, :] & (~stop2)[None, :, :]
    acc = (s1 * y[:, 0] + s2 * y[:, 1] + s3 * y[:, 2]).mean(-1)
    cost = (c[:, 0] + (~stop1)[:, None, :] * c[:, 1] + s3 * c[:, 2]).mean(-1)
    return acc, cost


def learn_cascade(data: MarketData, scores, budget: float,
                  cfg: RouterConfig | None = None) -> tuple[Cascade, dict]:
    """Learn (L, tau) maximizing accuracy s.t. avg cost <= budget."""
    cfg = cfg or RouterConfig()
    rng = np.random.default_rng(cfg.seed)
    sub = rng.choice(data.n, size=min(cfg.sample, data.n), replace=False)
    sub_data = MarketData(data.names, data.correct[sub], data.cost[sub],
                          data.n_in[sub], data.n_out[sub],
                          data.difficulty[sub])
    sub_scores = scores[sub]
    grid = jnp.linspace(0.0, 1.0, cfg.grid)

    best = (-1.0, None, None)
    for perm in _candidate_lists(data, cfg):
        acc, cost = _grid_eval(perm, sub_data, sub_scores, grid)
        feasible = cost <= budget
        if not bool(feasible.any()):
            continue
        masked = jnp.where(feasible, acc, -1.0)
        flat = int(jnp.argmax(masked))
        if len(perm) == 2:
            taus = (float(grid[flat]),)
        else:
            i1, i2 = np.unravel_index(flat, (cfg.grid, cfg.grid))
            taus = (float(grid[i1]), float(grid[i2]))
        a = float(masked.max())
        if a > best[0]:
            best = (a, perm, taus)
    if best[1] is None:
        # budget below the cheapest API: fall back to cheapest single API
        cheapest = int(jnp.argmin(data.cost.mean(0)))
        cascade = Cascade((cheapest,), ())
        return cascade, evaluate_offline(cascade, data, scores)
    cascade = Cascade(tuple(best[1]), best[2])
    # re-score the winner on the full training data (interpolation step)
    metrics = evaluate_offline(cascade, data, scores)
    return cascade, metrics


def frontier(data: MarketData, scores, budgets,
             cfg: RouterConfig | None = None):
    """Accuracy-cost tradeoff curve (Fig. 5): learn a cascade per budget."""
    out = []
    for b in budgets:
        cas, m = learn_cascade(data, scores, float(b), cfg)
        out.append({"budget": float(b), "cascade": cas, **m})
    return out


def cost_to_match(data_train: MarketData, scores_train,
                  data_test: MarketData, scores_test,
                  target_acc: float, cfg: RouterConfig | None = None,
                  n_steps: int = 12) -> dict:
    """Bisection over budgets: smallest avg cost whose learned cascade
    matches ``target_acc`` on the *test* split (Table 3 protocol)."""
    lo = float(data_train.cost.min(1).mean()) * 0.5
    hi = float(data_train.cost.max(1).mean()) * 1.5
    best = None
    for _ in range(n_steps):
        mid = 0.5 * (lo + hi)
        cas, _ = learn_cascade(data_train, scores_train, mid, cfg)
        m = evaluate_offline(cas, data_test, scores_test)
        if m["acc"] >= target_acc:
            best = {"budget": mid, "cascade": cas, **m}
            hi = mid
        else:
            lo = mid
    return best
