"""Prompt adaptation (paper §3 Strategy 1).

* Prompt selection (Fig. 2a): keep only a subset of in-context examples —
  greedy forward selection maximizing validation accuracy per token.
* Query concatenation (Fig. 2b): share one prompt across g queries so its
  token cost is amortized 1/g per query.

The cost model is exact (ApiCost); accuracy comes from an evaluator
callback so both the simulated and the neural marketplace can use it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.cost import ApiCost


@dataclasses.dataclass
class PromptSpec:
    example_ids: tuple          # which in-context examples are kept
    tokens_per_example: int
    base_tokens: int            # instruction + query tokens

    @property
    def n_tokens(self) -> int:
        return self.base_tokens + self.tokens_per_example * len(self.example_ids)


def select_prompt(candidates: Sequence[int], evaluate: Callable,
                  tokens_per_example: int, base_tokens: int,
                  min_gain: float = 1e-3, max_examples: int | None = None):
    """Greedy forward selection: add the example with the best accuracy
    gain until gains fall below ``min_gain``.

    evaluate(tuple_of_example_ids) -> accuracy on a validation set.
    Returns (PromptSpec, history)."""
    chosen: list[int] = []
    acc = evaluate(tuple(chosen))
    hist = [{"examples": tuple(chosen), "acc": acc}]
    pool = list(candidates)
    while pool and (max_examples is None or len(chosen) < max_examples):
        gains = [(evaluate(tuple(chosen + [c])), c) for c in pool]
        best_acc, best_c = max(gains)
        if best_acc - acc < min_gain:
            break
        chosen.append(best_c)
        pool.remove(best_c)
        acc = best_acc
        hist.append({"examples": tuple(chosen), "acc": acc})
    return PromptSpec(tuple(chosen), tokens_per_example, base_tokens), hist


def concat_cost(price: ApiCost, prompt_tokens: int, query_tokens: int,
                gen_tokens: int, group: int) -> float:
    """Per-query cost when ``group`` queries share one prompt (Fig. 2b)."""
    n_in = prompt_tokens + group * query_tokens
    n_out = group * gen_tokens
    total = float(price.query_cost(n_in, n_out))
    return total / group


def concat_savings(price: ApiCost, prompt_tokens: int, query_tokens: int,
                   gen_tokens: int, groups=(1, 2, 4, 8, 16)) -> dict:
    base = concat_cost(price, prompt_tokens, query_tokens, gen_tokens, 1)
    return {g: 1.0 - concat_cost(price, prompt_tokens, query_tokens,
                                 gen_tokens, g) / base
            for g in groups}
