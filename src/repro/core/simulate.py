"""Simulated LLM marketplace, calibrated to the paper's datasets.

IRT (item-response-theory) simulation: each query i has difficulty d_i,
each API k an ability a_k; P(correct) = sigmoid(disc * (a_k - d_i + eps)).
The shared difficulty induces the correlation structure between APIs that
the paper measures via MPI (Fig. 4); the idiosyncratic eps term creates
the complementarity (cheap models fixing expensive models' mistakes) that
makes the cascade able to *beat* GPT-4.

Abilities are calibrated per dataset so each API's marginal accuracy
matches the paper's observations (Figs. 3-5, Table 3 context).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import TABLE1, ApiCost

# Per-dataset target accuracies (paper Figs. 3-5; COQA: GPT-3 is the best
# individual LLM, matching Table 3).
DATASETS = {
    "HEADLINES": dict(
        acc={"GPT-4": 0.858, "GPT-3": 0.845, "ChatGPT": 0.832, "GPT-C": 0.820,
             "J1-J": 0.810, "J1-G": 0.800, "J1-L": 0.805, "Cohere": 0.780,
             "GPT-Neox": 0.770, "GPT-J": 0.800, "FAIRSEQ": 0.740, "FF-QA": 0.720},
        n_in=1023, n_out=4, size=10_000, n_shot=8),
    "OVERRULING": dict(
        acc={"GPT-4": 0.940, "ChatGPT": 0.925, "GPT-3": 0.920, "GPT-C": 0.890,
             "J1-J": 0.900, "J1-G": 0.885, "J1-L": 0.875, "Cohere": 0.870,
             "GPT-Neox": 0.855, "GPT-J": 0.880, "FAIRSEQ": 0.830, "FF-QA": 0.820},
        n_in=1267, n_out=4, size=2_400, n_shot=5),
    "COQA": dict(
        acc={"GPT-3": 0.725, "GPT-4": 0.680, "ChatGPT": 0.660, "GPT-C": 0.600,
             "J1-J": 0.640, "J1-G": 0.615, "J1-L": 0.590, "Cohere": 0.580,
             "GPT-Neox": 0.560, "GPT-J": 0.555, "FAIRSEQ": 0.530, "FF-QA": 0.510},
        n_in=4500, n_out=10, size=7_982, n_shot=2),
}

DISC = 1.6          # IRT discrimination
IDIO = 0.85         # idiosyncratic noise scale (drives MPI complementarity)


@dataclasses.dataclass
class MarketData:
    """Offline-collected marketplace responses for one dataset.

    correct:  (n, K) 0/1 — whether API k answered query i correctly
    cost:     (n, K) USD  — per-query cost of calling API k on query i
    n_in/out: (n,)  token counts
    names:    list of K API names
    """

    names: list
    correct: jnp.ndarray
    cost: jnp.ndarray
    n_in: jnp.ndarray
    n_out: jnp.ndarray
    difficulty: jnp.ndarray

    @property
    def n(self):
        return self.correct.shape[0]

    @property
    def k(self):
        return len(self.names)

    def accuracy(self):
        return self.correct.mean(0)

    def split(self, frac=0.5, seed=0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(self.n)
        cut = int(self.n * frac)
        tr, te = idx[:cut], idx[cut:]

        def take(i):
            return MarketData(self.names, self.correct[i], self.cost[i],
                              self.n_in[i], self.n_out[i], self.difficulty[i])
        return take(tr), take(te)


def split_market(data: MarketData, scores, frac=0.5, seed=0):
    """Split data AND the aligned score matrix with one permutation."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(data.n)
    cut = int(data.n * frac)
    s = np.asarray(scores)

    def take(i):
        return MarketData(data.names, data.correct[i], data.cost[i],
                          data.n_in[i], data.n_out[i], data.difficulty[i])

    return (take(idx[:cut]), take(idx[cut:]),
            jnp.asarray(s[idx[:cut]]), jnp.asarray(s[idx[cut:]]))


def _calibrate_ability(target_acc: float, d: np.ndarray, eps: np.ndarray,
                       disc: float) -> float:
    """Solve mean(sigmoid(disc*(a - d + eps))) == target by bisection."""
    lo, hi = -10.0, 10.0
    for _ in range(50):
        mid = 0.5 * (lo + hi)
        acc = float(np.mean(1.0 / (1.0 + np.exp(-disc * (mid - d + eps)))))
        if acc < target_acc:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def simulate_market(dataset: str, seed: int = 0, n: int | None = None,
                    apis: dict[str, ApiCost] | None = None) -> MarketData:
    spec = DATASETS[dataset]
    apis = apis or TABLE1
    names = list(apis)
    rng = np.random.default_rng(seed)
    n = n or spec["size"]
    d = rng.normal(0.0, 1.0, size=n)                       # query difficulty
    correct = np.zeros((n, len(names)), np.float32)
    for k, name in enumerate(names):
        eps = rng.normal(0.0, IDIO, size=n)                # per-(query,api)
        a = _calibrate_ability(spec["acc"][name], d, eps, DISC)
        p = 1.0 / (1.0 + np.exp(-DISC * (a - d + eps)))
        correct[:, k] = (rng.uniform(size=n) < p).astype(np.float32)
    # token counts: lognormal-ish around the dataset means
    n_in = np.maximum(8, rng.normal(spec["n_in"], spec["n_in"] * 0.15,
                                    size=n)).astype(np.int32)
    n_out = np.maximum(1, rng.normal(spec["n_out"], 1.5, size=n)).astype(np.int32)
    cost = np.zeros((n, len(names)), np.float32)
    for k, name in enumerate(names):
        cost[:, k] = np.asarray(apis[name].query_cost(n_in, n_out))
    return MarketData(names, jnp.asarray(correct), jnp.asarray(cost),
                      jnp.asarray(n_in), jnp.asarray(n_out), jnp.asarray(d))


def simulate_scores(data: MarketData, auc_quality: float = 1.45,
                    seed: int = 0) -> jnp.ndarray:
    """Simulated generation-scoring function g(q, a_k) in [0,1], (n, K).

    Emulates a trained DistilBERT regression scorer: score is informative
    of correctness with finite AUC (auc_quality = logit separation).
    The *neural* path (repro.core.scorer) learns this from data instead.
    """
    key = jax.random.PRNGKey(seed)
    noise = jax.random.normal(key, data.correct.shape)
    logits = auc_quality * (2.0 * data.correct - 1.0) + 1.25 * noise
    return jax.nn.sigmoid(logits)


def mpi_matrix(correct: jnp.ndarray) -> jnp.ndarray:
    """Maximum Performance Improvement (Fig. 4): MPI[r, c] = P(row wrong,
    col right) — how much the column API could add on top of the row API."""
    wrong = 1.0 - correct
    return (wrong.T @ correct) / correct.shape[0]
