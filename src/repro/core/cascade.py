"""LLM cascade (paper §3 Strategy 3): ordered API list + score thresholds.

Two execution paths:
  * ``evaluate_offline`` — vectorized accuracy/cost of a cascade on
    offline-collected marketplace data (used by the router optimizer and
    all §Repro experiments, mirroring the paper's offline methodology);
  * ``run_online`` — tier-by-tier batched execution against live models
    (the serving engine path): query tier-1 for the whole batch, score,
    and re-batch only the unreliable queries to the next tier.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.simulate import MarketData


@dataclasses.dataclass(frozen=True)
class Cascade:
    """A learned cascade: API indices L and per-position thresholds tau.

    The last position needs no threshold (it always answers), so
    ``thresholds`` has length len(apis) - 1.
    """

    apis: tuple            # indices into the marketplace (len m)
    thresholds: tuple      # len m-1, floats in [0,1]

    def describe(self, names: Sequence[str]) -> str:
        parts = []
        for j, a in enumerate(self.apis):
            if j < len(self.thresholds):
                parts.append(f"{names[a]} (accept if g>{self.thresholds[j]:.2f})")
            else:
                parts.append(f"{names[a]}")
        return " -> ".join(parts)


def evaluate_offline(cascade: Cascade, data: MarketData, scores) -> dict:
    """Vectorized evaluation. scores: (n, K) reliability scores g(q, a_k).

    Returns dict(acc, avg_cost, stop_fracs, total_cost).
    """
    n = data.n
    m = len(cascade.apis)
    answered = jnp.zeros((n,), bool)
    acc = jnp.zeros((n,), jnp.float32)
    cost = jnp.zeros((n,), jnp.float32)
    stop_fracs = []
    for j, a in enumerate(cascade.apis):
        cost = cost + jnp.where(answered, 0.0, data.cost[:, a])
        if j < m - 1:
            accept = scores[:, a] >= cascade.thresholds[j]
        else:
            accept = jnp.ones((n,), bool)
        take = (~answered) & accept
        acc = acc + jnp.where(take, data.correct[:, a], 0.0)
        stop_fracs.append(float(take.mean()))
        answered = answered | take
    return {
        "acc": float(acc.mean()),
        "avg_cost": float(cost.mean()),
        "total_cost": float(cost.sum()),
        "stop_fracs": stop_fracs,
    }


def run_online(cascade: Cascade, queries: list, apis: Sequence[Callable],
               scorer: Callable, names: Sequence[str] | None = None) -> dict:
    """Execute the cascade against live tier models.

    apis[k](list_of_queries) -> (answers, per_query_cost)
    scorer(queries, answers, api_index) -> np.ndarray scores in [0,1]

    Batched tier-by-tier: all pending queries hit tier j together
    (the serving engine's compaction pattern).
    """
    n = len(queries)
    pending = np.arange(n)
    answers = [None] * n
    total_cost = np.zeros(n, np.float64)
    trace = np.full(n, -1, np.int32)
    for j, a in enumerate(cascade.apis):
        if len(pending) == 0:
            break
        qs = [queries[i] for i in pending]
        ans, cost = apis[a](qs)
        total_cost[pending] += np.asarray(cost, np.float64)
        if j < len(cascade.apis) - 1:
            s = np.asarray(scorer(qs, ans, a))
            accept = s >= cascade.thresholds[j]
        else:
            accept = np.ones(len(pending), bool)
        for i_local, i_global in enumerate(pending):
            if accept[i_local]:
                answers[i_global] = ans[i_local]
                trace[i_global] = a
        pending = pending[~accept]
    return {"answers": answers, "cost": total_cost, "stopped_at": trace}
