"""LLM cascade (paper §3 Strategy 3): ordered API list + score thresholds.

There is exactly ONE cascade-execution implementation in this repo:
``execute_cascade``. It runs the tier-by-tier compaction loop — query
tier j with every still-pending query, score the answers, accept the
reliable ones, re-batch the rest to tier j+1 — and every answer, cost
and scorer call is chunked to ``batch_size`` so no tier ever sees an
unbounded batch. The per-tier chunk step itself is ``tier_step``
(invoke + score + accept on one chunk), which the continuous batcher
(``repro.serving.ingress``) reuses so the online admission loop and the
offline executor share one compaction implementation.

The executor is parameterized by backend through ``CascadeTier``:

  * offline replay — ``replay_tiers`` wraps a ``MarketData`` matrix so
    ``evaluate_offline`` (router optimizer, §Repro experiments) replays
    recorded marketplace responses through the same loop;
  * live models   — ``repro.serving`` wraps real tier models (neural
    marketplace APIs or ``GenerationEngine``-backed tiers) and the
    ``ServingPipeline`` adds the completion-cache and prompt-adaptation
    stages in front.

``run_online`` is kept as a thin compatibility wrapper for callable-API
call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.simulate import MarketData


@dataclasses.dataclass(frozen=True)
class Cascade:
    """A learned cascade: API indices L and per-position thresholds tau.

    The last position needs no threshold (it always answers), so
    ``thresholds`` has length len(apis) - 1.
    """

    apis: tuple            # indices into the marketplace (len m)
    thresholds: tuple      # len m-1, floats in [0,1]

    def describe(self, names: Sequence[str]) -> str:
        parts = []
        for j, a in enumerate(self.apis):
            if j < len(self.thresholds):
                parts.append(f"{names[a]} (accept if g>{self.thresholds[j]:.2f})")
            else:
                parts.append(f"{names[a]}")
        return " -> ".join(parts)


@dataclasses.dataclass
class CascadeTier:
    """One cascade stage: a single call returning (answers, costs).

    ``invoke(queries) -> (answers (b,), costs (b,))`` — one call per
    batch chunk, so backends that produce the answer and its cost
    together (a real API response) are never double-charged.
    """

    name: str
    invoke: Callable


def _accept_threshold(dtype, threshold: float):
    """Smallest ``dtype`` value t' with ``(x >= t') == (float64(x) >=
    threshold)`` for every finite x of ``dtype`` — lets the accept rule
    run natively on device scores (typically f32) while staying
    bit-identical to the host float64 comparison: round the threshold
    *up* to the next representable value whenever casting rounded it
    down."""
    t = np.asarray(threshold, dtype)
    if float(t) < float(threshold):
        t = np.nextafter(t, np.asarray(np.inf, dtype))
    return t


def _consume_prefilled(tier: CascadeTier, chunk, prefilled):
    """Merge a speculative pre-invoke into this chunk's (answers, costs).

    ``prefilled`` is ``(mask (b,) bool, answers (b,) object, costs (b,)
    float64)`` aligned row-for-row with ``chunk``: ``mask[i]`` means row
    i's ``tier.invoke`` already ran speculatively (while an earlier tier
    was still decoding) and its answer/cost are in ``answers[i]`` /
    ``costs[i]``. Only the cold rows are invoked now. Exact because tier
    backends are row-wise — the same contract the stream paths already
    rely on for chunk regrouping — so per-row answers and costs do not
    depend on which rows share an invoke."""
    mask, pa, pc = prefilled
    mask = np.asarray(mask, bool)
    if mask.shape != (len(chunk),):
        raise ValueError(f"prefilled mask shape {mask.shape} != "
                         f"({len(chunk)},)")

    def _densify(obj):
        # object array -> native dtype when rows are uniform scalars
        # (np.int32 elements infer int32, not int64); stays object
        # otherwise, and _merge_answers unboxes at fold time either way
        try:
            arr = np.array(obj.tolist())
        except Exception:
            return obj
        return arr if arr.ndim == 1 else obj

    pc = np.asarray(pc, np.float64)
    if mask.all():
        return _densify(pa), pc
    hot = np.flatnonzero(mask)
    cold = np.flatnonzero(~mask)
    ca, cc = tier.invoke(chunk[cold])
    ca = np.asarray(ca)
    a = np.empty(len(chunk), object)
    for i in hot:
        a[i] = pa[i]
    for k, i in enumerate(cold):
        a[i] = ca[k]
    c = np.empty(len(chunk), np.float64)
    c[hot] = pc[hot]
    c[cold] = np.asarray(cc, np.float64)
    return _densify(a), c


def tier_step(tier: CascadeTier, chunk, j: int, *, scorer: Callable,
              threshold: float | None, last: bool, scorer_lock=None,
              device_masks: list | None = None, prefilled=None):
    """One compaction step on ONE chunk: invoke tier j, score, accept.

    This is the single per-tier chunk implementation shared by the
    offline executor (``execute_cascade``), the continuous batcher
    (``repro.serving.ingress``) and the parallel tier scheduler
    (``repro.serving.sched``) — every path routes every tier call
    through here, so the accept rule can never drift between them.

    Returns ``(answers (b,), costs (b,) float64, scores (b,) float64,
    accept (b,) bool)``; ``scores`` are the accept-time reliability
    scores, NaN where the scorer was never consulted — the last tier
    accepts everything without scoring (``threshold`` is ignored).

    Concurrency contract (relied on by ``repro.serving.sched``):
    ``tier_step`` itself keeps no state, so it is safe to run on
    multiple threads provided the *caller* guarantees (a) each tier's
    ``invoke`` is entered by at most one thread at a time — the parallel
    scheduler gives every tier its own worker, so a tier backend
    (e.g. a ``GenerationEngine``) never sees concurrent calls — and
    (b) a ``scorer`` shared across tiers is either thread-safe or
    serialized by passing a ``scorer_lock`` (any context manager).

    ``device_masks`` (optional, a list): when the scorer returns a
    ``jax.Array``, the accept mask is computed *on device* — with the
    threshold rounded so the native-dtype comparison matches the host
    float64 rule exactly (``_accept_threshold``) — and the device mask
    is appended to the list. The on-device cascade executor feeds these
    masks straight into the compaction kernel, removing its last
    host->device round-trip (the host ``accept`` returned here is the
    transfer of that same mask, so bookkeeping cannot drift from it).

    ``prefilled`` (optional): speculative pre-invoke results from an
    idle-tier worker (``_consume_prefilled``) — rows already invoked
    skip the cold ``tier.invoke`` here; scoring, accept, and cost
    charging still run through the identical path below, so speculation
    can only move wall-clock, never answers or charged cost.
    """
    if prefilled is not None:
        a, c = _consume_prefilled(tier, chunk, prefilled)
    else:
        a, c = tier.invoke(chunk)
    a = np.asarray(a)
    c = np.asarray(c, np.float64)
    if last:
        s = np.full(len(chunk), np.nan)
        accept = np.ones(len(chunk), bool)
    else:
        if scorer_lock is not None:
            with scorer_lock:
                raw = scorer(chunk, a, j)
        else:
            raw = scorer(chunk, a, j)
        s = np.asarray(raw, np.float64)
        accept = None
        if device_masks is not None:
            import jax

            if (isinstance(raw, jax.Array)
                    and raw.dtype in (np.float16, np.float32, np.float64)):
                mask = raw >= _accept_threshold(raw.dtype, threshold)
                device_masks.append(mask)
                accept = np.asarray(mask)
        if accept is None:
            accept = s >= threshold
    return a, c, s, accept


#: pending-set compaction modes: "host" is the original numpy boolean
#: indexing; "device"/"pallas" run the gather + prefix-sum on device
#: (repro.kernels.cascade_compact — jnp argsort vs the Pallas kernel),
#: bit-identical to "host" by construction and by the equivalence suite
COMPACT_MODES = ("host", "device", "pallas")


def execute_cascade(tiers: Sequence[CascadeTier], thresholds: Sequence[float],
                    scorer: Callable, queries, *,
                    batch_size: int = 256, entry=None,
                    compact: str = "host", retry=None, breaker=None,
                    clock=None, sleep=None) -> dict:
    """THE cascade executor: tier-by-tier compaction over ``queries``.

    queries: (n, ...) array — rows are whatever the tier backend consumes
    (token matrices for live models, query indices for offline replay).
    scorer(queries_chunk, answers_chunk, tier_pos) -> scores in [0,1].

    ``entry`` (optional, (n,) ints in [0, m)) gives each query's cascade
    *entry position* (the contextual router, ``repro.serving.strategy``):
    query i joins the pending set at tier ``entry[i]`` instead of tier 0,
    never touching the tiers below it. ``entry=None`` keeps the classic
    everything-enters-at-0 cascade bit-identically.

    ``compact`` selects where the pending set lives between tiers:
    ``"host"`` (default) is the original numpy path; ``"device"`` keeps
    the pending indices on device and compacts them with a jitted
    gather + prefix-sum (``repro.kernels.cascade_compact``), so for
    numeric queries the next tier's batch is gathered on device too —
    and when the scorer is jax-native the accept mask is fused on device
    as well (``tier_step`` ``device_masks``), so compaction runs with no
    host round-trip at all; ``"pallas"`` uses the Pallas kernel variant
    of the same step. All three are bit-identical in every output
    (tests/test_placement.py).

    ``retry`` / ``breaker`` (optional, ``repro.serving.resilience``)
    opt the executor into fault tolerance: a ``RetryPolicy`` re-invokes
    chunks that raise ``TierFault`` (bounded attempts, deterministic
    backoff), a ``BreakerConfig`` — or a live ``TierHealth`` shared
    across calls — tracks per-tier availability and skips tiers whose
    circuit is open. Rows whose chunk still fails escalate
    forward with zero charged cost (failover); a row failing at the
    *last* tier resolves to its best-scoring earlier rejected answer
    (``stopped_at`` = that tier) or, with none, an accounted shed
    (``stopped_at = -2``). The result then gains a ``"resilience"``
    counters dict. ``clock``/``sleep`` are injectable for tests; both
    ``None`` (the default, with no retry/breaker) keeps every code path
    structurally identical to the pre-resilience executor.

    All tier and scorer calls are chunked to ``batch_size``. Returns
    dict(answers, cost, stopped_at (cascade position, -1 = unanswered),
    scores (accept-time reliability score, NaN where the scorer was
    never consulted — cache-confidence consumers use this), tier_counts
    (pending per tier), accepted_counts).
    """
    if compact not in COMPACT_MODES:
        raise ValueError(f"unknown compact mode {compact!r}; expected "
                         f"one of {COMPACT_MODES}")
    queries = np.asarray(queries)
    n = queries.shape[0]
    m = len(tiers)
    if len(thresholds) != m - 1:
        raise ValueError(f"need {m - 1} thresholds for {m} tiers, "
                         f"got {len(thresholds)}")
    if entry is not None:
        entry = np.asarray(entry, np.int64).ravel()
        if entry.shape != (n,):
            raise ValueError(f"entry must be ({n},), got {entry.shape}")
        if len(entry) and (entry.min() < 0 or entry.max() >= m):
            raise ValueError(f"entry positions must lie in [0, {m}); got "
                             f"range [{entry.min()}, {entry.max()}]")
    answers = np.empty(n, dtype=object)
    cost = np.zeros(n, np.float64)
    stopped_at = np.full(n, -1, np.int32)
    scores = np.full(n, np.nan)
    pending = (np.arange(n) if entry is None
               else np.flatnonzero(entry == 0))
    # fault tolerance is strictly opt-in: without a retry policy or a
    # breaker config every TierFault propagates (a fault-injected run is
    # *supposed* to crash when nobody asked for resilience) and none of
    # the machinery below is even imported
    resilient = retry is not None or breaker is not None
    health = rmeta = None
    if resilient:
        import time as _time

        from repro.serving.resilience import (TierFault, TierHealth,
                                              invoke_with_retry)
        if clock is None:
            _t0 = _time.perf_counter()
            clock = lambda: _time.perf_counter() - _t0  # noqa: E731
        if sleep is None:
            sleep = _time.sleep
        # breaker may be a BreakerConfig (fresh breakers for this call)
        # or a live TierHealth shared across calls — a repeatedly-invoked
        # executor then *starts* a pass with tiers already tripped open
        # and skips them outright
        health = None
        if breaker is not None:
            health = (breaker if isinstance(breaker, TierHealth)
                      else TierHealth(m, breaker))
            if len(health.breakers) != m:
                raise ValueError(f"TierHealth tracks "
                                 f"{len(health.breakers)} tiers, cascade "
                                 f"has {m}")
        # best-scoring rejected answer per row: the failover fallback
        # when the last tier fails the row
        best_ans = np.empty(n, object)
        best_score = np.full(n, -np.inf)
        best_tier = np.full(n, -1, np.int32)
        rmeta = {"retries": 0, "backoff_s": 0.0, "failovers": 0,
                 "fallback_answers": 0, "shed": 0}

        def _resolve_failed(g: int):
            if best_tier[g] >= 0:
                answers[g] = best_ans[g]
                scores[g] = best_score[g]
                stopped_at[g] = best_tier[g]
                rmeta["fallback_answers"] += 1
            else:
                stopped_at[g] = -2
                rmeta["shed"] += 1
    # on-device compaction: the pending indices (and, for numeric
    # queries, the query matrix) live on device between tiers; the host
    # mirror is refreshed from the device array so bookkeeping (cost
    # scatter, answer scatter) sees the exact same indices
    on_device = compact != "host"
    compact_op = None
    pending_dev = dev_queries = None
    if on_device:
        import jax.numpy as jnp

        from repro.kernels.cascade_compact.ops import compact as compact_op
        backend = "pallas" if compact == "pallas" else "jnp"
        pending_dev = jnp.asarray(pending, jnp.int32)
        if queries.dtype != object:
            dq = jnp.asarray(queries)
            # device-gather only when the round-trip is lossless: with
            # x64 disabled jax would silently downcast int64/float64
            # queries, changing what the tiers see
            dev_queries = dq if dq.dtype == queries.dtype else None
    tier_counts: list[int] = []
    accepted_counts: list[int] = []
    for j, tier in enumerate(tiers):
        if entry is not None and j > 0:
            # late entrants join the survivors, in ascending row order
            # (the same order a tier-0 entry would have seen them)
            pending = np.sort(np.concatenate(
                [pending, np.flatnonzero(entry == j)]))
            if on_device:
                pending_dev = jnp.asarray(pending, jnp.int32)
        tier_counts.append(len(pending))
        last = j == m - 1
        if len(pending) == 0:
            accepted_counts.append(0)
            continue
        if health is not None and not health.available(j, clock()):
            # circuit open: the whole pending set skips this tier
            # (forward-only escalation). At the last tier there is no
            # forward — every row resolves via its fallback or sheds.
            accepted_counts.append(0)
            rmeta["failovers"] += len(pending)
            if last:
                for g in pending:
                    _resolve_failed(g)
                pending = pending[:0]
            continue
        qs = (np.asarray(jnp.take(dev_queries, pending_dev, axis=0))
              if dev_queries is not None else queries[pending])
        b = len(pending)
        ans_chunks, cost_chunks, score_chunks, accept_chunks = [], [], [], []
        dev_masks: list = []
        eff_tier, failed = tier, None
        if resilient:
            failed = np.zeros(b, bool)
            if retry is not None:
                def _call(ch, _t=tier, _j=j):
                    fails = [0]

                    def _fail(_attempt, _exc):
                        fails[0] += 1

                    def _waited(w):
                        # credited per backoff, not from the returned
                        # total, so a terminally-failed chunk's wasted
                        # backoff seconds still land in the telemetry
                        rmeta["backoff_s"] += w

                    try:
                        a_, c_, attempts, _ = invoke_with_retry(
                            _t, ch, retry, clock=clock, sleep=sleep,
                            token=_j, on_attempt_fail=_fail,
                            on_backoff=_waited)
                    except TierFault:
                        rmeta["retries"] += max(0, fails[0] - 1)
                        raise
                    rmeta["retries"] += attempts - 1
                    return a_, c_

                eff_tier = CascadeTier(tier.name, _call)
        for i in range(0, b, batch_size):
            chunk = qs[i:i + batch_size]
            if resilient:
                try:
                    a, c, s, acc = tier_step(
                        eff_tier, chunk, j, scorer=scorer,
                        threshold=None if last else thresholds[j],
                        last=last,
                        device_masks=dev_masks if on_device else None)
                except TierFault:
                    # retries exhausted (or no retry policy): the chunk
                    # fails forward — zero charged cost, no score, no
                    # accept; the rows stay pending for the next tier
                    nl = len(chunk)
                    failed[i:i + nl] = True
                    a = np.empty(nl, object)
                    c = np.zeros(nl, np.float64)
                    s = np.full(nl, np.nan)
                    acc = np.zeros(nl, bool)
                    if health is not None:
                        health.record(j, False, clock())
                else:
                    if health is not None:
                        health.record(j, True, clock())
            else:
                a, c, s, acc = tier_step(
                    tier, chunk, j, scorer=scorer,
                    threshold=None if last else thresholds[j], last=last,
                    device_masks=dev_masks if on_device else None)
            ans_chunks.append(a)
            cost_chunks.append(c)
            score_chunks.append(s)
            accept_chunks.append(acc)
        ans = np.concatenate(ans_chunks)
        cost[pending] += np.concatenate(cost_chunks)
        accept = np.concatenate(accept_chunks)
        done = pending[accept]
        scores[done] = np.concatenate(score_chunks)[accept]
        if ans.dtype == object or ans.ndim != 1:
            for i_local, i_global in zip(np.flatnonzero(accept), done):
                answers[i_global] = ans[i_local]
        else:
            answers[done] = ans[accept]
        stopped_at[done] = j
        accepted_counts.append(int(accept.sum()))
        if resilient:
            n_failed = int(failed.sum())
            rmeta["failovers"] += n_failed
            if not last:
                # remember each rejected row's best-scoring answer — the
                # failover fallback if every remaining tier fails it too
                sc = np.concatenate(score_chunks)
                for i_local in np.flatnonzero(~accept & ~failed):
                    g = pending[i_local]
                    if sc[i_local] > best_score[g]:
                        best_score[g] = sc[i_local]
                        best_ans[g] = ans[i_local]
                        best_tier[g] = j
            elif n_failed:
                for g in pending[failed]:
                    _resolve_failed(g)
        if on_device:
            if len(dev_masks) == len(accept_chunks):
                # every chunk's accept mask was fused on device
                # (jax-native scorer): compaction consumes the device
                # masks directly — no host->device mask upload
                keep = (jnp.logical_not(dev_masks[0])
                        if len(dev_masks) == 1 else
                        jnp.logical_not(jnp.concatenate(dev_masks)))
            else:
                keep = jnp.asarray(~accept)
            padded, cnt = compact_op(pending_dev, keep, backend=backend)
            pending_dev = padded[:int(cnt)]   # cnt sync sizes the slice
            # host mirror: the cost/answer scatters above are numpy, so
            # the indices come back each tier — what stays on device is
            # the compaction itself and the next tier's query gather
            pending = np.asarray(pending_dev)
        else:
            pending = pending[~accept]
    try:                                     # densify when answers are scalar
        dense = np.array(answers.tolist())
        answers_arr = dense if dense.ndim == 1 else answers
    except ValueError:                       # heterogeneous answer objects
        answers_arr = answers
    out = {
        "answers": answers_arr,
        "cost": cost,
        "stopped_at": stopped_at,
        "scores": scores,
        "tier_counts": tier_counts,
        "accepted_counts": accepted_counts,
    }
    if resilient:
        if health is not None:
            rmeta["trips"] = health.trips
            rmeta["recoveries"] = health.recoveries
            rmeta["breakers"] = health.snapshot(clock())
        out["resilience"] = rmeta
    return out


def replay_tiers(data: MarketData, apis: Sequence[int]) -> list[CascadeTier]:
    """Offline backend: tiers that replay recorded MarketData responses.

    Queries are row indices into ``data``; tier k's "answer" is the
    recorded correctness bit (so accuracy = mean answer) and its cost is
    the recorded per-query cost.
    """
    correct = np.asarray(data.correct)
    cost = np.asarray(data.cost)

    def make(a: int) -> CascadeTier:
        return CascadeTier(
            data.names[a],
            lambda idx, a=a: (correct[idx, a], cost[idx, a]))

    return [make(a) for a in apis]


def evaluate_offline(cascade: Cascade, data: MarketData, scores) -> dict:
    """Replay a cascade over offline marketplace data (the paper's offline
    methodology). scores: (n, K) reliability scores g(q, a_k).

    Runs through ``execute_cascade`` on the replay backend.
    Returns dict(acc, avg_cost, stop_fracs, total_cost).
    """
    s = np.asarray(scores)
    tiers = replay_tiers(data, cascade.apis)

    def scorer(idx, _ans, j):
        return s[idx, cascade.apis[j]]

    res = execute_cascade(tiers, cascade.thresholds, scorer,
                          np.arange(data.n), batch_size=max(1, data.n))
    acc_per_query = np.asarray(res["answers"], np.float64)
    return {
        "acc": float(acc_per_query.mean()),
        "avg_cost": float(res["cost"].mean()),
        "total_cost": float(res["cost"].sum()),
        "stop_fracs": [c / data.n for c in res["accepted_counts"]],
    }


def run_online(cascade: Cascade, queries: list, apis: Sequence[Callable],
               scorer: Callable, names: Sequence[str] | None = None) -> dict:
    """Execute the cascade against live callable APIs (compat wrapper).

    apis[k](list_of_queries) -> (answers, per_query_cost)
    scorer(queries, answers, api_index) -> np.ndarray scores in [0,1]
    """
    try:
        qarr = np.asarray(queries)
    except ValueError:                   # ragged / heterogeneous queries
        qarr = np.empty(len(queries), dtype=object)
        qarr[:] = queries
    tiers = [CascadeTier(names[a] if names else str(a),
                         lambda qs, a=a: apis[a](list(qs)))
             for a in cascade.apis]

    def pos_scorer(qs, ans, j):
        return scorer(list(qs), ans, cascade.apis[j])

    res = execute_cascade(tiers, cascade.thresholds, pos_scorer, qarr,
                          batch_size=max(1, len(queries)))
    # map cascade positions back to marketplace API indices
    trace = np.full(len(queries), -1, np.int32)
    for j, a in enumerate(cascade.apis):
        trace[res["stopped_at"] == j] = a
    return {"answers": list(res["answers"]), "cost": res["cost"],
            "stopped_at": trace}
