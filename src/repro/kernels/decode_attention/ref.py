"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_ref(q, k, v, length):
    """q: (B, KVH, G, d); k/v: (B, S, KVH, d). Returns (B, KVH, G, dv)."""
    s = k.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(s) < length
    logits = jnp.where(valid[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
