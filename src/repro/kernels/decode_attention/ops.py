"""jit'd wrapper for the decode-attention kernel."""
from __future__ import annotations

from repro.kernels.decode_attention.kernel import decode_attention


def gqa_decode(q, k, v, length, *, bk: int = 512, interpret: bool = True):
    """q: (B, 1, H, d) single-token query; k/v: (B, S, KVH, d).

    Returns (B, 1, H, dv)."""
    b, one, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    o = decode_attention(qg, k, v, length, bk=bk, interpret=interpret)
    return o.reshape(b, 1, h, v.shape[-1])
