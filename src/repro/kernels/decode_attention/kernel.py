"""Decode attention Pallas TPU kernel: one query token vs a long KV cache.

GQA layout: each program handles one (batch, kv_head) pair; the q-group
dim (queries per kv head) rides in the block's leading axis so the MXU
sees a (G, d) x (d, bk) matmul per block. Online softmax across kv
blocks, state in VMEM scratch. The cache validity horizon ``length`` is
a scalar-prefetch style operand (here: masked by absolute position).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import SMEM, tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, bk: int, nk: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (g, d)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], bk), 1)
    valid = kpos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    v = v_ref[0, :, 0].astype(jnp.float32)           # (bk, dv)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, length, *, bk: int = 512,
                     interpret: bool = True):
    """q: (B, KVH, G, d); k/v: (B, S, KVH, d); length: scalar valid-length.

    Returns (B, KVH, G, dv)."""
    b, kvh, g, d = q.shape
    s = k.shape[1]
    dv = v.shape[-1]
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)
    nk = s // bk
    scale = 1.0 / (d ** 0.5)
    length_arr = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec(memory_space=SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, kj: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, hi, kj: (bi, kj, hi, 0)),
            pl.BlockSpec((1, bk, 1, dv), lambda bi, hi, kj: (bi, kj, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda bi, hi, kj: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, dv), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length_arr, q, k, v)
