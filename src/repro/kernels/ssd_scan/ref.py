"""Sequential-recurrence oracle for the SSD kernel (independent of the
chunked formulation — a plain O(S) state-space scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, bm, cm):
    """x: (B, S, H, P); dt: (B, S, H); a: (H,); bm/cm: (B, S, N).

    h_t = exp(a*dt_t) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t . h_t
    """
    b, s, h, p = x.shape
    n = bm.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                         # (b,h,p),(b,h),(b,n),(b,n)
        da = jnp.exp(dtt * a)                         # (b,h)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        state = da[..., None, None] * state + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cm.astype(jnp.float32), 1, 0))
    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
