"""jit'd wrapper: SSD scan with the D skip-connection term."""
from __future__ import annotations

from repro.kernels.ssd_scan.kernel import ssd_scan


def ssd(x, dt, a, bm, cm, d=None, *, chunk: int = 256,
        interpret: bool = True):
    """Full SSD mixer core: y = SSD(x, dt, A, B, C) [+ D * x]."""
    y = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=interpret)
    if d is not None:
        y = y + d[:, None] * x
    return y
