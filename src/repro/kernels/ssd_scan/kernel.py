"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

One program per (batch, head); the chunk grid dim is 'arbitrary' and the
SSM state (P, N) persists in VMEM scratch across chunks — the TPU
adaptation of the SSD algorithm: the intra-chunk quadratic part is a
(Q, Q) MXU matmul, the inter-chunk recurrence is the scratch carry, so
no sequential scan ever leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                q: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)           # (q, p)
    dt = dt_ref[0, 0, 0, :, 0].astype(jnp.float32)   # (q,)
    a = a_ref[0]                                     # scalar A (negative)
    bm = b_ref[0, 0, 0].astype(jnp.float32)          # (q, n)
    cm = c_ref[0, 0, 0].astype(jnp.float32)          # (q, n)

    xdt = x * dt[:, None]
    da = dt * a                                      # (q,)
    da_cs = jnp.cumsum(da)                           # inclusive
    da_sum = da_cs[-1]

    # intra-chunk: L[i, j] = exp(da_cs[i] - da_cs[j]) for i >= j
    li = da_cs[:, None] - da_cs[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.exp(jnp.where(iota_i >= iota_j, li, -jnp.inf))
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot(scores * l_mat, xdt,
                         preferred_element_type=jnp.float32)

    # off-chunk: contribution of the state entering this chunk
    state = state_ref[...]                           # (p, n)
    y_off = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.exp(da_cs)[:, None]          # decay within chunk
    y_ref[0, 0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # update state: decay old state through the chunk + inject chunk inputs
    decay_end = jnp.exp(da_sum - da_cs)              # (q,)
    upd = jax.lax.dot_general(xdt * decay_end[:, None], bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (p, n)
    state_ref[...] = jnp.exp(da_sum) * state + upd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, bm, cm, *, chunk: int = 256, interpret: bool = True):
    """x: (B, S, H, P); dt: (B, S, H); a: (H,); bm/cm: (B, S, N).

    Returns y: (B, S, H, P) = SSD(x*dt) without the D skip term."""
    b, s, h, p = x.shape
    n = bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # pre-chunk the operands: (B, H, NC, Q, ...)
    xr = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, nc, chunk, 1)
    br = bm.reshape(b, 1, nc, chunk, n)
    cr = cm.reshape(b, 1, nc, chunk, n)

    kernel = functools.partial(_ssd_kernel, q=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, cj: (bi, hi, cj, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1),
                         lambda bi, hi, cj: (bi, hi, cj, 0, 0)),
            pl.BlockSpec((1,), lambda bi, hi, cj: (hi,)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bi, hi, cj: (bi, 0, cj, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bi, hi, cj: (bi, 0, cj, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p),
                               lambda bi, hi, cj: (bi, hi, cj, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xr, dtr, a, br, cr)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
