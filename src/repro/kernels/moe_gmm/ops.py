"""jit'd wrapper: gated-MLP expert compute via grouped matmuls."""
from __future__ import annotations

import jax

from repro.kernels.moe_gmm.kernel import gmm


def expert_mlp(x, w_gate, w_up, w_down, *, interpret: bool = True):
    """x: (E, C, d); w_*: (E, d, f)/(E, f, d). SwiGLU expert FFN."""
    g = gmm(x, w_gate, interpret=interpret)
    u = gmm(x, w_up, interpret=interpret)
    h = (jax.nn.silu(g.astype(jax.numpy.float32)) *
         u.astype(jax.numpy.float32)).astype(x.dtype)
    return gmm(h, w_down, interpret=interpret)
