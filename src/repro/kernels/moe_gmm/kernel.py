"""Grouped (per-expert) matmul Pallas TPU kernel for MoE expert compute.

Computes out[e] = x[e] @ w[e] for all experts with one kernel launch:
grid = (E, C_blocks, F_blocks, K_blocks), fp32 accumulation in VMEM
scratch across the contraction grid dim. Block shapes are MXU-aligned
(128x128 tiles by default).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)                 # (bc, bk)
    w = w_ref[0].astype(jnp.float32)                 # (bk, bf)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kj == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bk", "interpret"))
def gmm(x, w, *, bc: int = 128, bf: int = 128, bk: int = 128,
        interpret: bool = True):
    """x: (E, C, K); w: (E, K, F) -> (E, C, F)."""
    e, c, k = x.shape
    f = w.shape[-1]
    bc, bf, bk = min(bc, c), min(bf, f), min(bk, k)
    assert c % bc == 0 and f % bf == 0 and k % bk == 0, (c, f, k, bc, bf, bk)
    grid = (e, c // bc, f // bf, k // bk)

    kernel = functools.partial(_gmm_kernel, nk=k // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda ei, ci, fi, kj: (ei, ci, kj)),
            pl.BlockSpec((1, bk, bf), lambda ei, ci, fi, kj: (ei, kj, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ei, ci, fi, kj: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
