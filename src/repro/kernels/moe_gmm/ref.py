"""Pure-jnp oracle for the grouped matmul."""
import jax.numpy as jnp


def gmm_ref(x, w):
    """x: (E, C, K); w: (E, K, F) -> (E, C, F)."""
    return jnp.einsum("eck,ekf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
