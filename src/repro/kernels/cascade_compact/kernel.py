"""Pallas TPU kernel: pending-set compaction for the cascade executor.

Block-sequential formulation: the index vector is processed in blocks of
``block`` rows (grid dim, sequential), with the running survivor count
carried in SMEM scratch — so memory is O(n + block^2), not O(n^2), and
a 100k-row pending set never materializes a 100k x 100k select matrix.
Per block the step is TPU-friendly prefix-sum + gather:

  * ``pos = cumsum(keep) - 1`` assigns every kept row its slot within
    the block;
  * a 0/1 select matrix ``sel[i, k] = keep[i] & (pos[i] == k)`` turns
    the block gather into a single MXU matmul — no scatter and no sort,
    the two primitives TPU Pallas handles worst;
  * the block's compacted rows are stored at the running base offset
    (``pl.ds`` dynamic store). The padded tail of each store is garbage
    that the NEXT block overwrites (the grid is sequential); whatever
    garbage survives past the total count is masked with ``fill`` by
    the wrapper.

Bit-exact against ``ref.compact_ref`` (int32 arithmetic throughout) —
the equivalence suite (tests/test_placement.py) relies on that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compact_kernel(idx_ref, keep_ref, out_ref, base_ref, *, q: int):
    bk = pl.program_id(0)

    @pl.when(bk == 0)
    def _init():
        base_ref[0] = 0

    idx = idx_ref[...]                               # (1, q) int32 block
    keep = keep_ref[...] != 0                        # (1, q)
    ki = keep.astype(jnp.int32)
    pos = jnp.cumsum(ki, axis=1) - 1                 # slot within block
    local = jnp.sum(ki)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    sel = (keep[0][:, None] & (pos[0][:, None] == cols)).astype(jnp.int32)
    gathered = jax.lax.dot_general(idx, sel, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)
    base = base_ref[0]
    pl.store(out_ref, (slice(None), pl.ds(base, q)), gathered)
    base_ref[0] = base + local


@functools.partial(jax.jit,
                   static_argnames=("fill", "interpret", "block"))
def compact_pallas(idx, keep, *, fill: int = -1, interpret: bool = True,
                   block: int = 256):
    """idx (n,) int32, keep (n,) bool -> (padded (n,) int32, count).

    ``padded[:count]`` are the kept indices in original order; the tail
    is ``fill``. ``block`` is the per-grid-step row count (the select
    matrix is block x block).
    """
    n = idx.shape[0]
    q = min(block, max(n, 1))
    nb = -(-n // q)                                  # ceil blocks
    n_pad = nb * q
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, n_pad - n))
    keep_p = jnp.pad(keep, (0, n_pad - n))           # pad rows: keep=False
    kernel = functools.partial(_compact_kernel, q=q)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, q), lambda b: (0, b)),
                  pl.BlockSpec((1, q), lambda b: (0, b))],
        # the output is revisited whole by every block: each stores its
        # compacted rows at the running offset; one trailing block of
        # slack keeps the fixed-width dynamic store in bounds
        out_specs=pl.BlockSpec((1, n_pad + q), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad + q), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(idx_p[None, :], keep_p[None, :].astype(jnp.int32))
    count = jnp.sum(keep.astype(jnp.int32))
    lane = jnp.arange(n, dtype=jnp.int32)
    return jnp.where(lane < count, out[0, :n], fill), count
