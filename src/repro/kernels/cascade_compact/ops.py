"""Dispatch wrapper: on-device pending-set compaction for the cascade.

Two device backends behind one call, both bit-identical to the numpy
oracle (``ref.compact_ref``):

  * ``backend="jnp"``    — a jitted stable-argsort formulation (kept
    rows keep their original relative order; sort keys are distinct so
    the result is deterministic on every XLA backend);
  * ``backend="pallas"`` — the Pallas kernel (``kernel.compact_pallas``,
    interpret mode on CPU, compiled on real TPUs) alongside the repo's
    other kernel families.

Fixed output shape (padded to the input length, ``fill`` in the tail)
keeps both variants jittable; the true length comes back as a scalar
alongside, so callers that can stay on device slice there (callers that
also need the indices on host — the cascade executor's bookkeeping
scatters do — still pull the compacted vector back).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cascade_compact.kernel import compact_pallas

BACKENDS = ("jnp", "pallas")


@functools.partial(jax.jit, static_argnames=("fill",))
def _compact_jnp(idx, keep, fill: int):
    n = idx.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    # stable partition: kept rows sort by original position, rejected
    # rows sort after every kept one — keys are distinct ints, so the
    # argsort (and therefore the result) is fully deterministic
    order = jnp.argsort(jnp.where(keep, iota, n + iota))
    count = jnp.sum(keep.astype(jnp.int32))
    out = jnp.where(iota < count, idx.astype(jnp.int32)[order], fill)
    return out, count


def compact(idx, keep, *, backend: str = "jnp", fill: int = -1,
            interpret: bool | None = None, block: int = 256):
    """idx (n,), keep (n,) bool -> (padded (n,) int32 device array,
    count int32 scalar). ``padded[:count]`` are the kept indices in
    original order. ``interpret=None`` auto-selects: the Pallas
    interpreter everywhere except a real TPU backend, where the kernel
    compiles; ``block`` is the Pallas kernel's per-grid-step row count.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown compaction backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    idx = jnp.asarray(idx)
    keep = jnp.asarray(keep, bool)
    if idx.shape != keep.shape or idx.ndim != 1:
        raise ValueError(f"idx/keep must be matching 1-D vectors, got "
                         f"{idx.shape} and {keep.shape}")
    if idx.shape[0] == 0:
        return idx.astype(jnp.int32), jnp.int32(0)
    if backend == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return compact_pallas(idx, keep, fill=fill, interpret=interpret,
                              block=block)
    return _compact_jnp(idx, keep, fill)
