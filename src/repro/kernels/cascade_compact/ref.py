"""Numpy oracle for the cascade pending-set compaction step.

After a tier's accept decision, the cascade keeps the rejected rows (in
their original order) as the next tier's pending set. The reference is
plain boolean indexing — the exact host-side operation
``execute_cascade`` has always performed — padded to the input length so
the fixed-shape device variants (``ops.compact``) can be compared
bit-for-bit: ``out[:count] == idx[keep]`` and ``out[count:] == fill``.
"""
from __future__ import annotations

import numpy as np


def compact_ref(idx: np.ndarray, keep: np.ndarray,
                fill: int = -1) -> tuple[np.ndarray, int]:
    """idx (n,) int, keep (n,) bool -> (padded (n,) int, count).

    ``padded[:count]`` are ``idx``'s kept entries in original order;
    the tail is ``fill``.
    """
    idx = np.asarray(idx)
    keep = np.asarray(keep, bool)
    kept = idx[keep]
    out = np.full(idx.shape, fill, idx.dtype)
    out[:len(kept)] = kept
    return out, int(len(kept))
