"""Pallas TPU kernels (kernel.py + ops.py wrapper + ref.py oracle each).

``enable_kernels(True)`` routes the model stack's hot paths through the
kernels (interpret mode on CPU — used by the integration tests; compiled
on real TPUs). Default off: the pure-jnp reference path is the oracle
and the dry-run path (Pallas cannot lower on the CPU dry-run backend).
"""
_ENABLED = False


def enable_kernels(on: bool = True):
    global _ENABLED
    _ENABLED = on


def kernels_enabled() -> bool:
    return _ENABLED
