"""Version-compat shims shared by the Pallas kernel family.

JAX renamed the TPU compiler-params dataclass: newer releases expose
``pltpu.CompilerParams``, while the pinned toolchain here still ships
``pltpu.TPUCompilerParams``. Every kernel builds its params through
:func:`tpu_compiler_params` so the rename is absorbed in ONE place
instead of four `try/except` blocks that drift apart.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

#: the TPU compiler-params class under whichever name this JAX exports
TPUCompilerParams = getattr(pltpu, "CompilerParams", None)
if TPUCompilerParams is None:
    TPUCompilerParams = pltpu.TPUCompilerParams

#: the TPU memory-space enum went through the same rename
TPUMemorySpace = getattr(pltpu, "MemorySpace", None)
if TPUMemorySpace is None:
    TPUMemorySpace = pltpu.TPUMemorySpace

#: scalar-prefetch memory space for BlockSpec(memory_space=...)
SMEM = TPUMemorySpace.SMEM


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params (``dimension_semantics=...`` etc.)
    against whichever class name the installed JAX exposes."""
    return TPUCompilerParams(**kwargs)
