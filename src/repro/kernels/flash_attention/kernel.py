"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax blocked attention with causal and sliding-window masking.
Grid = (batch*kv_heads*q_groups, n_q_blocks, n_kv_blocks); the kv-block
grid dim is 'arbitrary' so running max / denominator / accumulator
persist in VMEM scratch across kv blocks (the TPU analogue of the GPU
flash-attention inner loop — no warp shuffles, per-block VREG reductions
instead). Block shapes are MXU-aligned (multiples of 128 where the
problem allows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)                 # (bk, dv)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (BH, S, d); k/v: (BH, S, d) (GQA pre-broadcast by ops.py).

    Returns (BH, S, dv)."""
    bh, s, d = q.shape
    dv = v.shape[-1]
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),   # running accumulator
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
