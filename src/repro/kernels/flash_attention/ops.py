"""jit'd public wrapper: GQA-aware flash attention entry point."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


def mha(q, k, v, *, causal: bool = True, window: int = 0,
        interpret: bool = True, bq: int = 128, bk: int = 128):
    """q: (B, S, H, d); k/v: (B, S, KVH, d). Returns (B, S, H, dv).

    KV heads are broadcast to query heads (GQA) before the kernel; the
    TPU kernel then runs one (batch*head) program per grid row.
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    dv = v.shape[-1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, dv)
    o = flash_attention(qf, kf, vf, causal=causal, window=window,
                        interpret=interpret, bq=bq, bk=bk)
    return o.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
