"""Pure-jnp oracle for the flash-attention kernel: full softmax."""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q/k/v: (BH, S, d). Full (quadratic) masked softmax attention."""
    s = q.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
