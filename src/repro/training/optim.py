"""AdamW + cosine schedule + global-norm clipping, as pure pytree ops."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup))
    prog = jnp.clip((step - cfg.warmup) / max(1, cfg.total_steps - cfg.warmup),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = state["step"] + 1
    lr = schedule(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        wd = cfg.weight_decay * p if p.ndim >= 2 else 0.0  # no decay on norms
        return p - lr * (delta + wd), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
