"""Flat-npz checkpointing with a JSON manifest (no external deps)."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path: str, tree, meta: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    struct = jax.tree.map(lambda _: 0, tree)
    man = {"structure": _describe(tree), "meta": meta or {}}
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(man, f, indent=1, default=str)


def _describe(tree):
    if isinstance(tree, dict):
        return {k: _describe(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_describe(v) for v in tree]
    a = np.asarray(tree)
    return {"shape": list(a.shape), "dtype": str(a.dtype)}


def load(path: str, like):
    """Load into the structure of ``like`` (a template pytree)."""
    z = np.load(path if path.endswith(".npz") else path + ".npz")
    flat = dict(z)

    def rebuild(tmpl, prefix=""):
        if isinstance(tmpl, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tmpl.items()}
        if isinstance(tmpl, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tmpl)]
            return type(tmpl)(vals)
        return jnp.asarray(flat[prefix[:-1]])

    return rebuild(like)
