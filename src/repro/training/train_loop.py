"""Training loops: classifier (marketplace APIs / scorer) and LM."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import synthetic
from repro.models.classifier import classifier_logits, init_classifier
from repro.models.transformer import forward_train
from repro.training.optim import OptConfig, adamw_update, init_opt_state


def _xent(logits, labels):
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def train_classifier(cfg: ModelConfig, n_classes: int, *, task: str | None = None,
                     data_fn=None, steps: int = 300, batch: int = 64,
                     seq_len: int = 64, seed: int = 0,
                     opt: OptConfig | None = None, log_every: int = 0):
    """Train a classifier; data from the synthetic task or a custom
    ``data_fn(step) -> (tokens, labels)``. Returns (params, history)."""
    opt = opt or OptConfig(lr=1e-3, warmup=20, total_steps=steps)
    key = jax.random.PRNGKey(seed)
    params = init_classifier(key, cfg, n_classes)
    state = init_opt_state(params)

    @jax.jit
    def step_fn(params, state, tokens, labels):
        def loss_fn(p):
            logits = classifier_logits(p, tokens, cfg)
            return _xent(logits, labels), logits
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, state, om = adamw_update(opt, params, grads, state)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return params, state, {"loss": loss, "acc": acc, **om}

    hist = []
    for i in range(steps):
        if data_fn is not None:
            toks, labels = data_fn(i)
        else:
            b = synthetic.sample(task, batch, seq_len=seq_len,
                                 seed=seed * 100_003 + i)
            toks, labels = b.tokens, b.labels
        params, state, m = step_fn(params, state, jnp.asarray(toks),
                                   jnp.asarray(labels))
        hist.append({k: float(v) for k, v in m.items()})
        if log_every and (i + 1) % log_every == 0:
            print(f"  step {i+1}: loss={hist[-1]['loss']:.3f} "
                  f"acc={hist[-1]['acc']:.3f}")
    return params, hist


def eval_classifier(params, cfg: ModelConfig, tokens, labels,
                    batch: int = 256):
    """Accuracy + predictions on a fixed set."""
    n = tokens.shape[0]
    preds = []
    fn = jax.jit(functools.partial(classifier_logits, cfg=cfg))
    for i in range(0, n, batch):
        logits = fn(params, jnp.asarray(tokens[i:i + batch]))
        preds.append(np.asarray(jnp.argmax(logits, -1)))
    preds = np.concatenate(preds)
    return float((preds == np.asarray(labels)).mean()), preds


def train_lm(cfg: ModelConfig, *, data_fn, steps: int = 100,
             opt: OptConfig | None = None, seed: int = 0, log_every: int = 0,
             remat: bool = True):
    """Generic LM trainer (used by the e2e example and distillation)."""
    from repro.models.transformer import init_params
    opt = opt or OptConfig(lr=3e-4, warmup=max(1, steps // 10),
                           total_steps=steps)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    state = init_opt_state(params)

    @jax.jit
    def step_fn(params, state, batch):
        def loss_fn(p):
            loss, metrics = forward_train(p, batch, cfg, remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, state, om = adamw_update(opt, params, grads, state)
        return params, state, {**metrics, **om}

    hist = []
    t0 = time.time()
    for i in range(steps):
        batch = data_fn(i)
        batch = jax.tree.map(jnp.asarray, batch)
        params, state, m = step_fn(params, state, batch)
        hist.append({k: float(v) for k, v in m.items()})
        if log_every and (i + 1) % log_every == 0:
            dt = (time.time() - t0) / (i + 1)
            print(f"  step {i+1}: loss={hist[-1]['loss']:.3f} "
                  f"({dt*1e3:.0f} ms/step)")
    return params, hist
