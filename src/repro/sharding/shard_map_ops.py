"""Explicit shard_map collectives for the serving data plane.

GSPMD handles the seq-sharded decode attention implicitly (§Perf C2);
this module is the *explicit* production variant: flash-decode partial
softmax over sequence shards with hand-placed pmax/psum, so the
collective schedule is deterministic rather than propagation-dependent.
Used by the launcher when ``--explicit-collectives`` is set; validated
against the single-device oracle in tests/test_shard_map_ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def flash_decode_sharded(q, k, v, length, mesh, *, seq_axis: str = "model"):
    """Decode attention with the KV cache sequence-sharded over
    ``seq_axis``: each shard computes a partial softmax over its local
    keys; pmax/psum combine the partials (one scalar-sized collective
    per head instead of gathering the cache).

    q: (B, KVH, G, D) replicated over seq_axis
    k/v: (B, S, KVH, D) sharded on dim 1
    length: scalar valid length. Returns (B, KVH, G, D).
    """
    n_shards = mesh.shape[seq_axis]
    s = k.shape[1]
    assert s % n_shards == 0, (s, n_shards)
    s_loc = s // n_shards
    scale = 1.0 / (q.shape[-1] ** 0.5)

    def local(q, k, v, length):
        # k/v here are the LOCAL shard (B, s_loc, KVH, D)
        idx = jax.lax.axis_index(seq_axis)
        kpos = idx * s_loc + jnp.arange(s_loc)
        logits = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        valid = kpos < length
        logits = jnp.where(valid[None, None, None], logits, NEG_INF)
        m_loc = jnp.max(logits, axis=-1)                  # (b,h,g)
        p = jnp.exp(logits - m_loc[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
        # combine partial softmaxes across sequence shards
        m_glob = jax.lax.pmax(m_loc, seq_axis)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * corr, seq_axis)
        o_glob = jax.lax.psum(o_loc * corr[..., None], seq_axis)
        return (o_glob / jnp.maximum(l_glob, 1e-30)[..., None]).astype(q.dtype)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, seq_axis, None, None),
                  P(None, seq_axis, None, None), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(q, k, v, jnp.asarray(length, jnp.int32))


def expert_parallel_ffn(xg, w_gate, w_up, w_down, mesh, *,
                        expert_axis: str = "model"):
    """Explicit expert-parallel gated FFN: experts sharded over
    ``expert_axis``; each shard runs only its local experts (no
    cross-shard traffic here — dispatch/combine gathers live outside).

    xg: (B, E, C, d) dispatched tokens; w_*: (E, d, f) / (E, f, d).
    """
    def local(xg, wg, wu, wd):
        # all operands local: (B, E_loc, C, d), (E_loc, d, f)
        g = jnp.einsum("becd,edf->becf", xg, wg,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("becd,edf->becf", xg, wu,
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xg.dtype)
        return jnp.einsum("becf,efd->becd", h, wd,
                          preferred_element_type=jnp.float32).astype(xg.dtype)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, expert_axis, None, None),
                  P(expert_axis, None, None),
                  P(expert_axis, None, None),
                  P(expert_axis, None, None)),
        out_specs=P(None, expert_axis, None, None),
        check_rep=False,
    )
    return fn(xg, w_gate, w_up, w_down)
