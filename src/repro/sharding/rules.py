"""Partition-spec rules: param / optimizer / cache / batch shardings.

Logical mapping (DESIGN.md §5):
  vocab, attention heads, FFN hidden, MoE expert axis, mamba inner dim
      -> "model"
  batch -> ("pod", "data"); batch==1 decode -> sequence over "data"
  train mode additionally FSDP-shards the largest replicated dim of every
  weight over "data" (ZeRO-style; serving keeps params data-replicated).

Axes are only sharded when divisible by the mesh axis size (e.g. gemma3's
4 query heads stay replicated on a 16-wide model axis).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import axis_size, data_axes


def _div(n: int, size: int) -> bool:
    return size > 1 and n % size == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path: str, shape: tuple, mesh, *, fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf, by path + shape."""
    m = axis_size(mesh, "model")
    stacked = "period/" in path or path.startswith("period")
    dims = list(shape[1:]) if stacked else list(shape)
    spec: list = [None] * len(dims)

    def ok(i):                      # dim i divisible by model axis
        return _div(dims[i], m)

    leaf = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    if leaf == "tok" or (parent == "embed" and leaf == "pos"):
        if leaf == "tok" and ok(0):
            spec[0] = "model"                      # (V, d) vocab-sharded
    elif leaf == "unembed":
        if ok(1):
            spec[1] = "model"                      # (d, V)
    elif leaf in ("wq", "wk", "wv"):               # (d, H, hd)
        if ok(1):
            spec[1] = "model"
    elif leaf == "wo":                             # (H, hd, d)
        if ok(0):
            spec[0] = "model"
    elif leaf in ("wuq", "wuk", "wuv"):            # MLA (r, H, hd)
        if ok(1):
            spec[1] = "model"
    elif leaf in ("up", "gate", "down") and len(dims) == 3:
        if ok(0):
            spec[0] = "model"                      # MoE experts (E, d, f)
    elif leaf in ("sh_up", "sh_gate"):             # shared experts (d, f)
        if ok(1):
            spec[1] = "model"
    elif leaf == "sh_down":                        # (f, d)
        if ok(0):
            spec[0] = "model"
    elif leaf == "w" and len(dims) == 2:
        # dense mlp / head: (d, f) or (f, d) — shard the wider dim
        if "up" in path or "gate" in path:
            if ok(1):
                spec[1] = "model"
        elif "down" in path:
            if ok(0):
                spec[0] = "model"
    elif leaf in ("z_proj", "x_proj"):             # (d, d_in)
        if ok(1):
            spec[1] = "model"
    elif leaf == "out_proj":                       # (d_in, d)
        if ok(0):
            spec[0] = "model"
    elif leaf == "conv_x_w":                       # (k, d_in)
        if ok(1):
            spec[1] = "model"
    elif leaf in ("conv_x_b", "norm") and len(dims) == 1:
        if ok(0):
            spec[0] = "model"
    elif leaf in ("A_log", "D", "dt_bias"):
        if ok(0):
            spec[0] = "model"

    if fsdp:
        d = axis_size(mesh, "data")
        # ZeRO-style: shard the largest still-replicated dim over "data"
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if spec[i] is None and _div(dims[i], d) and dims[i] >= 1024:
                spec[i] = "data"
                break

    if stacked:
        spec = [None] + spec
    return P(*spec)


def params_shardings(params_shapes, mesh, *, fsdp: bool = False):
    """Map a params (or optimizer-state) shape pytree to NamedShardings."""

    def fn(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, param_spec(ps, leaf.shape, mesh, fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(fn, params_shapes)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------


def batch_spec(shape: tuple, mesh, *, batch_dim: int = 0) -> P:
    """Shard the batch dim over (pod, data) when divisible."""
    dp = data_axes(mesh)
    total = 1
    for a in dp:
        total *= axis_size(mesh, a)
    spec = [None] * len(shape)
    if total > 1 and _div(shape[batch_dim], total):
        spec[batch_dim] = dp if len(dp) > 1 else dp[0]
    return P(*spec)


def batch_shardings(batch_shapes, mesh):
    def fn(path, leaf):
        ps = _path_str(path)
        bd = 1 if ps.startswith("mrope_pos") else 0
        return NamedSharding(mesh, batch_spec(leaf.shape, mesh, batch_dim=bd))

    return jax.tree_util.tree_map_with_path(fn, batch_shapes)


def cache_spec(path: str, shape: tuple, mesh, cfg: ModelConfig) -> P:
    """Decode-cache sharding. Batch over (pod,data); if batch==1, shard
    long sequence dims over "data" (context parallelism); KV heads / mamba
    heads / inner dims over "model" when divisible."""
    m = axis_size(mesh, "model")
    d = axis_size(mesh, "data")
    dp = data_axes(mesh)
    total = 1
    for a in dp:
        total *= axis_size(mesh, a)
    stacked = "period/" in path
    dims = list(shape[1:]) if stacked else list(shape)
    spec: list = [None] * len(dims)
    leaf = path.split("/")[-1]

    batch = dims[0]
    if _div(batch, total):
        spec[0] = dp if len(dp) > 1 else dp[0]

    if leaf in ("k", "v"):                          # (B, S, KVH, hd)
        if _div(dims[2], m):
            spec[2] = "model"                       # heads fill the axis
        elif _div(dims[1], m) and dims[1] >= 8192:
            # heads can't fill "model": shard the sequence instead
            # (flash-decode partial softmax; keeps cache/device bounded)
            spec[1] = "model"
        if spec[0] is None and dims[1] >= 8192:
            # batch==1: additionally spread the sequence over "data"
            if spec[1] == "model" and _div(dims[1], m * d):
                spec[1] = ("data", "model")
            elif spec[1] is None and _div(dims[1], d):
                spec[1] = "data"
    elif leaf in ("ckv", "kr"):                     # MLA (B, S, r)
        if spec[0] is None and _div(dims[1], d) and dims[1] >= 8192:
            spec[1] = "data"
    elif leaf == "ssm":                             # (B, H, P, N)
        if _div(dims[1], m):
            spec[1] = "model"
    elif leaf == "conv_x":                          # (B, k-1, d_in)
        if _div(dims[2], m):
            spec[2] = "model"
    # conv_bc: replicated

    if stacked:
        spec = [None] + spec
    return P(*spec)


def cache_shardings(cache_shapes, mesh, cfg: ModelConfig):
    def fn(path, leaf):
        ps = _path_str(path)
        return NamedSharding(mesh, cache_spec(ps, leaf.shape, mesh, cfg))

    return jax.tree_util.tree_map_with_path(fn, cache_shapes)


def logits_sharding(mesh, cfg: ModelConfig, batch: int):
    dp = data_axes(mesh)
    total = 1
    for a in dp:
        total *= axis_size(mesh, a)
    b = (dp if len(dp) > 1 else dp[0]) if _div(batch, total) else None
    v = "model" if _div(cfg.vocab, axis_size(mesh, "model")) else None
    return NamedSharding(mesh, P(b, None, v))
