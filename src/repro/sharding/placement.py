"""Per-tier device placement for the serving cascade.

The parallel tier scheduler (``repro.serving.sched``) gives every
cascade tier its own worker thread, but all tier models share one
default device, so concurrency is capped by that device's throughput.
This module assigns each tier's model its own ``jax.Device`` so tier
workers dispatch to disjoint devices and chunk decode genuinely
overlaps (ROADMAP "Per-tier devices"; the multi-host pjit mesh of
DESIGN.md §5 is the follow-up — one *local* device per tier is the
single-host rung of that ladder).

Sizing: ``plan_placement`` takes the cascade's observed (or predicted)
per-tier traffic — ``ServeResult.tier_counts`` online, the offline
replay's pending fractions in the builder — and greedily balances
tiers over devices so the busiest tiers get the least-loaded devices
first. Without traffic counts it falls back to round-robin. With fewer
devices than tiers, devices are shared; with one device the plan
degenerates to today's shared-device behaviour — placement can never
change results, only where they are computed (the equivalence suite in
tests/test_placement.py pins that).

Placement is enacted by moving a tier's params with ``place_params``:
jax runs a jitted computation on the device its committed arguments
live on, so pinning the params pins every chunk the tier decodes.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax


@dataclasses.dataclass(frozen=True)
class TierPlacement:
    """A device assignment for one cascade: ``devices[j]`` hosts tier j."""

    devices: tuple                 # one jax.Device per cascade tier
    shares: tuple | None = None    # traffic share the sizing used

    def for_tier(self, j: int):
        return self.devices[j]

    @property
    def n_distinct(self) -> int:
        return len({d.id for d in self.devices})

    def describe(self, names: Sequence[str] | None = None) -> str:
        parts = []
        for j, d in enumerate(self.devices):
            nm = names[j] if names else f"tier{j}"
            share = (f" ({self.shares[j]:.2f})" if self.shares is not None
                     else "")
            parts.append(f"{nm}{share} -> {d.platform}:{d.id}")
        return ", ".join(parts)


def plan_placement(n_tiers: int, devices: Sequence | None = None,
                   tier_counts: Sequence[float] | None = None
                   ) -> TierPlacement:
    """Assign each of ``n_tiers`` cascade tiers a device.

    ``tier_counts`` — queries *reaching* each tier (``ServeResult.
    tier_counts``, or any proportional traffic-share signal): tiers are
    placed heaviest-first onto the device with the least accumulated
    share, so the hot cheap tiers end up alone on a device while the
    rarely-reached top tiers share. ``None`` falls back to round-robin.
    The plan is deterministic (ties break on device order).
    """
    if n_tiers < 1:
        raise ValueError(f"n_tiers must be >= 1, got {n_tiers}")
    devs = list(devices) if devices is not None else list(jax.local_devices())
    if not devs:
        raise ValueError("no devices to place tiers on")
    if tier_counts is not None and len(tier_counts) != n_tiers:
        raise ValueError(f"tier_counts must have {n_tiers} entries, "
                         f"got {len(tier_counts)}")
    if tier_counts is None or sum(tier_counts) <= 0:
        return TierPlacement(tuple(devs[j % len(devs)]
                                   for j in range(n_tiers)))
    total = float(sum(tier_counts))
    shares = [float(c) / total for c in tier_counts]
    load = [0.0] * len(devs)
    assignment: list = [None] * n_tiers
    # heaviest tier first; ties keep ascending tier order (stable sort)
    for j in sorted(range(n_tiers), key=lambda j: -shares[j]):
        d = min(range(len(devs)), key=lambda d: (load[d], d))
        assignment[j] = devs[d]
        load[d] += shares[j]
    return TierPlacement(tuple(assignment), tuple(shares))


def place_params(params, device):
    """Move a tier model's params pytree onto ``device`` (committed), so
    every jitted call over them runs there. No-op placement-wise when
    ``device`` is None."""
    if device is None:
        return params
    return jax.device_put(params, device)
