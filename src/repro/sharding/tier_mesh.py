"""Per-tier mesh slices: the multi-host rung of tier placement.

``sharding.placement`` pinned each cascade tier to a single local
``jax.Device`` — enough to overlap tier workers, not enough to serve a
tier whose params do not fit one chip. This module extends that plan so
each tier gets a contiguous **mesh slice**: a sub-``Mesh`` over >= 1
devices, sized greedily by the same traffic signal ``plan_placement``
uses (``ServeResult.tier_counts`` online, the offline replay's pending
fractions in the builder). The busiest tiers get the widest slices;
every tier always gets at least one device.

Each slice is a standard 2-D mesh with axes ``("data", "model")``:

  * "data"  — batch / FSDP axis. Batch-dim sharding splits independent
    rows across devices, and FSDP param sharding all-gathers exact
    weight values before use, so **data-only slices are bit-identical**
    to the unsharded computation (pinned by tests/test_placement.py's
    sharded legs).
  * "model" — tensor-parallel axis (``sharding.rules`` head/FFN/vocab
    rules). Width defaults to 1 because model-axis matmul reductions
    change float summation order — opt in via ``mesh_shape=(R, C)``
    with C > 1 when capacity matters more than bit-identicality.

Params are sharded by the same ``sharding.rules`` used for training
(FSDP on the scanned ``params["period"]`` stack), and
``init_params_sharded`` initialises them *sharded from birth*: the init
is jitted with the target shardings as ``out_shardings``, so each
device materialises only its own shard — a 70B-class tier never exists
unsharded on one host. jax's threefry PRNG is counter-based and
elementwise, so the values are identical regardless of mesh shape
(pinned by the determinism test).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.sharding import rules


def _mesh_device_ids(mesh) -> tuple:
    return tuple(int(d.id) for d in mesh.devices.flat)


def mesh_desc(mesh) -> str:
    """'2x1@cpu:0,1' — rows x cols @ platform : device ids."""
    r, c = mesh.devices.shape
    plat = mesh.devices.flat[0].platform
    ids = ",".join(str(i) for i in _mesh_device_ids(mesh))
    return f"{r}x{c}@{plat}:{ids}"


@dataclasses.dataclass(frozen=True)
class TierMeshPlan:
    """A mesh-slice assignment for one cascade: ``slices[j]`` hosts tier j."""

    slices: tuple                  # one jax.sharding.Mesh per cascade tier
    shares: tuple | None = None    # traffic share the sizing used
    grid: tuple = (1, 1)           # (rows, cols) of the device grid planned

    def for_tier(self, j: int):
        return self.slices[j]

    @property
    def devices_per_tier(self) -> tuple:
        return tuple(m.devices.size for m in self.slices)

    @property
    def n_distinct(self) -> int:
        """Distinct device *sets* (slices may share rows when the grid
        has fewer rows than the cascade has tiers)."""
        return len({_mesh_device_ids(m) for m in self.slices})

    def describe(self, names: Sequence[str] | None = None) -> str:
        parts = []
        for j, m in enumerate(self.slices):
            nm = names[j] if names else f"tier{j}"
            share = (f" ({self.shares[j]:.2f})" if self.shares is not None
                     else "")
            parts.append(f"{nm}{share} -> {mesh_desc(m)}")
        return ", ".join(parts)


def plan_tier_meshes(n_tiers: int, mesh_shape: tuple | None = None,
                     devices: Sequence | None = None,
                     tier_counts: Sequence[float] | None = None
                     ) -> TierMeshPlan:
    """Assign each of ``n_tiers`` cascade tiers a contiguous mesh slice.

    The available devices form an ``R x C`` grid (``mesh_shape``; default
    ``(len(devices), 1)`` — data-parallel only). Rows are the unit of
    allocation: every tier gets >= 1 whole row (C devices wide on the
    "model" axis), and the remaining rows go to tiers greedily by
    traffic share (highest share-per-row first — D'Hondt apportionment,
    so a tier carrying 90% of the traffic ends up with ~90% of the spare
    rows). Slices are contiguous row ranges in tier order. With fewer
    rows than tiers, tiers wrap round-robin onto shared rows (degenerate
    single-row grid == today's shared-device behaviour). Deterministic:
    ties break on ascending tier index.
    """
    if n_tiers < 1:
        raise ValueError(f"n_tiers must be >= 1, got {n_tiers}")
    if tier_counts is not None and len(tier_counts) != n_tiers:
        raise ValueError(f"tier_counts must have {n_tiers} entries, "
                         f"got {len(tier_counts)}")
    devs = list(devices) if devices is not None else list(jax.local_devices())
    if not devs:
        raise ValueError("no devices to slice tiers over")
    if mesh_shape is None:
        rows_n, cols = len(devs), 1
    else:
        rows_n, cols = int(mesh_shape[0]), int(mesh_shape[1])
    if rows_n < 1 or cols < 1:
        raise ValueError(f"mesh_shape must be positive, got {mesh_shape}")
    if rows_n * cols > len(devs):
        raise ValueError(f"mesh_shape {rows_n}x{cols} needs "
                         f"{rows_n * cols} devices, have {len(devs)}")
    grid = np.array(devs[:rows_n * cols], dtype=object).reshape(rows_n, cols)

    def slice_mesh(r0: int, r1: int) -> Mesh:
        return Mesh(grid[r0:r1], ("data", "model"))

    shares = None
    if tier_counts is not None and sum(tier_counts) > 0:
        total = float(sum(tier_counts))
        shares = tuple(float(c) / total for c in tier_counts)

    if rows_n < n_tiers:
        # fewer rows than tiers: share rows round-robin (contiguous
        # single-row slices), like plan_placement's fallback
        slices = tuple(slice_mesh(j % rows_n, j % rows_n + 1)
                       for j in range(n_tiers))
        return TierMeshPlan(slices, shares, (rows_n, cols))

    counts = [1] * n_tiers                 # every tier gets >= 1 row
    spare = rows_n - n_tiers
    eff = shares if shares is not None else tuple([1.0] * n_tiers)
    for _ in range(spare):
        # D'Hondt: next row to the tier with the highest share per row
        j = max(range(n_tiers), key=lambda j: (eff[j] / counts[j], -j))
        counts[j] += 1
    starts = np.concatenate([[0], np.cumsum(counts)])
    slices = tuple(slice_mesh(int(starts[j]), int(starts[j + 1]))
                   for j in range(n_tiers))
    return TierMeshPlan(slices, shares, (rows_n, cols))


# ---------------------------------------------------------------------------
# Sharding a tier over its slice
# ---------------------------------------------------------------------------


def batch_sharding(mesh, n_rows: int) -> NamedSharding:
    """Batch-dim sharding for a (n_rows, ...) array on a slice —
    replicated when the row count does not divide the data axis (the
    engine's pow2 batch buckets normally do)."""
    d = mesh.shape["data"]
    return NamedSharding(mesh, P("data") if d > 1 and n_rows % d == 0
                         else P())


def tier_param_shardings(params, mesh):
    """NamedShardings for a tier's params on its slice: tensor axes over
    "model" per sharding.rules, FSDP over "data" (exact — FSDP
    all-gathers full values before use)."""
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype)
        if not hasattr(x, "shape") else x, params)
    return rules.params_shardings(shapes, mesh, fsdp=True)


def shard_params(params, mesh):
    """device_put a tier's params onto its slice per the rules shardings
    (the across-slice-boundary transfer when a tier moves slices)."""
    return jax.device_put(params, tier_param_shardings(params, mesh))


def init_params_sharded(key, cfg, mesh, *, fold: bool = True):
    """Initialise a tier's params *sharded from birth* on its slice.

    The init function is jitted with the target shardings as
    ``out_shardings``, so XLA materialises each param directly in its
    sharded layout — no host-side full copy ever exists. The
    partitionable threefry lowering is forced on for the init call:
    it generates bits as a pure elementwise function of the counter,
    so the same (key, cfg) gives bit-identical params on a 1x1 and an
    8x1 slice (tests/test_tier_mesh.py pins this). The legacy lowering
    (jax_threefry_partitionable=False, the 0.4.x default) is NOT
    sharding-invariant — XLA partitions its batched hash loop and each
    shard draws different bits. ``fold=True`` folds homogeneous
    prefix/suffix into the scanned stack first, so the whole depth is
    one FSDP-shardable stacked leaf per weight.
    """
    if fold:
        cfg = T.fold_config(cfg)

    def init(k):
        return T.init_params(k, cfg)

    shapes = jax.eval_shape(init, key)
    shardings = rules.params_shardings(shapes, mesh, fsdp=True)
    prev = jax.config.jax_threefry_partitionable
    jax.config.update("jax_threefry_partitionable", True)
    try:
        params = jax.jit(init, out_shardings=shardings)(key)
    finally:
        jax.config.update("jax_threefry_partitionable", prev)
    return cfg, params
