"""Activation-sharding policy: logical with_sharding_constraint hooks.

Model code calls ``constrain(x, "dp", None, "model")`` with logical axis
names; when a policy mesh is active (the dry-run / production launcher),
this pins the intermediate's sharding so GSPMD propagation cannot wander
into pathological reshards (e.g. all-gathering a 43 GB KV cache to
re-split it over heads — see EXPERIMENTS.md §Perf C1). When no policy is
active (CPU tests, single-device smoke), it is a no-op.

Logical names:
  "dp"    -> the batch axes ("pod","data") — applied only if divisible
  "data"  -> the data axis only
  "model" -> the model axis — applied only if divisible
  None    -> replicated dim
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_ACTIVE_MESH = None


def activate(mesh):
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def deactivate():
    global _ACTIVE_MESH
    _ACTIVE_MESH = None


@contextlib.contextmanager
def policy(mesh):
    activate(mesh)
    try:
        yield
    finally:
        deactivate()


def _axis_size(mesh, name):
    return mesh.shape[name] if name in mesh.axis_names else 1


def constrain(x, *logical, priority=None):
    """Pin x's sharding by logical axis names (no-op without a policy).

    Each dim may name one axis, "dp", or a tuple of axes. Dims claim mesh
    axes in ``priority`` order (default: left-to-right); an axis already
    claimed by a higher-priority dim is dropped for later dims, so e.g.
    KV heads take "model" when they divide it and the sequence dim picks
    it up otherwise.
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    spec = [None] * x.ndim
    order = list(priority) if priority is not None else list(range(x.ndim))
    used: set = set()
    for i in order:
        if i >= len(logical) or logical[i] is None:
            continue
        name = logical[i]
        if name == "dp":
            cand = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        elif isinstance(name, tuple):
            cand = tuple(a for a in name if a in mesh.axis_names)
        else:
            cand = (name,) if name in mesh.axis_names else ()
        axes = tuple(a for a in cand if a not in used)
        # greedily shrink the axis set until it divides the dim
        while axes:
            total = 1
            for a in axes:
                total *= _axis_size(mesh, a)
            if total > 1 and x.shape[i] % total == 0:
                break
            axes = axes[:-1]
        if not axes:
            continue
        total = 1
        for a in axes:
            total *= _axis_size(mesh, a)
        if total <= 1:
            continue
        used.update(axes)
        spec[i] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
