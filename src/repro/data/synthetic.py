"""Synthetic natural-language-like tasks mirroring the paper's datasets.

Each task generates token sequences from a compositional template grammar
with a *known* label function and a tunable difficulty knob (distractor
density, negation), so that models of different capacity land at
heterogeneous accuracies — the neural analogue of the LLM marketplace.

Tasks:
  * headlines  — 4-class commodity-trend classification (HEADLINES)
  * overruling — binary legal overruling detection (OVERRULING)
  * qa         — span-style answer selection over a passage (COQA-like,
                 framed as answer-token prediction)
"""
from __future__ import annotations

import dataclasses

import numpy as np

VOCAB = 512
PAD, CLS, SEP = 0, 1, 2
# token-id regions
UP_TOKENS = list(range(10, 30))        # "surges", "rallies", ...
DOWN_TOKENS = list(range(30, 50))      # "slides", "tumbles", ...
NEUTRAL_TOKENS = list(range(50, 60))   # "steady", "flat"
NEG_TOKENS = list(range(60, 70))       # "despite", "reverses"
OVERRULE_TOKENS = list(range(70, 90))
AFFIRM_TOKENS = list(range(90, 110))
FILLER = list(range(120, VOCAB))
ANSWER_BASE = 200                      # qa answers live in [200, 264)

N_CLASSES = {"headlines": 4, "overruling": 2, "qa": 64}


@dataclasses.dataclass
class TaskBatch:
    tokens: np.ndarray      # (n, L) int32
    labels: np.ndarray      # (n,) int32
    difficulty: np.ndarray  # (n,) float32 in [0,1]


def sample(task: str, n: int, seq_len: int = 64, seed: int = 0) -> TaskBatch:
    rng = np.random.default_rng(seed)
    toks = rng.choice(FILLER, size=(n, seq_len)).astype(np.int32)
    toks[:, 0] = CLS
    labels = np.zeros(n, np.int32)
    diff = rng.uniform(0.0, 1.0, size=n).astype(np.float32)

    if task == "headlines":
        # label: 0=up 1=down 2=neutral 3=none; difficulty adds negations
        for i in range(n):
            lab = rng.integers(0, 4)
            labels[i] = lab
            pos = rng.integers(2, seq_len // 2)
            if lab == 0:
                toks[i, pos] = rng.choice(UP_TOKENS)
            elif lab == 1:
                toks[i, pos] = rng.choice(DOWN_TOKENS)
            elif lab == 2:
                toks[i, pos] = rng.choice(NEUTRAL_TOKENS)
            # difficulty: negation flips the surface signal
            if diff[i] > 0.55 and lab in (0, 1):
                toks[i, pos - 1] = rng.choice(NEG_TOKENS)
                toks[i, rng.integers(seq_len // 2, seq_len)] = rng.choice(
                    UP_TOKENS if lab == 1 else DOWN_TOKENS)
    elif task == "overruling":
        for i in range(n):
            lab = rng.integers(0, 2)
            labels[i] = lab
            pos = rng.integers(2, seq_len - 2)
            toks[i, pos] = rng.choice(OVERRULE_TOKENS if lab else AFFIRM_TOKENS)
            if diff[i] > 0.6:   # distractor from the opposite class
                toks[i, rng.integers(2, seq_len - 2)] = rng.choice(
                    AFFIRM_TOKENS if lab else OVERRULE_TOKENS)
    elif task == "qa":
        # passage contains key->value pairs; question asks for one key's value
        n_pairs = 4
        for i in range(n):
            keys = rng.choice(range(110, 160), size=n_pairs, replace=False)
            vals = rng.integers(0, N_CLASSES["qa"], size=n_pairs)
            for j in range(n_pairs):
                p = 4 + 6 * j
                toks[i, p] = keys[j]
                toks[i, p + 1] = ANSWER_BASE + vals[j]
            qj = rng.integers(0, n_pairs if diff[i] > 0.3 else 1)
            toks[i, seq_len - 2] = SEP
            toks[i, seq_len - 1] = keys[qj]
            labels[i] = vals[qj]
    else:
        raise ValueError(task)
    return TaskBatch(toks, labels, diff)


def append_answer(tokens: np.ndarray, answers: np.ndarray) -> np.ndarray:
    """(query, answer) pairs for the scorer: append SEP + answer token."""
    n, L = tokens.shape
    out = np.concatenate([tokens,
                          np.full((n, 1), SEP, np.int32),
                          (ANSWER_BASE + answers[:, None]).astype(np.int32)],
                         axis=1)
    return out
