"""Attention mixers: GQA (full / sliding-window / encoder) and DeepSeek MLA.

Full-sequence attention is *query-chunked* (flash-style running softmax is
in the Pallas kernel; here we chunk queries so the (q, S) score block stays
bounded — mathematically identical to full softmax). Decode attends one
token against a KV cache; sliding-window layers keep a ring-buffer cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (Params, _normal, apply_mrope, apply_rope,
                                 cast, rmsnorm)
from repro.sharding.policy import constrain

NEG_INF = -1e30


def _kernel_ok(seq: int, block: int) -> bool:
    return seq % block == 0


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ModelConfig) -> Params:
    if cfg.mla is not None:
        m = cfg.mla
        ks = jax.random.split(key, 8)
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "wdq": _normal(ks[0], (cfg.d_model, m.q_lora_rank)),
            "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
            "wuq": _normal(ks[1], (m.q_lora_rank, cfg.n_heads, qk_hd)),
            "wdkv": _normal(ks[2], (cfg.d_model, m.kv_lora_rank)),
            "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
            "wkr": _normal(ks[3], (cfg.d_model, m.qk_rope_head_dim)),
            "wuk": _normal(ks[4], (m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim)),
            "wuv": _normal(ks[5], (m.kv_lora_rank, cfg.n_heads, m.v_head_dim)),
            "wo": _normal(ks[6], (cfg.n_heads, m.v_head_dim, cfg.d_model)),
        }
    ks = jax.random.split(key, 4)
    return {
        "wq": _normal(ks[0], (cfg.d_model, cfg.n_heads, cfg.head_dim)),
        "wk": _normal(ks[1], (cfg.d_model, cfg.n_kv_heads, cfg.head_dim)),
        "wv": _normal(ks[2], (cfg.d_model, cfg.n_kv_heads, cfg.head_dim)),
        "wo": _normal(ks[3], (cfg.n_heads, cfg.head_dim, cfg.d_model)),
    }


def init_attn_cache(cfg: ModelConfig, sliding: bool, batch: int, seq: int,
                    dtype=None):
    """Zeros KV cache for one attention layer.

    Full attention: (B, seq, KVH, hd) K/V. Sliding: ring buffer of
    ``window`` slots. MLA: compressed latent + rope-key cache.
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype),
        }
    s = min(cfg.window, seq) if sliding and cfg.window else seq
    return {
        "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# Core chunked attention (full-sequence modes)
# ---------------------------------------------------------------------------


def _chunked_attention(q, k, v, *, causal: bool, window: int, q_chunk: int = 512):
    """q: (B, S, H, hd); k/v: (B, S, KVH, hd). Returns (B, S, H, vd).

    Queries are processed in chunks; each chunk sees the full key range
    with a causal / sliding mask. GQA handled by head grouping.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    vd = v.shape[-1]
    g = h // kvh
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))
    qc = min(q_chunk, s)
    n_chunks = s // qc
    assert s % qc == 0, (s, qc)

    qr = q.reshape(b, n_chunks, qc, kvh, g, hd)
    qr = jnp.moveaxis(qr, 1, 0)                       # (n, b, qc, kvh, g, hd)
    kpos = jnp.arange(s)

    def body(carry, inp):
        ci, qch = inp                                 # qch: (b, qc, kvh, g, hd)
        qpos = ci * qc + jnp.arange(qc)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qch, k,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((qc, s), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, v,
                       preferred_element_type=jnp.float32)
        return carry, o.astype(v.dtype)

    _, outs = jax.lax.scan(body, 0, (jnp.arange(n_chunks), qr))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, vd)
    return out


def _decode_attention(q, k, v, *, valid_mask):
    """q: (B, 1, H, hd); k/v: (B, Sc, KVH, hd); valid_mask: (Sc,) or (B, Sc)."""
    b, _, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))
    qg = q.reshape(b, kvh, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    vm = valid_mask if valid_mask.ndim == 2 else valid_mask[None]
    logits = jnp.where(vm[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v, preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, v.shape[-1]).astype(v.dtype)


def ring_slot_positions(pos, window: int):
    """Absolute position stored in each ring-buffer slot when the *current*
    write position is ``pos`` (i.e. ``pos`` tokens already written)."""
    i = jnp.arange(window)
    # last p <= pos with p % window == i
    p = pos - jnp.mod(pos - i, window)
    return p  # may be negative => never written


# ---------------------------------------------------------------------------
# GQA apply
# ---------------------------------------------------------------------------


def apply_attn(p: Params, x, *, cfg: ModelConfig, sliding: bool, mode: str,
               positions=None, cache=None, pos=None, q_chunk: int = 512,
               max_len: int = 0):
    """mode: 'train' | 'prefill' | 'decode'.

    positions: rope positions — (B, S) int32, or (3, B, S) for mrope.
    decode: x is (B, 1, d), ``pos`` scalar count of tokens already cached.
    Returns (y, new_cache) — new_cache is None in train mode.
    """
    if cfg.mla is not None:
        return _apply_mla(p, x, cfg=cfg, mode=mode, positions=positions,
                          cache=cache, pos=pos, q_chunk=q_chunk,
                          max_len=max_len)

    b, s, _ = x.shape
    wq, wk, wv = cast(p["wq"], cfg), cast(p["wk"], cfg), cast(p["wv"], cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, wq, preferred_element_type=jnp.float32
                   ).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, wk, preferred_element_type=jnp.float32
                   ).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, wv, preferred_element_type=jnp.float32
                   ).astype(x.dtype)

    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)

    # pin shardings: batch over dp, q heads over model, KV heads only when
    # divisible (constrain() drops non-divisible axes) — stops GSPMD from
    # partially resharding the KV cache over heads (§Perf C1)
    q = constrain(q, "dp", None, "model", None)
    k = constrain(k, "dp", None, "model", None)
    v = constrain(v, "dp", None, "model", None)

    window = cfg.window if sliding else 0

    if mode in ("train", "prefill"):
        o = None
        if _kernel_ok(q.shape[1], 128):
            from repro.kernels import kernels_enabled
            if kernels_enabled():
                from repro.kernels.flash_attention.ops import mha
                o = mha(q, k, v, causal=cfg.causal, window=window,
                        bq=128, bk=128)
        if o is None:
            o = _chunked_attention(q, k, v, causal=cfg.causal, window=window,
                                   q_chunk=q_chunk)
        new_cache = None
        if mode == "prefill":
            if window:
                # ring-buffer cache: position p lives at slot p % cache_len
                cache_len = min(window, max_len) if max_len else min(window, s)
                if s >= cache_len:
                    last = jnp.arange(s - cache_len, s)
                    order = jnp.argsort(jnp.mod(last, cache_len))
                    idx = last[order]
                    new_cache = {"k": k[:, idx], "v": v[:, idx]}
                else:
                    pad = ((0, 0), (0, cache_len - s), (0, 0), (0, 0))
                    new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
            else:
                grow = max(0, max_len - s) if max_len else 0
                pad = ((0, 0), (0, grow), (0, 0), (0, 0))
                new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    else:  # decode
        ck, cv = cache["k"], cache["v"]
        s_c = ck.shape[1]
        if window and s_c <= window:
            slot = jnp.mod(pos, s_c)
            ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
            slot_pos = ring_slot_positions(pos, s_c)
            valid = (slot_pos >= 0) & (slot_pos <= pos)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k, (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, pos, 0, 0))
            valid = jnp.arange(s_c) <= pos
        # cache sharding: heads on "model" when they fill it; otherwise
        # shard the SEQUENCE over "model" (flash-decode style: partial
        # softmax per seq shard + all-reduce) so the per-device cache
        # footprint stays bounded (§Perf C2). batch==1 additionally
        # spreads the sequence over "data".
        seq_axes = ("data", "model") if ck.shape[0] == 1 else ("model",)
        ck = constrain(ck, "dp", seq_axes, "model", None, priority=(0, 2, 1))
        cv = constrain(cv, "dp", seq_axes, "model", None, priority=(0, 2, 1))
        o = None
        if not window and _kernel_ok(s_c, 128):
            from repro.kernels import kernels_enabled
            if kernels_enabled():
                from repro.kernels.decode_attention.ops import gqa_decode
                o = gqa_decode(q, ck, cv, pos + 1, bk=128)
        if o is None:
            o = _decode_attention(q, ck, cv, valid_mask=valid)
        new_cache = {"k": ck, "v": cv}

    wo = cast(p["wo"], cfg)
    y = jnp.einsum("bshk,hkd->bsd", o, wo, preferred_element_type=jnp.float32)
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek) apply — compressed KV cache; absorbed matmuls for decode
# ---------------------------------------------------------------------------


def _apply_mla(p: Params, x, *, cfg: ModelConfig, mode: str, positions, cache,
               pos, q_chunk: int, max_len: int = 0):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    nd, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    # queries
    q_lat = jnp.einsum("bsd,dr->bsr", x, cast(p["wdq"], cfg),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    q_lat = rmsnorm(q_lat, p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, cast(p["wuq"], cfg),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # compressed kv + shared rope key
    ckv = jnp.einsum("bsd,dr->bsr", x, cast(p["wdkv"], cfg),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    ckv = rmsnorm(ckv, p["kv_norm"])
    kr = jnp.einsum("bsd,dr->bsr", x, cast(p["wkr"], cfg),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    scale = 1.0 / jnp.sqrt(jnp.array(nd + rd, jnp.float32))

    if mode in ("train", "prefill"):
        # materialize per-head K (nope) and V from the latent
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, cast(p["wuk"], cfg),
                            preferred_element_type=jnp.float32).astype(x.dtype)
        v = jnp.einsum("bsr,rhk->bshk", ckv, cast(p["wuv"], cfg),
                       preferred_element_type=jnp.float32).astype(x.dtype)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        kfull = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :], (b, s, h, rd))], axis=-1)
        o = _chunked_attention(qfull, kfull, v, causal=True, window=0,
                               q_chunk=q_chunk)
        new_cache = None
        if mode == "prefill":
            grow = max(0, max_len - s) if max_len else 0
            new_cache = {"ckv": jnp.pad(ckv, ((0, 0), (0, grow), (0, 0))),
                         "kr": jnp.pad(kr, ((0, 0), (0, grow), (0, 0)))}
    else:  # decode: absorbed attention against the compressed cache
        c_ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, pos, 0))
        c_kr = jax.lax.dynamic_update_slice(cache["kr"], kr, (0, pos, 0))
        s_c = c_ckv.shape[1]
        valid = jnp.arange(s_c) <= pos
        # absorb W_uk into q: (b,1,h,nd) x (r,h,nd) -> (b,h,r)
        q_abs = jnp.einsum("bshk,rhk->bhr", q_nope, cast(p["wuk"], cfg),
                           preferred_element_type=jnp.float32).astype(x.dtype)
        logits = (jnp.einsum("bhr,bsr->bhs", q_abs, c_ckv,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshk,btk->bht", q_rope, c_kr,
                               preferred_element_type=jnp.float32)) * scale
        logits = jnp.where(valid[None, None, :], logits, NEG_INF)
        pattn = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhs,bsr->bhr", pattn, c_ckv,
                             preferred_element_type=jnp.float32).astype(x.dtype)
        o = jnp.einsum("bhr,rhk->bhk", ctx_lat, cast(p["wuv"], cfg),
                       preferred_element_type=jnp.float32)[:, None].astype(x.dtype)
        new_cache = {"ckv": c_ckv, "kr": c_kr}

    y = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"], cfg),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype), new_cache
