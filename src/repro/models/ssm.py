"""Mamba-2 SSD (state-space duality) mixer — chunked jnp reference path.

The chunked algorithm (intra-chunk quadratic + inter-chunk recurrence via
lax.scan) follows arXiv:2405.21060 §6. The Pallas kernel in
``repro.kernels.ssd_scan`` implements the same math with VMEM tiling.

Projections are kept *separate* (z/x/BC/dt) rather than packed in one
in_proj so each gets a clean partition spec: z/x project to the
head-sharded inner dim ("model" axis), while the small B/C/dt projections
stay replicated — no mid-tensor reshards (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _normal, cast, rmsnorm
from repro.sharding.policy import constrain


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return d_in, n_heads


def init_mamba(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d_in, n_heads = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "z_proj": _normal(ks[0], (cfg.d_model, d_in)),
        "x_proj": _normal(ks[1], (cfg.d_model, d_in)),
        "bc_proj": _normal(ks[2], (cfg.d_model, 2 * s.d_state)),
        "dt_proj": _normal(ks[3], (cfg.d_model, n_heads)),
        "conv_x_w": _normal(ks[4], (s.d_conv, d_in), scale=0.1),
        "conv_x_b": jnp.zeros((d_in,), jnp.float32),
        "conv_bc_w": _normal(ks[5], (s.d_conv, 2 * s.d_state), scale=0.1),
        "conv_bc_b": jnp.zeros((2 * s.d_state,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": _normal(ks[0], (d_in, cfg.d_model)),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None):
    s = cfg.ssm
    d_in, n_heads = _dims(cfg)
    dtype = dtype or jnp.dtype(cfg.dtype)
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, s.d_conv - 1, 2 * s.d_state), dtype),
        "ssm": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, B, C, *, chunk: int, init_state=None):
    """x: (b,s,h,p) dt: (b,s,h) A: (h,)<0  B,C: (b,s,n). Returns (y, state).

    y[t] = C_t . h_t;  h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    s_orig = s
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 => identity step
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    c, q = s // chunk, chunk
    xdt = (x * dt[..., None]).astype(jnp.float32)
    xr = xdt.reshape(b, c, q, h, p)
    dA = (dt.astype(jnp.float32) * A).reshape(b, c, q, h)       # (b,c,q,h)
    dA_cs = jnp.cumsum(dA, axis=2)                              # inclusive
    dA_sum = dA_cs[:, :, -1]                                    # (b,c,h)
    Br = B.astype(jnp.float32).reshape(b, c, q, n)
    Cr = C.astype(jnp.float32).reshape(b, c, q, n)

    # intra-chunk (quadratic within chunk); mask the exponent BEFORE exp so
    # the backward pass never sees exp(+large)*0 = nan
    diff = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]    # (b,c,i,j,h)
    causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(causal, diff, -jnp.inf))
    scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)
    # intra-chunk product in bf16: the (b,c,q,q,h) tensors dominate the
    # SSD byte footprint; exp/cumsum stay fp32 (§Perf B3)
    M = (scores[..., None] * L).astype(x.dtype)                 # (b,c,i,j,h)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M, xr.astype(x.dtype),
                        preferred_element_type=jnp.float32)

    # chunk-final states
    decay_end = jnp.exp(dA_sum[:, :, None, :] - dA_cs)          # (b,c,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Br, decay_end, xr)

    # inter-chunk recurrence
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(carry, inp):
        st_c, dA_sum_c = inp                                    # (b,h,p,n),(b,h)
        new = jnp.exp(dA_sum_c)[:, :, None, None] * carry + st_c
        return new, carry                                       # emit state entering chunk

    states_t = jnp.moveaxis(states, 1, 0)                       # (c,b,h,p,n)
    dA_sum_t = jnp.moveaxis(dA_sum, 1, 0)                       # (c,b,h)
    final, entry_states = jax.lax.scan(body, s0, (states_t, dA_sum_t))
    entry = jnp.moveaxis(entry_states, 0, 1)                    # (b,c,h,p,n)

    # contribution of the entering state within each chunk
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cr, jnp.exp(dA_cs), entry)
    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final


def _causal_conv(u, w, bias):
    """u: (b, s, ch); w: (k, ch) depthwise causal conv."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros(u.shape, jnp.float32)
    for i in range(k):
        out = out + pad[:, i:i + u.shape[1]].astype(jnp.float32) * w[i]
    return (out + bias).astype(u.dtype)


def _proj(x, w, cfg):
    return jnp.einsum("bsd,de->bse", x, cast(w, cfg),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def apply_mamba(p: Params, xin, *, cfg: ModelConfig, mode: str, cache=None,
                pos=None, use_kernel: bool = False):
    """xin: (B, S, d) (S=1 for decode). Returns (y, new_cache)."""
    s_cfg = cfg.ssm
    d_in, n_heads = _dims(cfg)
    b, s, _ = xin.shape
    N, P = s_cfg.d_state, s_cfg.head_dim

    z = constrain(_proj(xin, p["z_proj"], cfg), "dp", None, "model")
    xc = constrain(_proj(xin, p["x_proj"], cfg), "dp", None, "model")
    bc = _proj(xin, p["bc_proj"], cfg)
    dt_raw = _proj(xin, p["dt_proj"], cfg)
    A = -jnp.exp(p["A_log"])                                    # (h,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if mode in ("train", "prefill"):
        xcv = jax.nn.silu(_causal_conv(xc, cast(p["conv_x_w"], cfg),
                                       cast(p["conv_x_b"], cfg)))
        bcv = jax.nn.silu(_causal_conv(bc, cast(p["conv_bc_w"], cfg),
                                       cast(p["conv_bc_b"], cfg)))
        x = xcv.reshape(b, s, n_heads, P)
        Bm, Cm = bcv[..., :N], bcv[..., N:]
        from repro.kernels import kernels_enabled
        chunk = min(s_cfg.chunk, s)
        if (use_kernel or kernels_enabled()) and mode == "train" \
                and s % chunk == 0:
            from repro.kernels.ssd_scan.kernel import ssd_scan
            y = ssd_scan(x, dt.astype(x.dtype), A, Bm, Cm, chunk=chunk)
            state = None  # kernel path is train-only (no state output)
        else:
            y, state = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        y = y + p["D"][:, None] * x
        new_cache = None
        if mode == "prefill":
            k = s_cfg.d_conv - 1
            new_cache = {"conv_x": xc[:, -k:], "conv_bc": bc[:, -k:],
                         "ssm": state}
    else:  # decode
        win_x = jnp.concatenate([cache["conv_x"], xc], axis=1)  # (b, k, d_in)
        win_bc = jnp.concatenate([cache["conv_bc"], bc], axis=1)
        wx, wbc = cast(p["conv_x_w"], cfg), cast(p["conv_bc_w"], cfg)
        xcv = jax.nn.silu(jnp.einsum(
            "bkc,kc->bc", win_x.astype(jnp.float32), wx.astype(jnp.float32))
            + p["conv_x_b"]).astype(xin.dtype)
        bcv = jax.nn.silu(jnp.einsum(
            "bkc,kc->bc", win_bc.astype(jnp.float32), wbc.astype(jnp.float32))
            + p["conv_bc_b"]).astype(xin.dtype)
        x = xcv.reshape(b, n_heads, P)
        Bm, Cm = bcv[..., :N], bcv[..., N:]
        dt1 = dt[:, 0]                                          # (b,h)
        h_prev = cache["ssm"]                                   # (b,h,p,n) f32
        dA = jnp.exp(dt1 * A)                                   # (b,h)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, Bm.astype(jnp.float32),
                         x.astype(jnp.float32))
        h_new = dA[..., None, None] * h_prev + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h_new)
        y = (y + p["D"][:, None] * x.astype(jnp.float32))[:, None]
        y = y.astype(xin.dtype)
        new_cache = {"conv_x": win_x[:, 1:], "conv_bc": win_bc[:, 1:],
                     "ssm": h_new}

    y = y.reshape(b, s, d_in)
    y = rmsnorm(y, p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, cast(p["out_proj"], cfg),
                     preferred_element_type=jnp.float32)
    return out.astype(xin.dtype), new_cache
