from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward_train,
    init_cache,
    init_params,
    prefill,
)
