"""Sequence classifier on top of the transformer stack (encoder mode).

Used for (i) the neural marketplace "APIs" (tiny models of different
capacity answering classification-style queries, mirroring the paper's
tasks) and (ii) the DistilBERT-analogue generation scorer.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.layers import apply_norm
from repro.models.transformer import _apply_stack, _embed_inputs, init_params


def encoder_config(name: str, n_layers: int = 4, d_model: int = 128,
                   n_heads: int = 4, d_ff: int = 256, vocab: int = 512,
                   max_seq: int = 256) -> ModelConfig:
    return ModelConfig(
        name=name, arch_type="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_heads, head_dim=d_model // n_heads,
        d_ff=d_ff, vocab=vocab,
        period=(LayerSpec("attn", "dense"),), n_periods=n_layers,
        pos="abs", causal=False, ffn_act="gelu", norm="layernorm",
        max_seq=max_seq, dtype="float32",
    )


def init_classifier(key, cfg: ModelConfig, n_classes: int):
    k1, k2 = jax.random.split(key)
    params = init_params(k1, cfg)
    params["head"] = {"w": 0.02 * jax.random.normal(
        k2, (cfg.d_model, n_classes)), "b": jnp.zeros((n_classes,))}
    return params


def classifier_logits(params, tokens, cfg: ModelConfig):
    """tokens: (B, L) -> class logits (B, C). Pools the CLS position."""
    x, positions = _embed_inputs(params, {"tokens": tokens}, cfg, "train")
    x, _, _ = _apply_stack(params, x, cfg=cfg, mode="train",
                           positions=positions, cache=None, pos=None,
                           remat=False)
    h = apply_norm(params["final_norm"], x, cfg)[:, 0]      # CLS pool
    return h @ params["head"]["w"] + params["head"]["b"]


def classifier_score(params, tokens, cfg: ModelConfig):
    """Regression head in [0,1] (the generation scorer g)."""
    logits = classifier_logits(params, tokens, cfg)
    return jax.nn.sigmoid(logits[:, 0])


_JITTED: dict[ModelConfig, Callable] = {}


def jitted_logits(cfg: ModelConfig) -> Callable:
    """Per-config cached ``jit(classifier_logits)``.

    Serving-hot-path callers must use this instead of wrapping a fresh
    ``jax.jit(partial(...))`` per call — a new wrapper object misses
    jax's jit cache and retraces on every batch. Keyed by the (frozen)
    config itself, not its name: two configs may share a name with
    different hyperparameters and must not reuse each other's graph.
    """
    fn = _JITTED.get(cfg)
    if fn is None:
        fn = jax.jit(functools.partial(classifier_logits, cfg=cfg))
        _JITTED[cfg] = fn
    return fn
