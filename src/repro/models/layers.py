"""Common layers: norms, MLPs, embeddings, RoPE / M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict


def _normal(key, shape, scale=0.02, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype=dtype)


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def cast(x, cfg: ModelConfig):
    return x.astype(compute_dtype(cfg))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: int | None = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm" or "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p.get("bias", 0.0)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, scale=0.02) -> Params:
    return {"w": _normal(key, (d_in, d_out), scale)}


def apply_dense(p: Params, x, cfg: ModelConfig):
    w = cast(p["w"], cfg)
    y = jnp.einsum("...d,df->...f", x, w, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def _act(name: str):
    if name in ("swiglu",):
        return jax.nn.silu
    return jax.nn.gelu


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], cfg.d_model, d_ff),
         "down": init_dense(ks[1], d_ff, cfg.d_model)}
    if cfg.ffn_act in ("swiglu", "geglu"):
        p["gate"] = init_dense(ks[2], cfg.d_model, d_ff)
    return p


def apply_mlp(p: Params, x, cfg: ModelConfig):
    up = apply_dense(p["up"], x, cfg)
    if "gate" in p:
        g = apply_dense(p["gate"], x, cfg)
        h = _act(cfg.ffn_act)(g) * up
    else:
        h = _act(cfg.ffn_act)(up)
    return apply_dense(p["down"], h, cfg)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    p = {}
    if cfg.embed_inputs:
        p["tok"] = _normal(ks[0], (cfg.vocab, cfg.d_model))
    if cfg.pos == "abs":
        p["pos"] = _normal(ks[1], (cfg.max_seq if cfg.max_seq <= 65_536 else 65_536,
                                   cfg.d_model))
    if not cfg.tie_embeddings:
        p["unembed"] = _normal(ks[2], (cfg.d_model, cfg.vocab))
    return p


def embed_tokens(p: Params, tokens, cfg: ModelConfig):
    w = cast(p["tok"], cfg)
    return jnp.take(w, tokens, axis=0)


def unembed(p: Params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = cast(p["tok"], cfg).T
    else:
        w = cast(p["unembed"], cfg)
    return jnp.einsum("...d,dv->...v", x, w,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    ang = ang[..., None, :]                          # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int):
    """Split of the half-dim into (t, h, w) sections, Qwen2-VL style."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return (t, h, w)


def apply_mrope(x, pos3, theta: float):
    """x: (B, S, H, hd); pos3: (3, B, S) t/h/w position ids."""
    hd = x.shape[-1]
    half = hd // 2
    inv = rope_freqs(hd, theta)                      # (half,)
    secs = mrope_sections(hd)
    # section id per frequency index
    sec_id = jnp.concatenate([
        jnp.full((secs[0],), 0), jnp.full((secs[1],), 1), jnp.full((secs[2],), 2)
    ]).astype(jnp.int32)                             # (half,)
    # per-frequency positions: pick t/h/w pos per section
    pos = jnp.take(pos3.astype(jnp.float32), sec_id, axis=0)  # (half, B, S)
    ang = jnp.moveaxis(pos, 0, -1) * inv             # (B, S, half)
    ang = ang[..., None, :]                          # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
