"""Model assembly: layer-pattern blocks, period-scanned stack, LM heads.

Public API (all pure functions of pytrees):
  init_params(key, cfg)                 -> params
  init_cache(cfg, batch, seq[, dtype])  -> decode cache pytree
  forward_train(params, batch, cfg)     -> (loss, metrics)
  prefill(params, batch, cfg)           -> (last_logits, cache)
  decode_step(params, cache, tokens, pos, cfg [, mrope_pos]) -> (logits, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention, moe, ssm
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embed, init_mlp, init_norm, unembed)
from repro.sharding.policy import constrain

AUX_LOSS_WEIGHT = 0.01
MTP_WEIGHT = 0.3


# ---------------------------------------------------------------------------
# Single block (mixer + FFN)
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg)}
    if spec.mixer.startswith("attn"):
        p["mixer"] = attention.init_attn(ks[0], cfg)
    else:
        p["mixer"] = ssm.init_mamba(ks[0], cfg)
    if spec.ffn == "dense":
        p["norm2"] = init_norm(cfg)
        p["ffn"] = init_mlp(ks[1], cfg)
    elif spec.ffn == "moe":
        p["norm2"] = init_norm(cfg)
        p["ffn"] = moe.init_moe(ks[1], cfg)
    return p


def apply_block(p, x, *, cfg: ModelConfig, spec: LayerSpec, mode: str,
                positions, cache, pos, max_len: int = 0):
    # keep the residual stream batch-sharded; without this GSPMD may
    # all-gather activations over the data axis every layer (§Perf B1)
    x = constrain(x, "dp", None, None)
    h = apply_norm(p["norm1"], x, cfg)
    if spec.mixer.startswith("attn"):
        y, new_cache = attention.apply_attn(
            p["mixer"], h, cfg=cfg, sliding=spec.mixer == "attn_sliding",
            mode=mode, positions=positions, cache=cache, pos=pos,
            max_len=max_len)
    else:
        y, new_cache = ssm.apply_mamba(p["mixer"], h, cfg=cfg, mode=mode,
                                       cache=cache, pos=pos)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h = apply_norm(p["norm2"], x, cfg)
        if spec.ffn == "dense":
            y = apply_mlp(p["ffn"], h, cfg)
        else:
            y, aux = moe.apply_moe(p["ffn"], h, cfg=cfg)
        x = x + y
    return x, new_cache, aux


def init_block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int,
                     dtype=None):
    if spec.mixer.startswith("attn"):
        return attention.init_attn_cache(cfg, spec.mixer == "attn_sliding",
                                         batch, seq, dtype)
    return ssm.init_mamba_cache(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# Full-stack params / cache
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    n_pre, n_per, n_suf = len(cfg.prefix), len(cfg.period), len(cfg.suffix)
    keys = jax.random.split(key, 3 + n_pre + n_suf + max(1, cfg.n_periods))
    params = {"embed": init_embed(keys[0], cfg),
              "final_norm": init_norm(cfg)}
    params["prefix"] = [init_block(keys[3 + i], cfg, s)
                        for i, s in enumerate(cfg.prefix)]
    params["suffix"] = [init_block(keys[3 + n_pre + i], cfg, s)
                        for i, s in enumerate(cfg.suffix)]
    if cfg.n_periods:
        per_keys = keys[3 + n_pre + n_suf:3 + n_pre + n_suf + cfg.n_periods]

        def one_period(k):
            sub = jax.random.split(k, n_per)
            return {f"sub{i}": init_block(sub[i], cfg, s)
                    for i, s in enumerate(cfg.period)}

        stacked = [one_period(k) for k in per_keys]
        params["period"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    if cfg.mtp:
        mk = jax.random.split(keys[1], 3)
        params["mtp"] = {
            "proj": {"w": 0.02 * jax.random.normal(mk[0], (2 * cfg.d_model,
                                                           cfg.d_model))},
            "block": init_block(mk[1], cfg, LayerSpec("attn", "dense")),
            "norm_h": init_norm(cfg), "norm_e": init_norm(cfg),
        }
    return params


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    cache = {
        "prefix": [init_block_cache(cfg, s, batch, seq, dtype)
                   for s in cfg.prefix],
        "suffix": [init_block_cache(cfg, s, batch, seq, dtype)
                   for s in cfg.suffix],
    }
    if cfg.n_periods:
        one = {f"sub{i}": init_block_cache(cfg, s, batch, seq, dtype)
               for i, s in enumerate(cfg.period)}
        cache["period"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape),
            one)
    return cache


# ---------------------------------------------------------------------------
# Scan-over-layers folding: absorb homogeneous prefix/suffix into the
# scanned period stack (compile count stays O(1) in depth, and the whole
# layer stack becomes ONE stacked pytree leaf per parameter — the unit
# the per-tier mesh sharding in repro.sharding.tier_mesh partitions)
# ---------------------------------------------------------------------------


def _fold_counts(cfg: ModelConfig):
    """(k_pre, k_suf, period): how many whole period-copies the prefix /
    suffix fold into. A homogeneous prefix with no existing period
    becomes its own period of length 1. (0, 0, cfg.period) = nothing to
    fold."""
    period = cfg.period
    if not period:
        if cfg.prefix and len(set(cfg.prefix)) == 1 and not cfg.suffix:
            return len(cfg.prefix), 0, (cfg.prefix[0],)
        return 0, 0, cfg.period
    p = len(period)
    k_pre = (len(cfg.prefix) // p
             if cfg.prefix and cfg.prefix == period * (len(cfg.prefix) // p)
             and len(cfg.prefix) % p == 0 else 0)
    k_suf = (len(cfg.suffix) // p
             if cfg.suffix and cfg.suffix == period * (len(cfg.suffix) // p)
             and len(cfg.suffix) % p == 0 else 0)
    return k_pre, k_suf, period


def fold_config(cfg: ModelConfig) -> ModelConfig:
    """Fold homogeneous prefix/suffix blocks into the scanned stack.

    When the prefix (and/or suffix) is a whole number of copies of the
    period pattern, those blocks are absorbed into ``n_periods`` so the
    entire stack lowers to one ``jax.lax.scan`` — the flattened layer
    sequence (``cfg.layers``) is unchanged, so the computation is
    identical block for block. Returns ``cfg`` itself when nothing
    folds."""
    k_pre, k_suf, period = _fold_counts(cfg)
    if k_pre == 0 and k_suf == 0:
        return cfg
    import dataclasses
    return dataclasses.replace(
        cfg,
        prefix=cfg.prefix if k_pre == 0 else (),
        suffix=cfg.suffix if k_suf == 0 else (),
        period=period,
        n_periods=cfg.n_periods + k_pre + k_suf)


def fold_stack(cfg: ModelConfig, params):
    """(cfg, params) -> (folded_cfg, folded_params).

    The params counterpart of ``fold_config``: prefix/suffix block
    params are restacked onto the leading axis of the ``period`` stack
    (prefix copies in front, suffix copies behind), so every weight of
    the folded stack lives in one stacked leaf. No-op (same objects
    returned) when nothing folds; the flattened layer sequence — and so
    the forward computation — is unchanged either way."""
    k_pre, k_suf, period = _fold_counts(cfg)
    if k_pre == 0 and k_suf == 0:
        return cfg, params
    p = len(period)

    def group_stack(blocks):
        groups = [{f"sub{i}": blocks[g * p + i] for i in range(p)}
                  for g in range(len(blocks) // p)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *groups)

    parts = []
    if k_pre:
        parts.append(group_stack(params["prefix"]))
    if cfg.n_periods:
        parts.append(params["period"])
    if k_suf:
        parts.append(group_stack(params["suffix"]))
    stacked = (parts[0] if len(parts) == 1 else
               jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts))
    folded = {k: v for k, v in params.items()
              if k not in ("prefix", "suffix", "period")}
    folded["prefix"] = [] if k_pre else params["prefix"]
    folded["suffix"] = [] if k_suf else params["suffix"]
    folded["period"] = stacked
    return fold_config(cfg), folded


# ---------------------------------------------------------------------------
# Stack forward
# ---------------------------------------------------------------------------


def _apply_stack(params, x, *, cfg: ModelConfig, mode: str, positions, cache,
                 pos, remat: bool, max_len: int = 0):
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {"prefix": [], "suffix": []}

    for i, spec in enumerate(cfg.prefix):
        c = cache["prefix"][i] if cache is not None else None
        x, nc, aux = apply_block(params["prefix"][i], x, cfg=cfg, spec=spec,
                                 mode=mode, positions=positions, cache=c,
                                 pos=pos, max_len=max_len)
        new_cache["prefix"].append(nc)
        aux_total += aux

    if cfg.n_periods:
        has_cache = cache is not None

        def body(carry, xs):
            h, aux_acc = carry
            p_slice, c_slice = xs           # c_slice is None when no cache
            ncs = {}
            for i, spec in enumerate(cfg.period):
                c = None if c_slice is None else c_slice[f"sub{i}"]
                h, nc, aux = apply_block(p_slice[f"sub{i}"], h, cfg=cfg,
                                         spec=spec, mode=mode,
                                         positions=positions, cache=c,
                                         pos=pos, max_len=max_len)
                if has_cache:
                    ncs[f"sub{i}"] = nc
                aux_acc = aux_acc + aux
            return (h, aux_acc), ncs

        if remat:
            body = jax.checkpoint(body)
        xs = (params["period"], cache["period"] if has_cache else None)
        (x, aux_total), per_cache = jax.lax.scan(body, (x, aux_total), xs)
        if has_cache:
            new_cache["period"] = per_cache

    for i, spec in enumerate(cfg.suffix):
        c = cache["suffix"][i] if cache is not None else None
        x, nc, aux = apply_block(params["suffix"][i], x, cfg=cfg, spec=spec,
                                 mode=mode, positions=positions, cache=c,
                                 pos=pos, max_len=max_len)
        new_cache["suffix"].append(nc)
        aux_total += aux

    return x, (new_cache if cache is not None else None), aux_total


def _embed_inputs(params, batch, cfg: ModelConfig, mode: str):
    """Returns (x, positions). Handles audio (precomputed embeds), VLM
    (vision patch embeds + M-RoPE position ids) and plain tokens."""
    if not cfg.embed_inputs:                       # audio backbone
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        if cfg.pos == "abs":
            pe = params["embed"]["pos"][:x.shape[1]].astype(x.dtype)
            x = x + pe[None]
        return x, None
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg)
    if cfg.vision_tokens and "vision_embeds" in batch:
        v = batch["vision_embeds"].astype(x.dtype)
        nv = v.shape[1]
        x = jnp.concatenate([v, x[:, nv:]], axis=1)
    b, s = tokens.shape
    if cfg.pos == "mrope":
        positions = batch.get("mrope_pos")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    elif cfg.pos == "rope":
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    else:
        positions = None
    return x, positions


# ---------------------------------------------------------------------------
# Train / prefill / decode entry points
# ---------------------------------------------------------------------------


def _cast_params(params, cfg: ModelConfig):
    """Pre-cast fp32 master weights to the compute dtype ONCE, before the
    stack consumes them — under FSDP the all-gather then moves bf16, not
    fp32, halving param collective/HBM traffic (§Perf A2). Norm scales
    and other 1-d params stay fp32."""
    dt = jnp.dtype(cfg.dtype)
    if dt == jnp.float32:
        return params

    def c(x):
        if hasattr(x, "dtype") and x.dtype == jnp.float32 and x.ndim >= 2:
            return x.astype(dt)
        return x

    return jax.tree.map(c, params)


# materialize full (B,S,V) fp32 logits only below this element count;
# above it the train loss runs in unrolled sequence chunks, bf16 logits
_CHUNKED_LOSS_THRESHOLD = 2 ** 31
_LOSS_CHUNKS = 8


def _lm_loss(params, h, labels, cfg: ModelConfig):
    """LM cross-entropy; seq-chunked with bf16 logits when (B,S,V) is too
    large to materialize in fp32 (never builds the full logits tensor) —
    §Perf A3."""
    b, s = labels.shape
    if b * s * cfg.vocab > _CHUNKED_LOSS_THRESHOLD and s % _LOSS_CHUNKS == 0:
        cs = s // _LOSS_CHUNKS
        total = jnp.zeros((), jnp.float32)
        for i in range(_LOSS_CHUNKS):
            lg = unembed(params["embed"], h[:, i * cs:(i + 1) * cs], cfg)
            lg = lg.astype(jnp.dtype(cfg.dtype))
            total += softmax_xent(lg, labels[:, i * cs:(i + 1) * cs])
        return total / _LOSS_CHUNKS
    logits = unembed(params["embed"], h, cfg)      # (B,S,V) fp32
    return softmax_xent(logits, labels)


def forward_train(params, batch, cfg: ModelConfig, remat: bool = True):
    params = _cast_params(params, cfg)
    x, positions = _embed_inputs(params, batch, cfg, "train")
    x, _, aux = _apply_stack(params, x, cfg=cfg, mode="train",
                             positions=positions, cache=None, pos=None,
                             remat=remat)
    h = apply_norm(params["final_norm"], x, cfg)
    labels = batch["labels"]
    loss = _lm_loss(params, h, labels, cfg)
    metrics = {"xent": loss, "aux": aux}
    if cfg.mtp and cfg.embed_inputs:
        loss_mtp = _mtp_loss(params, h, batch, cfg, positions)
        metrics["mtp"] = loss_mtp
        loss = loss + MTP_WEIGHT * loss_mtp
    loss = loss + AUX_LOSS_WEIGHT * aux
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(params, h, batch, cfg: ModelConfig, positions):
    """DeepSeek-V3 MTP depth-1: predict token t+2 from h_t and emb(t+1)."""
    mp = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    emb_next = embed_tokens(params["embed"], labels, cfg)  # labels = t+1 tokens
    hn = apply_norm(mp["norm_h"], h, cfg)
    en = apply_norm(mp["norm_e"], emb_next, cfg)
    merged = jnp.einsum("bse,ed->bsd", jnp.concatenate([hn, en], -1),
                        mp["proj"]["w"].astype(h.dtype),
                        preferred_element_type=jnp.float32).astype(h.dtype)
    spec = LayerSpec("attn", "dense")
    x, _, _ = apply_block(mp["block"], merged, cfg=cfg, spec=spec, mode="train",
                          positions=positions, cache=None, pos=None)
    hn2 = apply_norm(params["final_norm"], x, cfg)
    # target: token at t+2 == labels shifted by one
    tgt = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
    return _lm_loss(params, hn2, tgt, cfg)


def softmax_xent(logits, labels):
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(params, batch, cfg: ModelConfig, max_len: int = 0,
            last_index=None):
    """Full-sequence forward building the decode cache (or, for encoder-only
    archs, the encoding pass). ``max_len``: decode-cache allocation length
    (>= prompt length); defaults to the prompt length. ``last_index``:
    position whose logits to return (may be a traced scalar; defaults to
    the final position) — lets right-padded prompts read the logits of
    their true last token. Returns (last_logits, cache)."""
    x, positions = _embed_inputs(params, batch, cfg, "prefill")
    b, s = x.shape[0], x.shape[1]
    cache = init_cache(cfg, b, s, jnp.dtype(cfg.dtype)) if cfg.causal else None
    if cfg.causal:
        x, new_cache, _ = _apply_stack(params, x, cfg=cfg, mode="prefill",
                                       positions=positions, cache=cache,
                                       pos=jnp.zeros((), jnp.int32), remat=False,
                                       max_len=max_len or s)
    else:
        x, new_cache, _ = _apply_stack(params, x, cfg=cfg, mode="train",
                                       positions=positions, cache=None,
                                       pos=None, remat=False)
    h = apply_norm(params["final_norm"], x, cfg)
    if cfg.causal:
        if last_index is None:
            h_last = h[:, -1:]
        else:
            h_last = jax.lax.dynamic_slice_in_dim(h, last_index, 1, axis=1)
        logits = unembed(params["embed"], h_last, cfg)
    else:
        logits = unembed(params["embed"], h, cfg)   # per-frame logits
    return logits, new_cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, mrope_pos=None):
    """One decode step: tokens (B, 1), pos = scalar fill level of the cache.

    Returns (logits (B,1,V), new_cache)."""
    assert cfg.causal, "decode not supported for encoder-only archs"
    x = embed_tokens(params["embed"], tokens, cfg)
    b = tokens.shape[0]
    if cfg.pos == "mrope":
        positions = (mrope_pos if mrope_pos is not None
                     else jnp.broadcast_to(pos, (3, b, 1)))
    elif cfg.pos == "rope":
        positions = jnp.broadcast_to(pos, (b, 1))
    else:
        positions = None
    x, new_cache, _ = _apply_stack(params, x, cfg=cfg, mode="decode",
                                   positions=positions, cache=cache, pos=pos,
                                   remat=False)
    h = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], h, cfg)
    return logits, new_cache
