"""Mixture-of-Experts FFN with capacity-based token-choice routing.

Dispatch is gather-based (per-group expert top-C by earliest-token
priority) rather than Mesh-TF one-hot-einsum dispatch: the gather /
take_along_axis formulation keeps HLO FLOPs equal to the *active* expert
compute (x capacity factor) and partitions cleanly with the batch (group)
dim on the data axis and the expert dim on the model axis, where pjit
inserts the all-to-all-equivalent collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _normal, cast, _act
from repro.sharding.policy import constrain


def init_moe(key, cfg: ModelConfig) -> Params:
    e = cfg.moe
    ks = jax.random.split(key, 6)
    gated = cfg.ffn_act in ("swiglu", "geglu")
    p = {
        "router": _normal(ks[0], (cfg.d_model, e.n_experts)),
        "up": _normal(ks[1], (e.n_experts, cfg.d_model, e.d_expert)),
        "down": _normal(ks[2], (e.n_experts, e.d_expert, cfg.d_model)),
    }
    if gated:
        p["gate"] = _normal(ks[3], (e.n_experts, cfg.d_model, e.d_expert))
    if e.n_shared:
        d_sh = e.d_expert * e.n_shared
        p["sh_up"] = _normal(ks[4], (cfg.d_model, d_sh))
        p["sh_down"] = _normal(ks[5], (d_sh, cfg.d_model))
        if gated:
            p["sh_gate"] = _normal(ks[4], (cfg.d_model, d_sh))
    return p


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    e = cfg.moe
    c = int(tokens_per_group * e.top_k * e.capacity_factor / e.n_experts)
    return max(1, min(c, tokens_per_group))


def apply_moe(p: Params, x, *, cfg: ModelConfig):
    """x: (B, T, d) — B is the dispatch group dim. Returns (y, aux_loss)."""
    e = cfg.moe
    b, t, d = x.shape
    cap = capacity(cfg, t)
    act = _act(cfg.ffn_act)
    gated = "gate" in p

    # --- routing ---------------------------------------------------------
    logits = jnp.einsum("btd,de->bte", x, cast(p["router"], cfg),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (b,t,E) f32
    w, e_idx = jax.lax.top_k(probs, e.top_k)                    # (b,t,k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    assign = jax.nn.one_hot(e_idx, e.n_experts, dtype=jnp.float32)  # (b,t,k,E)
    f_e = assign.sum(2).mean(1)                                 # (b,E) fraction
    p_e = probs.mean(1)                                         # (b,E)
    aux = e.n_experts * jnp.mean(jnp.sum(f_e * p_e, -1))

    # --- dispatch: per (group, expert) pick up to `cap` earliest tokens ---
    tok_mask = assign.sum(2)                                    # (b,t,E) 0/1
    prio = tok_mask * (t - jnp.arange(t, dtype=jnp.float32))[None, :, None]
    prio = jnp.swapaxes(prio, 1, 2)                             # (b,E,t)
    top_p, top_i = jax.lax.top_k(prio, cap)                     # (b,E,cap)
    slot_valid = top_p > 0.0                                    # (b,E,cap)

    xg = jnp.take_along_axis(
        x[:, None], top_i[..., None], axis=2)                   # (b,E,cap,d)
    xg = xg * slot_valid[..., None].astype(x.dtype)
    xg = constrain(xg, "dp", "model", None, None)

    # --- expert compute ----------------------------------------------------
    from repro.kernels import kernels_enabled
    yg = None
    if kernels_enabled() and gated and cfg.ffn_act == "swiglu" \
            and (b * cap) % 8 == 0:
        from repro.kernels.moe_gmm.ops import expert_mlp
        xe = jnp.swapaxes(xg, 0, 1).reshape(e.n_experts, b * cap, d)
        ye = expert_mlp(xe, cast(p["gate"], cfg), cast(p["up"], cfg),
                        cast(p["down"], cfg))
        yg = jnp.swapaxes(ye.reshape(e.n_experts, b, cap, d), 0, 1)
    if yg is None:
        up = jnp.einsum("becd,edf->becf", xg, cast(p["up"], cfg),
                        preferred_element_type=jnp.float32).astype(x.dtype)
        if gated:
            g = jnp.einsum("becd,edf->becf", xg, cast(p["gate"], cfg),
                           preferred_element_type=jnp.float32).astype(x.dtype)
            h = act(g) * up
        else:
            h = act(up)
        yg = jnp.einsum("becf,efd->becd", h, cast(p["down"], cfg),
                        preferred_element_type=jnp.float32).astype(x.dtype)

    # --- combine: token slot position == rank among earlier assigned tokens
    # cumulative count of assigned tokens per expert, exclusive
    pos_all = jnp.cumsum(tok_mask, axis=1) - tok_mask           # (b,t,E)
    pos_tk = jnp.take_along_axis(pos_all, e_idx.astype(jnp.int32), axis=2)
    keep = pos_tk < cap                                         # (b,t,k)
    slot = jnp.minimum(pos_tk.astype(jnp.int32), cap - 1)       # clip overflow
    flat_idx = (e_idx * cap + slot).reshape(b, t * e.top_k)
    y_flat = yg.reshape(b, e.n_experts * cap, d)
    y_tok = jnp.take_along_axis(
        y_flat, flat_idx[..., None], axis=1, mode="clip"
    ).reshape(b, t, e.top_k, d)
    wk = (w * keep).astype(x.dtype)
    y = jnp.einsum("btk,btkd->btd", wk, y_tok,
                   preferred_element_type=jnp.float32).astype(x.dtype)

    # --- shared experts (always-on) ---------------------------------------
    if "sh_up" in p:
        su = jnp.einsum("btd,df->btf", x, cast(p["sh_up"], cfg),
                        preferred_element_type=jnp.float32).astype(x.dtype)
        if gated:
            sg = jnp.einsum("btd,df->btf", x, cast(p["sh_gate"], cfg),
                            preferred_element_type=jnp.float32).astype(x.dtype)
            sh = act(sg) * su
        else:
            sh = act(su)
        y = y + jnp.einsum("btf,fd->btd", sh, cast(p["sh_down"], cfg),
                           preferred_element_type=jnp.float32).astype(x.dtype)
    return y, aux
