"""Config system for the repro framework.

Every architecture is described by a ModelConfig. Heterogeneous layer
stacks (hybrid Jamba, Gemma-3 local:global, DeepSeek dense-prefix+MoE)
are expressed as ``prefix ++ (period * n_periods) ++ suffix`` of
LayerSpec entries; the periods are scanned (params stacked on a leading
axis) so deep stacks lower to compact HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoECfg:
    """Mixture-of-experts FFN config (capacity-based routing)."""

    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 1.25
    router_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLACfg:
    """DeepSeek-style Multi-head Latent Attention."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    """Mamba-2 SSD mixer config."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256             # SSD chunk length


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One transformer sub-layer: a sequence mixer + an FFN."""

    mixer: str                   # "attn" | "attn_sliding" | "mamba"
    ffn: str                     # "dense" | "moe" | "none"

    def __post_init__(self):
        assert self.mixer in ("attn", "attn_sliding", "mamba"), self.mixer
        assert self.ffn in ("dense", "moe", "none"), self.ffn


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

_ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # one of _ARCH_TYPES
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0             # 0 for attention-free archs
    n_kv_heads: int = 0
    head_dim: int = 0

    # layer pattern: prefix ++ period*n_periods ++ suffix
    prefix: Tuple[LayerSpec, ...] = ()
    period: Tuple[LayerSpec, ...] = ()
    n_periods: int = 0
    suffix: Tuple[LayerSpec, ...] = ()

    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None

    pos: str = "rope"            # "rope" | "mrope" | "abs" | "none"
    rope_theta: float = 10_000.0
    window: int = 0              # sliding-window size for attn_sliding
    causal: bool = True          # False => encoder-only (no decode)
    ffn_act: str = "swiglu"      # "swiglu" | "gelu" | "geglu"
    norm: str = "rmsnorm"        # "rmsnorm" | "layernorm"
    tie_embeddings: bool = False
    embed_inputs: bool = True    # False => inputs are precomputed embeddings
    vision_tokens: int = 0       # VLM: number of stubbed patch-embedding slots
    mtp: bool = False            # DeepSeek multi-token-prediction head
    max_seq: int = 131_072
    dtype: str = "bfloat16"
    # citation for the config numbers
    source: str = ""

    # -- derived -------------------------------------------------------------
    def __post_init__(self):
        assert self.arch_type in _ARCH_TYPES, self.arch_type
        got = len(self.prefix) + len(self.period) * self.n_periods + len(self.suffix)
        assert got == self.n_layers, (
            f"{self.name}: layer pattern covers {got} layers, expected {self.n_layers}"
        )

    @property
    def layers(self) -> Tuple[LayerSpec, ...]:
        """The flattened per-layer spec list (for reference / counting)."""
        return self.prefix + self.period * self.n_periods + self.suffix

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def has_attention(self) -> bool:
        return any(s.mixer.startswith("attn") for s in self.layers)

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer attends to unbounded full context."""
        return all(s.mixer != "attn" for s in self.layers)

    @property
    def decode_supported(self) -> bool:
        return self.causal

    def supports_shape(self, shape_name: str) -> Tuple[bool, str]:
        """(supported, reason-if-not) for an input-shape name."""
        if shape_name in ("decode_32k", "long_500k") and not self.decode_supported:
            return False, "encoder-only: no decode step"
        if shape_name == "long_500k":
            # require sub-quadratic attention: every attn layer must be
            # sliding-window or the arch must be SSM/hybrid (bounded attn share)
            full_attn = any(s.mixer == "attn" for s in self.layers)
            if full_attn and self.arch_type not in ("ssm", "hybrid"):
                # dense archs with a global-attention share: allowed only if the
                # global layers are a small minority (gemma3 5:1 pattern)
                n_full = sum(1 for s in self.layers if s.mixer == "attn")
                if n_full / self.n_layers > 0.25:
                    return False, "full attention: long_500k requires sub-quadratic"
        return True, ""

    # -- reduced variant for CPU smoke tests ---------------------------------
    def reduced(self) -> "ModelConfig":
        """2-layer, d_model<=512, <=4-expert variant of the same family."""
        d_model = min(self.d_model, 256)
        head_dim = min(self.head_dim, 64) if self.head_dim else 0
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, max(1, n_heads // 2)) if self.n_kv_heads else 0
        if self.n_kv_heads == self.n_heads:  # keep MHA archs MHA
            n_kv = n_heads
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=min(self.moe.d_expert, 128),
                n_shared=min(self.moe.n_shared, 1),
            )
        mla = None
        if self.mla is not None:
            mla = MLACfg(q_lora_rank=64, kv_lora_rank=32,
                         qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
            head_dim = 0
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk=32)
        # 2 layers: take the first period (truncated to 2) or prefix+period head
        if self.period:
            period = self.period[:2] if len(self.period) >= 2 else self.period
            n_periods = 2 // len(period)
            rem = 2 - n_periods * len(period)
            prefix = self.prefix[:rem]
            if len(prefix) < rem:  # pad from period
                prefix = (self.period[0],) * rem
            suffix = ()
        else:
            prefix, period, n_periods, suffix = self.prefix[:2], (), 0, ()
        n_layers = len(prefix) + len(period) * n_periods + len(suffix)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 1024),
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            prefix=prefix,
            period=period,
            n_periods=n_periods,
            suffix=suffix,
            moe=moe,
            mla=mla,
            ssm=ssm,
            window=min(self.window, 64) if self.window else 0,
            vision_tokens=min(self.vision_tokens, 16),
            max_seq=512,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for 6*N*D roofline term)."""
    n = 0
    d = cfg.d_model
    if cfg.embed_inputs:
        n += cfg.vocab * d
    if not cfg.tie_embeddings:
        n += cfg.vocab * d
    for spec in cfg.layers:
        # mixer
        if spec.mixer.startswith("attn"):
            if cfg.mla is not None:
                m = cfg.mla
                qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                n += d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk_hd
                n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                n += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                n += cfg.n_heads * m.v_head_dim * d
            else:
                n += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        elif spec.mixer == "mamba":
            s = cfg.ssm
            d_in = s.expand * d
            n_h = d_in // s.head_dim
            n += d * (2 * d_in + 2 * s.d_state + n_h)  # in_proj(zx) + BC + dt
            n += s.d_conv * (d_in + 2 * s.d_state)     # conv over x,B,C
            n += d_in * d                              # out proj
            n += 2 * n_h                               # A_log, D
        # ffn
        if spec.ffn == "dense":
            mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
            n += mult * d * cfg.d_ff
        elif spec.ffn == "moe":
            mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
            e = cfg.moe
            n += (e.n_experts + e.n_shared) * mult * d * e.d_expert
            n += d * e.n_experts                       # router
        # norms
        n += 2 * d
    n += d  # final norm
    if cfg.mtp:
        # one MTP block: a dense transformer layer + projection
        n += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d + 3 * d * cfg.d_ff + 2 * d * d
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters active per token (MoE: top_k+shared experts only)."""
    if cfg.moe is None:
        return param_count(cfg)
    n = param_count(cfg)
    e = cfg.moe
    mult = 3 if cfg.ffn_act in ("swiglu", "geglu") else 2
    n_moe_layers = sum(1 for s in cfg.layers if s.ffn == "moe")
    dense_equiv = (e.top_k + e.n_shared) * mult * cfg.d_model * e.d_expert
    full = (e.n_experts + e.n_shared) * mult * cfg.d_model * e.d_expert
    return n - n_moe_layers * (full - dense_equiv)
