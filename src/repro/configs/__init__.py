from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    LayerSpec,
    MLACfg,
    ModelConfig,
    MoECfg,
    SSMCfg,
    active_param_count,
    param_count,
)
