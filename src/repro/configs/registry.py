"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from repro.configs import (
    deepseek_v3_671b,
    gemma3_1b,
    granite_moe_1b_a400m,
    hubert_xlarge,
    jamba_v0_1_52b,
    mamba2_1_3b,
    mistral_nemo_12b,
    moonshot_v1_16b_a3b,
    qwen2_vl_72b,
    starcoder2_15b,
)
from repro.configs.base import INPUT_SHAPES, ModelConfig

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        starcoder2_15b.CONFIG,
        hubert_xlarge.CONFIG,
        deepseek_v3_671b.CONFIG,
        granite_moe_1b_a400m.CONFIG,
        mamba2_1_3b.CONFIG,
        mistral_nemo_12b.CONFIG,
        moonshot_v1_16b_a3b.CONFIG,
        qwen2_vl_72b.CONFIG,
        jamba_v0_1_52b.CONFIG,
        gemma3_1b.CONFIG,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def all_pairs():
    """All (arch, shape) pairs with their support status."""
    out = []
    for a in ARCHS.values():
        for s in INPUT_SHAPES.values():
            ok, why = a.supports_shape(s.name)
            out.append((a, s, ok, why))
    return out
