"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import LayerSpec, ModelConfig, SSMCfg

_L = LayerSpec(mixer="mamba", ffn="none")

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50_280,
    period=(_L,),
    n_periods=48,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=128),
    pos="none",
    ffn_act="swiglu",
    tie_embeddings=True,
    max_seq=1_048_576,
    source="arXiv:2405.21060 (SSD; d_state=128, expand=2, head_dim=64)",
)
