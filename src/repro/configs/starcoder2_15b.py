"""StarCoder2-15B [arXiv:2402.19173] — dense GQA + RoPE, sliding-window 4096."""
from repro.configs.base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="attn_sliding", ffn="dense")

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    period=(_L,),
    n_periods=40,
    pos="rope",
    rope_theta=100_000.0,
    window=4096,
    ffn_act="gelu",
    norm="layernorm",
    max_seq=524_288,
    source="arXiv:2402.19173 (sliding window 4096; GQA kv=4; RoPE)",
)
