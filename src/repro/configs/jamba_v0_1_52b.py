"""Jamba-v0.1-52B [arXiv:2403.19887] — hybrid Mamba+attn 1:7, MoE 16e top-2.

Jamba block = 8 layers with 1 attention layer (index 3) and MoE on every
other layer. Adaptation note (DESIGN.md §4): Jamba v0.1 uses Mamba-1
(d_state=16); we use our Mamba-2 SSD mixer (d_state=128) so the SSD
Pallas kernel is shared with mamba2-1.3b — same hybrid topology.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoECfg, SSMCfg

_M_D = LayerSpec(mixer="mamba", ffn="dense")
_M_E = LayerSpec(mixer="mamba", ffn="moe")
_A_E = LayerSpec(mixer="attn", ffn="moe")

# 8-layer Jamba block: attn at index 3, MoE on odd indices.
_PERIOD = (_M_D, _M_E, _M_D, _A_E, _M_D, _M_E, _M_D, _M_E)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65_536,
    period=_PERIOD,
    n_periods=4,
    moe=MoECfg(n_experts=16, top_k=2, d_expert=14336, n_shared=0,
               capacity_factor=1.25),
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
    pos="rope",                 # attn layers only; mamba layers position-free
    ffn_act="swiglu",
    max_seq=1_048_576,
    source="arXiv:2403.19887 (1:7 attn:mamba, MoE 16e top-2 every other layer)",
)
