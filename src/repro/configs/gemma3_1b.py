"""Gemma-3-1B [hf:google/gemma-3-1b-pt] — 5:1 local:global sliding attention."""
from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="attn_sliding", ffn="dense")
_GLOBAL = LayerSpec(mixer="attn", ffn="dense")

# 26 layers = 4 x (5 local + 1 global) + 2 local suffix
_PERIOD = (_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL)

CONFIG = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262_144,
    period=_PERIOD,
    n_periods=4,
    suffix=(_LOCAL, _LOCAL),
    pos="rope",
    rope_theta=1_000_000.0,
    window=512,
    ffn_act="geglu",
    tie_embeddings=True,
    max_seq=524_288,
    source="hf:google/gemma-3-1b-pt (5:1 local:global, window 512, kv=1)",
)
