"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] — 32e top-8 MoE."""
from repro.configs.base import LayerSpec, ModelConfig, MoECfg

_L = LayerSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                # per-expert width
    vocab=49_155,
    period=(_L,),
    n_periods=24,
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512, n_shared=0,
               capacity_factor=1.25),
    pos="rope",
    ffn_act="swiglu",
    tie_embeddings=True,
    max_seq=8192,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (32 experts top-8)",
)
