"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense GQA, 128k ctx."""
from repro.configs.base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="attn", ffn="dense")

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,            # q_dim 4096 != d_model (Nemo head_dim override)
    d_ff=14336,
    vocab=131_072,
    period=(_L,),
    n_periods=40,
    pos="rope",
    rope_theta=1_000_000.0,
    ffn_act="swiglu",
    max_seq=131_072,
    source="hf:mistralai/Mistral-Nemo-Base-2407 (GQA kv=8, head_dim=128, 128k)",
)
