"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B] — MoE 64e top-6 (+2 shared).

The assignment labels it [dense] but specifies "MoE 64e top-6"; the model
card is a DeepSeek-V3-style MoE. We implement it as GQA (kv=16 => MHA)
with a dense first layer then MoE layers, per the card.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoECfg

_DENSE = LayerSpec(mixer="attn", ffn="dense")
_MOE = LayerSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,               # per-expert width (also dense-prefix width x8)
    vocab=163_840,
    prefix=(_DENSE,),
    period=(_MOE,),
    n_periods=47,
    moe=MoECfg(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
               capacity_factor=1.25),
    pos="rope",
    rope_theta=50_000.0,
    ffn_act="swiglu",
    max_seq=8192,
    source="hf:moonshotai/Moonlight-16B-A3B (64 routed top-6, 2 shared)",
)
