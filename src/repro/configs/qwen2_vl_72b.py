"""Qwen2-VL-72B [arXiv:2409.12191] — VLM backbone, M-RoPE, dynamic resolution.

Vision frontend (ViT + projector) is a STUB per the brief: ``input_specs``
provides precomputed patch embeddings (B, vision_tokens, d_model) that are
scattered into the token stream; M-RoPE position ids (3, B, S) carry the
temporal/height/width coordinates.
"""
from repro.configs.base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="attn", ffn="dense")

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152_064,
    period=(_L,),
    n_periods=80,
    pos="mrope",
    rope_theta=1_000_000.0,
    ffn_act="swiglu",
    vision_tokens=1024,      # stubbed patch-embedding slots per sequence
    max_seq=131_072,
    source="arXiv:2409.12191 (M-RoPE sections t/h/w; ViT frontend stubbed)",
)
