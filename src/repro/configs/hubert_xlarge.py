"""HuBERT-XLarge [arXiv:2106.07447] — audio encoder-only (w2v2-style backbone).

Frontend (mel + conv feature extractor) is a STUB per the brief:
``input_specs()`` feeds precomputed frame embeddings (B, frames, 1280).
vocab=504 is the masked-unit codebook / classification head.
"""
from repro.configs.base import LayerSpec, ModelConfig

_L = LayerSpec(mixer="attn", ffn="dense")

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    period=(_L,),
    n_periods=48,
    pos="abs",
    causal=False,            # encoder-only: no decode shapes
    embed_inputs=False,      # frame embeddings come from the stubbed frontend
    ffn_act="gelu",
    norm="layernorm",
    max_seq=65_536,
    source="arXiv:2106.07447 (encoder-only, MHA, conv frontend stubbed)",
)
