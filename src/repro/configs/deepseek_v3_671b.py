"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA, 1 shared + 256 routed top-8 MoE, MTP."""
from repro.configs.base import LayerSpec, MLACfg, ModelConfig, MoECfg

_DENSE = LayerSpec(mixer="attn", ffn="dense")
_MOE = LayerSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: latent cache, heads materialized from latents
    head_dim=128,
    d_ff=18432,              # dense-layer FFN width (first 3 layers)
    vocab=129_280,
    prefix=(_DENSE, _DENSE, _DENSE),
    period=(_MOE,),
    n_periods=58,
    moe=MoECfg(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
               capacity_factor=1.25),
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512,
               qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    pos="rope",
    rope_theta=10_000.0,
    ffn_act="swiglu",
    mtp=True,
    max_seq=131_072,
    source="arXiv:2412.19437 (MLA; 1 shared + 256 routed top-8; MTP depth 1)",
)
