"""Serving engine: batched generation with bucketed prefill compilation,
a shared engine pool, and the cascade-server facade.

``GenerationEngine`` replaces the old per-``(seq_len, max_len)`` jit
cache — which recompiled on every new shape the tier-by-tier compaction
produced — with *bucketed* compilation: batch, prompt length and cache
length are rounded up to power-of-two buckets, so the number of compiled
prefill variants is logarithmic in the shape range instead of linear in
the number of distinct request shapes.

Exactness of the bucketing (all verified by tests/test_serving.py):
  * batch padding    — extra rows are computed and sliced off; always exact.
  * cache (max_len)  — decode masks slots beyond the fill level (full
    attention) or by ring-slot position (sliding), so a larger cache is
    always exact.
  * prompt padding   — right-pad tokens, read prefill logits at the true
    last position, start decode at the true length so pad slots are
    overwritten before the mask admits them. Exact for attention-only
    stacks whose ring cache never truncates the padded prompt; engines
    fall back to exact prompt shapes for SSM/hybrid stacks or when the
    sliding window is smaller than the padded prompt.
With ``temperature > 0`` every generated token — including the
post-prefill one, sampled from the prefill logits — goes through the
keyed categorical path and is seed-reproducible per bucket shape (the
noise tensor follows the padded shape); greedy decoding is bit-exact
regardless of bucketing.

Generation is split into two entry points so the scheduler can overlap
tiers (speculative cascade execution, ``repro.serving.sched``):
``prefill_async`` dispatches the prefill and returns a cancellable
``PrefillFuture`` — the sampled post-prefill token plus the KV-cache
handle, still potentially in flight thanks to jax async dispatch —
and ``decode_from`` consumes the future (KV handoff) and runs the
decode loop. ``generate`` is exactly their composition, so the split
is bit-identical by construction. ``PrefillFuture.cancel`` retires a
speculation: the cache/token references are dropped so the device
buffers free, and the pool (``EnginePool.speculate``) untracks it.

``CascadeServer`` is the serving facade over the repo's single cascade
executor (``repro.core.cascade.execute_cascade``); the full three-strategy
pipeline (cache + prompt adaptation + cascade) lives in
``repro.serving.pipeline``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cascade import CascadeTier, execute_cascade
from repro.models import transformer as T


def bucket_size(x: int, floor: int) -> int:
    """Next power of two >= x, floored at ``floor`` — keeps the number of
    compiled shape variants O(log range) instead of O(distinct shapes)."""
    b = max(1, floor)
    while b < x:
        b *= 2
    return b


@dataclasses.dataclass
class PrefillFuture:
    """Cancellable handle to one dispatched prefill.

    Holds the post-prefill sampled token and the KV-cache handle (both
    jax arrays, possibly still computing — dispatch is async), plus the
    shape/seed bookkeeping ``decode_from`` needs to continue exactly
    where ``generate`` would. Exactly one of three things happens to a
    future: it is *committed* (``engine.decode_from`` — KV handoff into
    the decode loop), *cancelled* (``cancel`` — the device references
    are dropped so XLA can free the cache buffers; a cancelled
    speculation is never charged because its consumer never ran), or
    leaked with the engine (GC retires it). Commit and cancel both fire
    the one-shot ``_retire_cb`` so an owning ``EnginePool`` can untrack
    the in-flight speculation.
    """

    engine: "GenerationEngine"
    n_new: int
    b: int                      # true batch rows (callers see [:b])
    b_b: int                    # padded batch bucket
    s: int                      # true prompt length
    max_len: int                # KV-cache bucket length
    seed: int = 0
    cancelled: bool = False
    consumed: bool = False
    _tok: object = None         # (b_b, 1) int32 post-prefill token
    _cache: object = None       # KV-cache pytree (the handoff handle)
    _rkey: object = None        # PRNG state after the post-prefill sample
    _retire_cb: object = None   # pool untrack hook, fired exactly once

    @property
    def live(self) -> bool:
        """Still holding device state: neither committed nor cancelled."""
        return not (self.cancelled or self.consumed)

    def cancel(self):
        """Retire the speculation: drop the KV cache and token references
        (jax frees the device buffers once nothing holds them) and
        untrack from the owning pool. Idempotent; a no-op on a future
        already consumed by ``decode_from``."""
        if not self.live:
            return
        self.cancelled = True
        self._tok = self._cache = self._rkey = None
        self._retire()

    def _retire(self):
        cb, self._retire_cb = self._retire_cb, None
        if cb is not None:
            cb(self)


@dataclasses.dataclass
class GenerationEngine:
    """Batched prefill+decode generation for one model, bucket-compiled."""

    cfg: ModelConfig
    params: dict
    max_new_tokens: int = 16
    temperature: float = 0.0
    batch_floor: int = 8        # batch sizes bucketed to pow2 >= this
    seq_floor: int = 16         # prompt/cache lengths bucketed likewise
    pad_token: int = 0
    # pin this engine to one jax.Device (sharding.placement): params are
    # committed there, so prefill/decode — and the KV cache between
    # decode steps — run and stay on that device. None = default device.
    device: object | None = None
    # ... or shard it over a mesh slice (sharding.tier_mesh): params are
    # sharded per sharding.rules (FSDP over "data", tensor axes over
    # "model"), activations over batch, KV caches over heads, and every
    # prefill/decode runs as a pjit-sharded computation on the slice.
    # The layer stack is folded (models.transformer.fold_stack) so the
    # whole depth scans as one stacked leaf — compile count stays O(1)
    # in depth. Mutually exclusive with ``device``.
    mesh: object | None = None

    def __post_init__(self):
        if self.mesh is not None and self.device is not None:
            raise ValueError("pass device= or mesh=, not both")
        if self.mesh is not None:
            from repro.sharding import tier_mesh
            self.cfg, self.params = T.fold_stack(self.cfg, self.params)
            self._param_shardings = tier_mesh.tier_param_shardings(
                self.params, self.mesh)
            self.params = jax.device_put(self.params, self._param_shardings)
        cfg = self.cfg
        if self.device is not None:
            self.params = jax.device_put(self.params, self.device)
        self._prefill_fns: dict[tuple[int, int, int], Callable] = {}
        self.compile_stats = {"prefill_compiles": 0, "prefill_calls": 0}

        def _decode_body(params, cache, tok, pos, key):
            logits, cache = T.decode_step(params, cache, tok, pos, cfg)
            logits = logits[:, -1]
            if self.temperature > 0:
                nxt = jax.random.categorical(key, logits / self.temperature)
            else:
                nxt = jnp.argmax(logits, -1)
            return nxt[:, None].astype(jnp.int32), cache

        self._decode_body = _decode_body
        self._decode = jax.jit(_decode_body)
        # mesh-sharded decode variants, keyed by (batch, cache) bucket:
        # unlike the single-device jit above (shardings propagate from
        # committed inputs), the pjit path pins in/out shardings so the
        # KV-cache layout is *stable* across the prefill -> decode
        # handoff — a PrefillFuture's cache re-enters decode with
        # exactly the layout prefill committed, never a GSPMD re-guess
        self._decode_fns: dict[tuple[int, int], Callable] = {}
        self.decode_shardings: dict[tuple[int, int], tuple] = {}

    def _seq_paddable(self, seq_bucket: int) -> bool:
        """Right-padding the prompt is exact iff every mixer is attention
        and no sliding-window ring buffer would evict padded-prompt slots
        before decode overwrites them (i.e. padded prompt fits the window).
        """
        specs = self.cfg.layers
        if any(not s.mixer.startswith("attn") for s in specs):
            return False
        if self.cfg.window and any(s.mixer == "attn_sliding" for s in specs):
            return seq_bucket < self.cfg.window
        return True

    def _prefill_fn(self, key: tuple[int, int, int]) -> Callable:
        b_b, s_b, max_len = key
        if key not in self._prefill_fns:
            self.compile_stats["prefill_compiles"] += 1

            def fn(p, toks, last):
                return T.prefill(p, {"tokens": toks}, self.cfg,
                                 max_len=max_len, last_index=last)

            if self.mesh is None:
                self._prefill_fns[key] = jax.jit(fn)
            else:
                # pjit over the tier's slice: NamedSharding in/out
                # shardings per bucket key (batch over "data", KV cache
                # per sharding.rules — heads over "model" when they
                # divide it), so GSPMD never has to guess a layout.
                from repro.sharding import rules, tier_mesh
                tok_sh = tier_mesh.batch_sharding(self.mesh, b_b)
                rep = jax.sharding.NamedSharding(
                    self.mesh, jax.sharding.PartitionSpec())
                logits_s, cache_s = jax.eval_shape(
                    fn, self.params,
                    jax.ShapeDtypeStruct((b_b, s_b), jnp.int32),
                    jax.ShapeDtypeStruct((), jnp.int32))
                out_sh = (rules.logits_sharding(self.mesh, self.cfg, b_b),
                          rules.cache_shardings(cache_s, self.mesh,
                                                self.cfg))
                self._prefill_fns[key] = jax.jit(
                    fn,
                    in_shardings=(self._param_shardings, tok_sh, rep),
                    out_shardings=out_sh)
        return self._prefill_fns[key]

    def _decode_fn(self, b_b: int, max_len: int, cache) -> Callable:
        """The decode step for one (batch, cache) bucket: the shared jit
        on a single device; on a mesh, a pjit variant with in/out
        shardings pinned to the prefill's committed layout (tokens over
        "data", KV cache per ``sharding.rules``) so the cache layout
        cannot drift across decode steps or the prefill->decode
        handoff."""
        if self.mesh is None:
            return self._decode
        key = (b_b, max_len)
        if key not in self._decode_fns:
            from repro.sharding import rules, tier_mesh
            tok_sh = tier_mesh.batch_sharding(self.mesh, b_b)
            rep = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec())
            cache_sh = rules.cache_shardings(cache, self.mesh, self.cfg)
            self.decode_shardings[key] = (tok_sh, cache_sh)
            self._decode_fns[key] = jax.jit(
                self._decode_body,
                in_shardings=(self._param_shardings, cache_sh, tok_sh,
                              rep, rep),
                out_shardings=(tok_sh, cache_sh))
        return self._decode_fns[key]

    def prefill_async(self, tokens: np.ndarray, n_new: int | None = None,
                      seed: int = 0) -> PrefillFuture:
        """Dispatch the prefill for ``tokens`` (B, S) and return a
        cancellable ``PrefillFuture``. jax dispatch is asynchronous, so
        this returns as soon as the prefill (and the post-prefill token
        sample, which follows the exact keyed path ``generate`` uses) is
        enqueued on the engine's device/mesh — the caller overlaps it
        with other work and later either commits (``decode_from``) or
        cancels (``PrefillFuture.cancel``)."""
        if n_new is None:                  # NOT `or`: an explicit 0 is 0
            n_new = self.max_new_tokens
        b, s = tokens.shape
        if n_new <= 0:
            return PrefillFuture(self, n_new=0, b=b, b_b=b, s=s,
                                 max_len=0, seed=seed)
        b_b = bucket_size(b, self.batch_floor)
        s_b = bucket_size(s, self.seq_floor)
        if not self._seq_paddable(s_b):
            s_b = s
        max_len = bucket_size(s_b + n_new, self.seq_floor)

        toks = np.full((b_b, s_b), self.pad_token, tokens.dtype)
        toks[:b, :s] = tokens
        toks[b:, :s] = tokens[-1]          # batch filler: replicate a row

        self.compile_stats["prefill_calls"] += 1
        fn = self._prefill_fn((b_b, s_b, max_len))
        if self.mesh is not None:
            # the across-slice-boundary hop: host-compacted batches are
            # device_put onto the tier's slice, batch split over "data"
            from repro.sharding import tier_mesh
            toks_dev = jax.device_put(
                toks, tier_mesh.batch_sharding(self.mesh, b_b))
        else:
            toks_dev = jnp.asarray(toks)
        logits, cache = fn(self.params, toks_dev, jnp.int32(s - 1))
        rkey = jax.random.PRNGKey(seed)
        last_logits = logits[:, -1]
        if self.temperature > 0:
            # the post-prefill token goes through the same keyed
            # categorical path as every later token — not argmax
            rkey, sub = jax.random.split(rkey)
            nxt = jax.random.categorical(sub, last_logits / self.temperature)
        else:
            nxt = jnp.argmax(last_logits, -1)
        nxt = nxt[:, None].astype(jnp.int32)
        return PrefillFuture(self, n_new=n_new, b=b, b_b=b_b, s=s,
                             max_len=max_len, seed=seed, _tok=nxt,
                             _cache=cache, _rkey=rkey)

    def decode_from(self, fut: PrefillFuture) -> np.ndarray:
        """Commit a ``PrefillFuture``: take the KV-cache handoff and run
        the decode loop to ``(B, n_new)`` generated tokens — bit-identical
        to the ``generate`` call the future's ``prefill_async`` started,
        because it *is* the second half of that call."""
        if fut.engine is not self:
            raise ValueError("PrefillFuture belongs to a different engine")
        if fut.cancelled:
            raise RuntimeError("cannot decode a cancelled PrefillFuture "
                               "(its KV cache was retired)")
        if fut.consumed:
            raise RuntimeError("PrefillFuture already consumed")
        fut.consumed = True
        if fut.n_new <= 0:
            fut._retire()
            return np.zeros((fut.b, 0), np.int32)
        nxt, cache, rkey = fut._tok, fut._cache, fut._rkey
        fut._tok = fut._cache = fut._rkey = None
        fut._retire()
        decode = self._decode_fn(fut.b_b, fut.max_len, cache)
        out = [np.asarray(nxt)]
        for i in range(fut.n_new - 1):
            rkey, sub = jax.random.split(rkey)
            nxt, cache = decode(self.params, cache, nxt,
                                jnp.int32(fut.s + i), sub)
            out.append(np.asarray(nxt))
        return np.concatenate(out, axis=1)[:fut.b]

    def generate(self, tokens: np.ndarray, n_new: int | None = None,
                 seed: int = 0) -> np.ndarray:
        """tokens (B, S) -> generated (B, n_new). Exactly
        ``decode_from(prefill_async(...))`` — the split entry points the
        speculative scheduler drives are the same code path."""
        return self.decode_from(self.prefill_async(tokens, n_new, seed))


@dataclasses.dataclass
class EnginePool:
    """Shared ``GenerationEngine`` pool: one engine (and so one bucketed
    jit cache) per model config, reused by every tier/pipeline that serves
    that model."""

    max_new_tokens: int = 16
    temperature: float = 0.0

    def __post_init__(self):
        self._engines: dict[tuple, GenerationEngine] = {}
        self._params_refs: dict[tuple, dict] = {}
        # in-flight speculative PrefillFutures, tracked per engine key
        # (i.e. per tier×placement) so an idle device's speculations can
        # be cancelled wholesale when the real accept mask lands.
        self._speculative: dict[tuple, list] = {}
        self.spec_stats = {"issued": 0, "committed": 0, "cancelled": 0}

    @staticmethod
    def _key(cfg: ModelConfig, params: dict, device=None, mesh=None) -> tuple:
        # key on weight identity too: two tiers can share an architecture
        # (same cfg.name) with different trained params, and must not
        # silently serve each other's model. The pool itself pins the
        # caller's pytree (_params_refs) so id(params) cannot be
        # recycled for the key's lifetime — a device-pinned engine
        # rebinds its params to the device copy and must not be the one
        # carrying that guarantee. Device — or mesh-slice device set +
        # shape — is part of the key: the same weights pinned to two
        # devices or sharded over two slices (sharding.placement /
        # sharding.tier_mesh) are distinct engines with independent
        # NamedSharding-keyed jit caches and KV-cache residency.
        if mesh is not None:
            where = ("mesh", mesh.devices.shape,
                     tuple(int(d.id) for d in mesh.devices.flat))
        elif device is not None:
            where = (device.platform, device.id)
        else:
            where = None
        return (cfg.name, id(params), where)

    def get(self, cfg: ModelConfig, params: dict,
            device=None, mesh=None) -> GenerationEngine:
        key = self._key(cfg, params, device, mesh)
        eng = self._engines.get(key)
        if eng is None:
            eng = GenerationEngine(cfg, params,
                                   max_new_tokens=self.max_new_tokens,
                                   temperature=self.temperature,
                                   device=device, mesh=mesh)
            self._engines[key] = eng
            self._params_refs[key] = params
        return eng

    def speculate(self, cfg: ModelConfig, params: dict,
                  tokens: np.ndarray, n_new: int | None = None,
                  seed: int = 0, device=None, mesh=None) -> "PrefillFuture":
        """Dispatch a *speculative* prefill on the (tier, placement)
        engine and track the future. The caller later resolves it with
        ``commit`` (runs the decode — now charged work) or ``cancel``
        (retires the KV cache — only wall-clock was burnt). Both paths
        untrack the future via its retire hook."""
        key = self._key(cfg, params, device, mesh)
        eng = self.get(cfg, params, device=device, mesh=mesh)
        fut = eng.prefill_async(tokens, n_new, seed)
        fut._retire_cb = lambda f, key=key: self._untrack(key, f)
        self._speculative.setdefault(key, []).append(fut)
        self.spec_stats["issued"] += 1
        return fut

    def commit(self, fut: "PrefillFuture") -> np.ndarray:
        """Commit a tracked speculation: KV handoff into the decode loop,
        returning the generated tokens ``generate`` would have."""
        if not fut.live:
            raise RuntimeError("cannot commit a retired PrefillFuture")
        self.spec_stats["committed"] += 1
        return fut.engine.decode_from(fut)

    def cancel(self, fut: "PrefillFuture") -> None:
        """Cancel a tracked speculation, retiring its KV cache."""
        if not fut.live:
            return
        self.spec_stats["cancelled"] += 1
        fut.cancel()

    def cancel_all(self, cfg: ModelConfig = None, params: dict = None,
                   device=None, mesh=None) -> int:
        """Cancel every live speculative future — for one engine key when
        ``cfg``/``params`` are given, across the whole pool otherwise
        (shutdown). Returns how many were cancelled."""
        if cfg is not None:
            keys = [self._key(cfg, params, device, mesh)]
        else:
            keys = list(self._speculative)
        n = 0
        for key in keys:
            for fut in list(self._speculative.get(key, ())):
                if fut.live:
                    self.cancel(fut)
                    n += 1
        return n

    def inflight(self) -> int:
        """Live (neither committed nor cancelled) speculative futures."""
        return sum(len(v) for v in self._speculative.values())

    def _untrack(self, key: tuple, fut: "PrefillFuture") -> None:
        lst = self._speculative.get(key)
        if lst is not None:
            try:
                lst.remove(fut)
            except ValueError:
                pass
            if not lst:
                self._speculative.pop(key, None)

    def __len__(self) -> int:
        return len(self._engines)

    @property
    def compile_stats(self) -> dict:
        """Aggregate prefill compile/call counts across the pool."""
        out = {"prefill_compiles": 0, "prefill_calls": 0}
        for eng in self._engines.values():
            for k in out:
                out[k] += eng.compile_stats[k]
        return out


@dataclasses.dataclass
class Tier:
    name: str
    answer: Callable            # tokens (n, L) -> answers (n,)
    cost: Callable              # tokens (n, L) -> per-query cost (n,)


def generation_tier(name: str, engine: GenerationEngine, price,
                    decode_answer: Callable, n_new: int = 1,
                    pad_token: int = 0) -> Tier:
    """A cascade tier backed by a pooled ``GenerationEngine``.

    decode_answer(generated (b, n_new)) -> answer ids (b,);
    price: ``ApiCost`` used for exact token-count accounting.
    """

    def answer(tokens: np.ndarray) -> np.ndarray:
        return np.asarray(decode_answer(engine.generate(tokens, n_new)))

    def cost(tokens: np.ndarray) -> np.ndarray:
        n_in = (tokens != pad_token).sum(-1)
        return np.asarray(price.query_cost(n_in, np.full_like(n_in, n_new)))

    return Tier(name, answer, cost)


@dataclasses.dataclass
class CascadeServer:
    """FrugalGPT cascade as a serving policy (tier-by-tier compaction).

    Thin facade over the repo's single cascade executor; use
    ``repro.serving.pipeline.ServingPipeline`` for the full
    cache + prompt-adaptation + cascade request path.
    """

    tiers: Sequence[Tier]
    thresholds: Sequence[float]         # len = len(tiers) - 1
    scorer: Callable                    # (tokens, answers) -> scores (n,)
    batch_size: int = 256

    def serve(self, tokens: np.ndarray) -> dict:
        t0 = time.time()
        ct = [CascadeTier(t.name, lambda q, t=t: (t.answer(q), t.cost(q)))
              for t in self.tiers]
        res = execute_cascade(ct, self.thresholds,
                              lambda q, a, _j: self.scorer(q, a),
                              tokens, batch_size=self.batch_size)
        return {
            "answers": np.asarray(res["answers"]).astype(np.int32),
            "cost": res["cost"],
            "stopped_at": res["stopped_at"],
            "tier_counts": [c for c in res["tier_counts"]],
            "latency_s": time.time() - t0,
        }
