"""Serving engine: generation (prefill + decode loop) and the
cascade-aware tiered scheduler (the production realization of FrugalGPT's
LLM cascade — DESIGN.md §3).

Queries hit tier 1 as one batch; the scorer marks unreliable answers;
those are *compacted* and re-batched to tier 2, etc. Each tier is an
independently sharded model (pjit on the production mesh; plain jit on
the CPU CI runner).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass
class GenerationEngine:
    """Batched prefill+decode generation for one model."""

    cfg: ModelConfig
    params: dict
    max_new_tokens: int = 16
    temperature: float = 0.0

    def __post_init__(self):
        cfg = self.cfg

        @jax.jit
        def _prefill(params, batch, max_len):
            return T.prefill(params, batch, cfg, max_len=int(max_len))

        self._prefill_fns = {}

        @functools.partial(jax.jit, static_argnums=())
        def _decode(params, cache, tok, pos, key):
            logits, cache = T.decode_step(params, cache, tok, pos, cfg)
            logits = logits[:, -1]
            if self.temperature > 0:
                nxt = jax.random.categorical(key, logits / self.temperature)
            else:
                nxt = jnp.argmax(logits, -1)
            return nxt[:, None].astype(jnp.int32), cache

        self._decode = _decode

    def generate(self, tokens: np.ndarray, n_new: int | None = None,
                 seed: int = 0) -> np.ndarray:
        """tokens (B, S) -> generated (B, n_new)."""
        n_new = n_new or self.max_new_tokens
        b, s = tokens.shape
        max_len = s + n_new
        key = (s, max_len)
        if key not in self._prefill_fns:
            self._prefill_fns[key] = jax.jit(
                lambda p, bt: T.prefill(p, bt, self.cfg, max_len=max_len))
        logits, cache = self._prefill_fns[key](self.params,
                                               {"tokens": jnp.asarray(tokens)})
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = [np.asarray(nxt)]
        rkey = jax.random.PRNGKey(seed)
        for i in range(n_new - 1):
            rkey, sub = jax.random.split(rkey)
            nxt, cache = self._decode(self.params, cache, nxt,
                                      jnp.int32(s + i), sub)
            out.append(np.asarray(nxt))
        return np.concatenate(out, axis=1)


@dataclasses.dataclass
class Tier:
    name: str
    answer: Callable            # tokens (n, L) -> answers (n,)
    cost: Callable              # tokens (n, L) -> per-query cost (n,)


@dataclasses.dataclass
class CascadeServer:
    """FrugalGPT cascade as a serving policy (tier-by-tier compaction)."""

    tiers: Sequence[Tier]
    thresholds: Sequence[float]         # len = len(tiers) - 1
    scorer: Callable                    # (tokens, answers) -> scores (n,)
    batch_size: int = 256

    def serve(self, tokens: np.ndarray) -> dict:
        n = tokens.shape[0]
        answers = np.zeros(n, np.int32)
        cost = np.zeros(n, np.float64)
        stopped_at = np.full(n, len(self.tiers) - 1, np.int32)
        pending = np.arange(n)
        t0 = time.time()
        tier_counts = []
        for j, tier in enumerate(self.tiers):
            if len(pending) == 0:
                tier_counts.append(0)
                continue
            tier_counts.append(len(pending))
            toks = tokens[pending]
            ans = np.zeros(len(pending), np.int32)
            for i in range(0, len(pending), self.batch_size):
                ans[i:i + self.batch_size] = tier.answer(
                    toks[i:i + self.batch_size])
            cost[pending] += tier.cost(toks)
            if j < len(self.tiers) - 1:
                s = self.scorer(toks, ans)
                accept = s >= self.thresholds[j]
            else:
                accept = np.ones(len(pending), bool)
            done = pending[accept]
            answers[done] = ans[accept]
            stopped_at[done] = j
            pending = pending[~accept]
        return {
            "answers": answers,
            "cost": cost,
            "stopped_at": stopped_at,
            "tier_counts": tier_counts,
            "latency_s": time.time() - t0,
        }
