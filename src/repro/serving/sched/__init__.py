"""``repro.serving.sched`` — SLO-aware parallel tier scheduling.

The layer between async ingress (``repro.serving.ingress``) and the
cascade step (``repro.core.cascade.tier_step``):

``scheduler``  ``TierScheduler`` — one worker thread per cascade tier,
               concurrent chunk decoding, adaptive holdback, bounded
               queues with overload shedding/degradation.
``policy``     ``SLOConfig`` (deadlines, holdback cap, queue caps,
               overload policy, speculation dials) and the pure decision
               functions (``holdback_timeout``, ``admit_decision``,
               ``speculation_candidate``, ``may_speculate``).
``estimator``  per-tier EWMA service-time / queue-delay estimators and
               utilization counters feeding the policy.

``ServingPipeline.serve_stream`` / ``aserve`` run on this scheduler by
default (``parallel=False`` selects the serial ``ContinuousBatcher``).
"""
from repro.serving.sched.estimator import Ewma, TierEstimator  # noqa: F401
from repro.serving.sched.policy import (  # noqa: F401
    OVERLOAD_POLICIES,
    SLOConfig,
    admit_decision,
    holdback_timeout,
    may_speculate,
    rank_speculation,
    speculation_candidate,
    speculation_ev,
)
from repro.serving.sched.scheduler import TierScheduler  # noqa: F401
