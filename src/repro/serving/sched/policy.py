"""Admission and dispatch policy for the SLO-aware tier scheduler.

Three decisions live here, kept separate from the worker machinery in
``sched.scheduler`` so they stay unit-testable without threads:

  * **adaptive holdback** (``holdback_timeout``) — how long a tier may
    keep holding a partial chunk hoping for more fill. The fixed
    ``holdback`` window of the serial batcher becomes deadline-driven:
    ship when the head-of-line request's predicted completion
    (now + safety x EWMA service time) would miss its deadline, capped
    by ``max_holdback_s`` for requests without deadlines.
  * **admission under overload** (``admit_decision``) — bounded-queue
    backpressure. When tier 0's wait queue hits ``queue_cap`` the
    overload policy decides: ``"reject"`` sheds the arrival outright;
    ``"degrade"`` admits it pinned to the cheapest tier (its answer is
    accepted regardless of score — the paper's cost/accuracy dial
    applied to load: under pressure you trade accuracy, not
    availability), shedding only past a hard 2x cap.
  * **per-request deadlines** (``SLOConfig.deadline_for``) — an
    explicit per-request deadline wins; otherwise ``deadline_s`` sets
    one relative to arrival; otherwise no deadline (pure fill-driven
    dispatch, like the serial batcher).
"""
from __future__ import annotations

import dataclasses

OVERLOAD_POLICIES = ("reject", "degrade")

#: admission verdicts
ADMIT, DEGRADE, SHED = "admit", "degrade", "shed"


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Service-level objectives for one stream."""

    #: default per-request deadline, seconds after arrival (None = no SLO)
    deadline_s: float | None = None
    #: cap on how long a partial chunk may wait for fill (the serial
    #: batcher's fixed window becomes this upper bound)
    max_holdback_s: float = 0.02
    #: margin multiplied onto the predicted service time when testing a
    #: deadline — absorbs EWMA underestimates and queueing jitter
    service_safety: float = 1.25
    #: cold-start service-time guess (seconds) before the first chunk of
    #: a tier is observed
    init_service_s: float = 0.0
    #: bounded per-tier wait queue; None = unbounded (no backpressure)
    queue_cap: int | None = None
    #: what to do with arrivals once tier 0's queue is full
    overload: str = "reject"

    def __post_init__(self):
        if self.overload not in OVERLOAD_POLICIES:
            raise ValueError(f"unknown overload policy {self.overload!r}; "
                             f"expected one of {OVERLOAD_POLICIES}")
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {self.queue_cap}")
        if self.overload != "reject" and self.queue_cap is None:
            raise ValueError(
                f"overload={self.overload!r} never triggers without a "
                "queue_cap: set one (bounded queues are what admission "
                "decisions are made against)")
        if self.max_holdback_s < 0:
            raise ValueError("max_holdback_s must be >= 0")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0")
        if self.service_safety <= 0:
            raise ValueError("service_safety must be > 0")

    def deadline_for(self, arrival: float,
                     explicit: float | None = None) -> float | None:
        """Absolute deadline (stream clock) for a request arriving at
        ``arrival``; an explicit per-request deadline wins."""
        if explicit is not None:
            return float(explicit)
        if self.deadline_s is None:
            return None
        return float(arrival) + self.deadline_s


def holdback_timeout(head, est, now: float, slo: SLOConfig) -> float:
    """Seconds tier ``head.tier_pos`` may keep holding its partial chunk
    before dispatching, given the head-of-line request and the tier's
    estimator. ``<= 0`` means ship NOW: either the head has aged past
    ``max_holdback_s``, or its predicted completion
    (now + safety x EWMA service) would miss its deadline."""
    t_age = head.t_enqueued + slo.max_holdback_s - now
    if head.deadline is None:
        return t_age
    est_s = slo.service_safety * est.predicted_service(slo.init_service_s)
    t_slo = head.deadline - est_s - now
    return min(t_age, t_slo)


def admit_decision(queue_len: int, slo: SLOConfig) -> str:
    """Admission verdict for one arrival given tier 0's queue length."""
    cap = slo.queue_cap
    if cap is None or queue_len < cap:
        return ADMIT
    if slo.overload == "degrade" and queue_len < 2 * cap:
        return DEGRADE
    return SHED
