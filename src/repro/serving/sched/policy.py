"""Admission and dispatch policy for the SLO-aware tier scheduler.

Three decisions live here, kept separate from the worker machinery in
``sched.scheduler`` so they stay unit-testable without threads:

  * **adaptive holdback** (``holdback_timeout``) — how long a tier may
    keep holding a partial chunk hoping for more fill. The fixed
    ``holdback`` window of the serial batcher becomes deadline-driven:
    ship when the head-of-line request's predicted completion
    (now + safety x EWMA service time) would miss its deadline, capped
    by ``max_holdback_s`` for requests without deadlines.
  * **admission under overload** (``admit_decision``) — bounded-queue
    backpressure. When the entry tier's wait queue hits ``queue_cap``
    the overload policy decides: ``"reject"`` sheds the arrival
    outright; ``"degrade"`` admits it at a degraded entry (the cheapest
    tier by default; the cheapest tier clearing a reduced predicted-
    accept bar when a contextual router is attached — its answer is
    accepted regardless of score: the paper's cost/accuracy dial
    applied to load), shedding only past a hard 2x cap. With
    ``predictive_shed`` on, an arrival whose *predicted* completion
    (EWMA queue delay + safety x EWMA service time) would already miss
    its deadline is shed before the queue ever fills — queue length is
    a lagging overload signal, the wait estimate is a leading one.
  * **per-request deadlines** (``SLOConfig.deadline_for``) — an
    explicit per-request deadline wins; otherwise ``deadline_s`` sets
    one relative to arrival; otherwise no deadline (pure fill-driven
    dispatch, like the serial batcher).
  * **speculation** (``speculation_candidate``, ``may_speculate``) —
    whether an *idle* tier may burn cycles pre-invoking rows still
    decoding on earlier tiers (speculative cascade execution,
    ``sched.scheduler``). Candidate selection uses the contextual
    router's per-(query, tier) accept probabilities: a row qualifies
    when every tier between its current position and the speculating
    tier is predicted to reject (probability below ``spec_bar``); with
    no router attached every decoding row qualifies (cold fallback).
    The idle budget (``spec_idle_frac``) caps wasted device-seconds as
    a fraction of elapsed stream time, tested *leading* — the tier's
    EWMA-predicted chunk service time counts against the budget before
    the speculative chunk is issued, not after it was wasted.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.resilience import BreakerConfig, RetryPolicy

OVERLOAD_POLICIES = ("reject", "degrade")

#: admission verdicts
ADMIT, DEGRADE, SHED = "admit", "degrade", "shed"


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Service-level objectives for one stream."""

    #: default per-request deadline, seconds after arrival (None = no SLO)
    deadline_s: float | None = None
    #: cap on how long a partial chunk may wait for fill (the serial
    #: batcher's fixed window becomes this upper bound)
    max_holdback_s: float = 0.02
    #: margin multiplied onto the predicted service time when testing a
    #: deadline — absorbs EWMA underestimates and queueing jitter
    service_safety: float = 1.25
    #: cold-start service-time guess (seconds) before the first chunk of
    #: a tier is observed
    init_service_s: float = 0.0
    #: bounded per-tier wait queue; None = unbounded (no backpressure)
    queue_cap: int | None = None
    #: what to do with arrivals once the entry tier's queue is full
    overload: str = "reject"
    #: shed arrivals whose predicted completion (EWMA queue delay +
    #: safety x EWMA service) would miss their deadline — leading-signal
    #: shedding, acts before any queue fills (needs deadlines to bite)
    predictive_shed: bool = False
    #: speculative cascade execution — idle tiers pre-invoke predicted-
    #: reject rows still decoding upstream. Opt-in; never changes
    #: answers, charged cost, stopped_at, or tier_counts (speculation
    #: only moves wall-clock): results are committed through the normal
    #: ``tier_step`` path and charged only if the row actually escalates
    speculate: bool = False
    #: how many tiers ahead of a row's current position may speculate on
    #: it (1 = only the immediate next tier)
    spec_depth: int = 1
    #: router-probability floor: a tier speculates on a row only when
    #: every intermediate tier's predicted accept probability is below
    #: this bar; with no router attached, all decoding rows qualify
    spec_bar: float = 0.5
    #: cap on wasted (cancelled-speculation) device-seconds as a
    #: fraction of elapsed stream time; None = unlimited idle burn
    spec_idle_frac: float | None = 0.5
    #: per-tier retry for TierFault invoke failures
    #: (repro.serving.resilience.retry) — None = fail straight into the
    #: breaker/failover path
    retry: RetryPolicy | None = None
    #: per-tier circuit breakers (repro.serving.resilience.breaker) —
    #: None = no availability tracking; rows bound for an open tier skip
    #: it and escalate forward
    breaker: BreakerConfig | None = None

    def __post_init__(self):
        if self.overload not in OVERLOAD_POLICIES:
            raise ValueError(f"unknown overload policy {self.overload!r}; "
                             f"expected one of {OVERLOAD_POLICIES}")
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {self.queue_cap}")
        if self.overload != "reject" and self.queue_cap is None:
            raise ValueError(
                f"overload={self.overload!r} never triggers without a "
                "queue_cap: set one (bounded queues are what admission "
                "decisions are made against)")
        if self.max_holdback_s < 0:
            raise ValueError("max_holdback_s must be >= 0")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0")
        if self.service_safety <= 0:
            raise ValueError("service_safety must be > 0")
        if self.spec_depth < 1:
            raise ValueError("spec_depth must be >= 1")
        if not 0.0 <= self.spec_bar <= 1.0:
            raise ValueError("spec_bar must be in [0, 1]")
        if self.spec_idle_frac is not None and self.spec_idle_frac <= 0:
            raise ValueError("spec_idle_frac must be > 0 (or None for "
                             "unlimited idle burn)")
        if self.retry is not None and not isinstance(self.retry,
                                                     RetryPolicy):
            raise ValueError(f"retry must be a RetryPolicy or None, got "
                             f"{type(self.retry).__name__}")
        if self.breaker is not None and not isinstance(self.breaker,
                                                       BreakerConfig):
            raise ValueError(f"breaker must be a BreakerConfig or None, "
                             f"got {type(self.breaker).__name__}")

    def deadline_for(self, arrival: float,
                     explicit: float | None = None) -> float | None:
        """Absolute deadline (stream clock) for a request arriving at
        ``arrival``; an explicit per-request deadline wins."""
        if explicit is not None:
            return float(explicit)
        if self.deadline_s is None:
            return None
        return float(arrival) + self.deadline_s


def holdback_timeout(head, est, now: float, slo: SLOConfig,
                     max_holdback_s: float | None = None) -> float:
    """Seconds tier ``head.tier_pos`` may keep holding its partial chunk
    before dispatching, given the head-of-line request and the tier's
    estimator. ``<= 0`` means ship NOW: either the head has aged past
    ``max_holdback_s``, or its predicted completion
    (now + safety x EWMA service) would miss its deadline.
    ``max_holdback_s`` overrides the config window when given — the
    budget governor's holdback dial stretches/shrinks it under
    under/overspend without rebuilding the frozen ``SLOConfig``."""
    if max_holdback_s is None:
        max_holdback_s = slo.max_holdback_s
    t_age = head.t_enqueued + max_holdback_s - now
    if head.deadline is None:
        return t_age
    est_s = slo.service_safety * est.predicted_service(slo.init_service_s)
    t_slo = head.deadline - est_s - now
    return min(t_age, t_slo)


def admit_decision(queue_len: int, slo: SLOConfig, *, est=None,
                   now: float | None = None,
                   deadline: float | None = None) -> str:
    """Admission verdict for one arrival given its entry tier's queue
    length — and, with ``predictive_shed``, the tier's estimator: an
    arrival predicted to finish past its deadline (now + EWMA queue
    delay + safety x EWMA service time) is shed while the queue is
    still short, instead of waiting for the lagging queue-length signal.
    The estimator must have observed at least one chunk (a cold tier
    never predictively sheds — the first dispatch trains it)."""
    if (slo.predictive_shed and est is not None and now is not None
            and deadline is not None and est.service.n):
        wait = est.queue_delay.value
        service = slo.service_safety * est.predicted_service(
            slo.init_service_s)
        if now + wait + service > deadline:
            # under the 'degrade' contract (trade accuracy, not
            # availability) a predicted miss on the *routed* tier may
            # still be answerable in time on a cheaper one: degrade
            # within the hard 2x bound instead of shedding outright
            if slo.overload == "degrade":
                cap = slo.queue_cap
                return (DEGRADE if cap is None or queue_len < 2 * cap
                        else SHED)
            return SHED
    cap = slo.queue_cap
    if cap is None or queue_len < cap:
        return ADMIT
    if slo.overload == "degrade" and queue_len < 2 * cap:
        return DEGRADE
    return SHED


def speculation_candidate(probs, cur: int, target: int, bar: float) -> bool:
    """May tier ``target`` speculate on a row currently decoding at tier
    ``cur``? Yes when the router predicts *every* tier in
    ``[cur, target)`` rejects the row (accept probability below
    ``bar``) — a predicted accept anywhere in between means the row
    likely never reaches ``target`` and the prefill would be wasted.
    ``probs`` is the row's per-tier accept-probability vector from the
    contextual router; ``None`` (no router / cold router) falls back to
    treating every decoding row as a candidate."""
    if probs is None:
        return True
    return bool(np.all(np.asarray(probs)[cur:target] < bar))


def speculation_ev(probs, cur: int, target: int,
                   predicted_s: float) -> float:
    """Expected value of tier ``target`` pre-invoking a row currently
    decoding at tier ``cur``: P(the row actually escalates all the way
    to ``target``) x the tier's EWMA-predicted service time — i.e. the
    expected wall-clock the pre-invoke removes from the critical path.
    P(reach) is the product of the router's per-tier *reject*
    probabilities over ``[cur, target)``; with no router attached
    (``probs`` None) the EV is the bare ``predicted_s``, so all cold
    rows tie and a stable sort preserves queue order — bit-identical to
    the pre-EV selection."""
    if probs is None:
        return float(predicted_s)
    p = np.asarray(probs, np.float64)[cur:target]
    return float(np.prod(1.0 - p)) * float(predicted_s)


def rank_speculation(rows, positions, target: int,
                     predicted_s: float, cap: int) -> list:
    """Order speculation candidates by descending expected value and
    keep the best ``cap`` — the policy for an idle budget that covers
    only some candidates (ROADMAP item 4 follow-up (a)). ``rows`` and
    ``positions`` are parallel: each row's current decode position.
    Stable: ties (and the no-router cold path, where every EV equals
    ``predicted_s``) keep queue order, so ranking only reorders when the
    router actually distinguishes the candidates."""
    if len(rows) <= cap:
        return list(rows)
    order = sorted(
        range(len(rows)),
        key=lambda i: -speculation_ev(rows[i].probs, positions[i], target,
                                      predicted_s))
    return [rows[i] for i in sorted(order[:cap])]


def may_speculate(slo: SLOConfig, wasted_s: float, elapsed: float,
                  predicted_s: float = 0.0) -> bool:
    """Idle-budget gate: may a tier issue one more speculative chunk?
    ``wasted_s`` is the stream's cancelled-speculation device-seconds so
    far; ``predicted_s`` the speculating tier's EWMA-predicted service
    time for the chunk about to be issued — counted *before* issue so
    the budget check leads the spend instead of trailing it."""
    if not slo.speculate:
        return False
    if slo.spec_idle_frac is None:
        return True
    return wasted_s + predicted_s <= slo.spec_idle_frac * max(elapsed, 1e-9)
