"""SLO-aware parallel tier scheduler: concurrent per-tier workers over
the shared cascade step.

The serial ``ContinuousBatcher`` (``repro.serving.ingress``) dispatches
one chunk at a time on one thread: while tier 0 decodes, tier 1 sits
idle even when its queue could fill a chunk. This module replaces that
dispatch loop with one **worker thread per cascade tier**, all driving
the same ``repro.core.cascade.tier_step`` — so with >= 2 tiers backed by
real models, chunks decode concurrently and the cascade's wall clock
approaches the busiest tier's, not the sum of all tiers'.

Layering (the new layer sits between ingress and the cascade executor):

    IngressQueue  ->  TierScheduler (admission + per-tier workers)
                           |  tier_step (shared compaction step)
                           v
                      per-tier wait queues, escalation j -> j+1

Scheduling policy (``sched.policy``):

  * **adaptive holdback** — a tier ships a partial chunk when the
    head-of-line request's predicted completion (now + safety x EWMA
    service time, ``sched.estimator``) would miss its deadline, when the
    head has aged past ``max_holdback_s``, or when nothing upstream can
    ever top the chunk up (drain). Full chunks ship immediately.
  * **bounded queues + backpressure** — with ``queue_cap`` set,
    escalation into a full downstream queue blocks that tier's worker
    (escalations flow strictly forward, so blocking cannot deadlock);
    the stall propagates upstream until admission applies the overload
    policy: ``reject`` sheds arrivals, ``degrade`` admits them at a
    degraded entry — the cheapest tier whose *predicted* accept
    probability clears a reduced bar when a contextual router is
    attached, tier 0 otherwise — with the answer accepted regardless
    of score (the paper's cost/accuracy dial applied to load).

With a ``ServingStrategy`` on the pipeline (``repro.serving.strategy``)
the scheduler additionally routes each admitted miss to its predicted
entry tier, reads governor-adjusted thresholds at dispatch, and feeds
every finished request's cost back to the governor; with no strategy
every decision is bit-identical to the fixed cascade.

With per-tier device placement (``repro.sharding.placement``) each
tier's model is pinned to its own ``jax.Device`` (``TierSpec.device``),
so the workers' concurrent chunks decode on disjoint devices instead of
timesharing one — tier overlap is then limited by the tiers themselves,
not by a shared device queue. The pins are recorded in
``stats()["tier_devices"]``; placement never changes results
(tests/test_placement.py), only where they are computed.

With per-tier mesh slices (``repro.sharding.tier_mesh``,
``TierSpec.mesh``) each worker dispatches its chunks to its tier's
*slice* instead: the tier backend device_puts the compacted chunk
across the slice boundary (batch split over the slice's "data" axis)
and runs it as a pjit-sharded computation — same worker model, the
per-tier device becomes a per-tier device *set*, recorded in
``stats()["tier_meshes"]``. Data-parallel slices never change results
either (the sharded legs of tests/test_placement.py).

Concurrency contract (see ``tier_step``): each tier's ``invoke`` is
only ever entered by that tier's worker, so tier backends (e.g. a
``GenerationEngine``) need no internal locking — but two ``TierSpec``
entries must not share one stateful backend object. The pipeline's
shared scorer is serialized with a lock; completion-cache lookups
(admission thread) and inserts (workers) share another.

Equivalence guarantee (tests/test_sched.py, tests/test_ingress.py): for
a fixed request set under greedy decoding — row-wise tier ``answer``/
``scorer`` callables, which all repo tiers are — the parallel scheduler
returns bit-identical answers and per-request costs to
``ServingPipeline.serve``: a request's cost is still its own row-wise
``ApiCost`` terms summed in ascending tier order on float64, regardless
of which chunks it rode or what was decoding concurrently.

**Speculative cascade execution** (``SLOConfig.speculate``): a tier
worker with an empty queue may *pre-invoke* rows still decoding on
earlier tiers, picked by the contextual router's predicted-reject
probabilities (``policy.speculation_candidate``) under an idle-device
budget (``policy.may_speculate``). The speculative result is parked in
``_spec_ready``; if the row really escalates here, ``_run_chunk`` hands
it to ``tier_step(prefilled=...)`` — the cold invoke is skipped and the
tier's wall-clock overlaps the upstream decode — and if the row is
accepted upstream instead, the entry is cancelled and its device-seconds
count as waste. Scoring, the accept rule, escalation, and cost charging
all still run through the identical ``tier_step`` path on commit, and a
speculative chunk runs on the *same* worker thread as the tier's real
chunks (the one-thread-per-backend contract holds), so speculation can
only move wall-clock: answers, charged cost, ``stopped_at`` and
``tier_counts`` are bit-identical to ``speculate=False`` (the
speculative legs of tests/test_placement.py). The known tradeoff: a
real arrival during a speculative chunk waits for it to finish —
bounded by one chunk's service time, gated by the policy dials.

**Fault tolerance** (``repro.serving.resilience``): with
``SLOConfig.retry``/``SLOConfig.breaker`` set (or fault-injected tiers
wired in), a ``TierFault`` from an invoke is a *routing signal*, not a
crash. The invoke is retried under the bounded, deadline-aware
``RetryPolicy``; the final outcome feeds the tier's circuit breaker; and
a chunk whose tier still fails escalates forward — the cascade structure
IS the failover path. Rows waiting on a tier whose breaker is open skip
it without invoking (``_skip_open_tier_locked``); a failed *last* tier
resolves each row from the best-scoring answer an earlier tier produced
(a degraded answer) or as an accounted shed, so every admitted request
always resolves. A breaker trip cancels speculation parked against the
tier (and engine-level prefill futures via ``EnginePool.cancel_all``
when the pipeline exposes a pool). With no resilience dials the
TierFault path is structurally unreachable and the scheduler is
bit-identical to the pre-resilience one (the zero-fault legs of
tests/test_placement.py).
"""
from __future__ import annotations

import asyncio
import collections
import threading
import time
from typing import Sequence

import numpy as np

from repro.core.cascade import CascadeTier, tier_step
from repro.serving.ingress import (IngressQueue, RequestState,
                                   fold_stream_result, pad_pow2_rows,
                                   stage1_lookup)
from repro.serving.resilience import (FaultyTier, TierFault, TierHealth,
                                      invoke_with_retry)
from repro.serving.sched.estimator import TierEstimator
from repro.serving.sched.policy import (ADMIT, DEGRADE, SLOConfig,
                                        admit_decision, holdback_timeout,
                                        may_speculate, rank_speculation,
                                        speculation_candidate)


class TierScheduler:
    """Parallel, SLO-aware scheduler over a ``ServingPipeline``.

    One scheduler serves one stream and is then consumed (``result()``);
    build a fresh one per trace. Drop-in for ``ContinuousBatcher``:
    ``run_trace(tokens, arrivals)`` replays a closed trace,
    ``serve_async(queue)`` drives a live (possibly still-open)
    ``IngressQueue`` with per-request futures.
    """

    #: cap on idle waits so time-based triggers (holdback expiry,
    #: deadline pressure, late arrivals) are never missed for long
    IDLE_POLL = 0.02

    def __init__(self, pipeline, max_chunk: int | None = None,
                 slo: SLOConfig | None = None):
        self.pipeline = pipeline
        self.slo = slo or SLOConfig()
        self.max_chunk = int(pipeline.batch_size if max_chunk is None
                             else max_chunk)
        if self.max_chunk < 1:
            raise ValueError("max_chunk must be >= 1")
        m = len(pipeline.tiers)
        if m == 0:
            raise ValueError("pipeline has no tiers")
        self._tiers = pipeline._cascade_tiers()
        # contextual strategy (repro.serving.strategy): entry-tier
        # routing at admission, governor-adjusted thresholds at
        # dispatch, predicted-score degradation under overload; None
        # keeps every decision bit-identical to the fixed cascade
        self._strategy = pipeline.strategy
        # window-assignment routing (repro.serving.assign): admitted
        # misses are buffered into arrival windows and entry-routed by
        # the budgeted assignment solver at drain; the buffer is only
        # touched on the driver thread (admit + drain), enqueue happens
        # under the lock like every other path
        self._assign = (self._strategy is not None
                        and getattr(self._strategy, "mode", "entry")
                        == "assign")
        self._win_buf = None
        if self._assign:
            from repro.serving.assign import WindowBuffer
            self._win_buf = WindowBuffer(self._strategy.assigner.cfg)
        # accuracy guarantee (repro.serving.guarantee): finished rows
        # are shadow-sampled onto the reference (top) tier as clone
        # requests riding the normal worker machinery; None keeps the
        # request path structurally identical
        self._guarantee = (getattr(self._strategy, "guarantee", None)
                           if self._strategy is not None else None)
        self._shadow_rid = -1           # clone rids: negative, unique

        # one lock + condition guards every field below; chunk compute,
        # embedding and cache traffic all happen OUTSIDE it
        self._mu = threading.Lock()
        self._cv = threading.Condition(self._mu)
        self._scorer_mu = threading.Lock()   # shared scorer (tier_step)
        self._cache_mu = threading.Lock()    # lookup (admission) vs insert

        self._waiting: list[collections.deque] = [collections.deque()
                                                  for _ in range(m)]
        self._busy = [0] * m            # rows inside a running chunk
        self._inflight = 0              # admitted, not yet finished
        self._ingress_drained = False   # no further arrivals possible
        self._stop = False
        self._error: BaseException | None = None
        self._threads: list[threading.Thread] = []
        self._clock = None

        # telemetry (all under _mu)
        self._requests: list[RequestState] = []
        self.tier_counts = [0] * m
        self.chunks_per_tier = [0] * m
        self._fill: list[float] = []
        self.queue_peak = [0] * m
        self.estimators = [TierEstimator() for _ in range(m)]
        self.cache_hits = 0
        self.cache_misses = 0
        self.shed_count = 0
        self.degraded_count = 0
        self.deadline_hits = 0
        self.deadline_total = 0
        self.latency = {"embed": 0.0, "cache": 0.0, "cascade": 0.0,
                        "insert": 0.0}
        if self._assign:
            self.latency["assign"] = 0.0

        # speculation state (all under _mu; see module docstring).
        # _decoding[j]: rid -> request for rows inside tier j's running
        # chunk — the candidate pool downstream tiers speculate over.
        # _spec_ready[t]: rid -> (answer, cost, row_s) pre-invoked on
        # tier t, awaiting commit (row escalates to t) or cancel (row
        # accepted upstream). _spec_inflight[t]: rids being pre-invoked
        # right now. Every _spec_ready entry resolves: a row's position
        # only ever increases, so it either reaches t (consumed by
        # _take_speculation) or is accepted at some j < t with
        # t <= j + spec_depth (cancelled by _run_chunk's scan).
        self._decoding: list[dict] = [dict() for _ in range(m)]
        self._spec_ready: list[dict] = [dict() for _ in range(m)]
        self._spec_inflight: list[set] = [set() for _ in range(m)]
        self.spec_issued = 0        # rows pre-invoked
        self.spec_committed = 0     # rows whose pre-invoke was consumed
        self.spec_cancelled = 0     # rows pre-invoked in vain
        self.spec_wasted_s = 0.0    # device-seconds of cancelled rows
        self.spec_busy_s = [0.0] * m   # speculative busy time per tier
        self.spec_chunks = [0] * m

        # resilience (repro.serving.resilience): per-tier circuit
        # breakers, retry, and failover-past-failed-tier semantics.
        # _resilient is the single gate, and it is an explicit opt-in
        # (a retry or breaker dial): False keeps every code path —
        # including the TierFault catch in _run_chunk — structurally
        # identical to the pre-resilience scheduler, so disabled runs
        # stay bit-identical AND a fault-injected run without the dials
        # still crashes (the bench's no-resilience baseline).
        self._health = (TierHealth(m, self.slo.breaker)
                        if self.slo.breaker is not None else None)
        self._resilient = (self.slo.retry is not None
                           or self._health is not None)
        self._sleep = time.sleep    # no-op under an injected clock
        self.retry_count = 0        # failed attempts that were retried
        self.retry_backoff_s = 0.0  # added latency spent backing off
        self.failover_count = 0     # rows escalated past a failed tier
        self.fallback_count = 0     # last-tier failures answered from an
                                    # earlier tier's best-scoring answer
        self.res_shed = 0           # last-tier failures with no fallback
        self.spec_aborted = 0       # speculative invokes killed by faults

    # -- admission (driver thread) -----------------------------------------
    def _admit(self, reqs: Sequence[RequestState], now: float):
        """Stage-1 a burst of arrivals: embed + cache lookup (and, with
        a contextual router, entry-tier prediction) outside the lock;
        then, under it, resolve hits, apply the overload policy, and
        queue each admitted miss on its entry tier (tier 0 without a
        router — bit-identical to the fixed cascade)."""
        if not reqs:
            return
        strat = self._strategy
        routed = (strat is not None and not self._assign
                  and getattr(strat, "router", None) is not None)
        hit_mask, cached, emb, embed_s, cache_s = stage1_lookup(
            self.pipeline, reqs, cache_lock=self._cache_mu,
            need_emb=routed or self._assign)
        entries = probs = None
        if routed:
            entries, probs = strat.route(emb)
        m = len(self._tiers)
        keep_emb = self.pipeline.cache is not None
        with self._cv:
            self.latency["embed"] += embed_s
            self.latency["cache"] += cache_s
            self.cache_hits += int(hit_mask.sum())
            self.cache_misses += int((~hit_mask).sum())
            for i, r in enumerate(reqs):
                r.t_admitted = now
                r.deadline = self.slo.deadline_for(r.arrival, r.deadline)
                self._requests.append(r)
                self._inflight += 1
                if hit_mask[i]:
                    r.answer = cached[i]
                    r.stopped_at = -1
                    self._finish_locked(r, now)
                    continue
                if self._assign:
                    # buffer into the arrival window; overload policy
                    # and enqueue happen at drain, once the solver has
                    # picked the entry tier (_drain_window)
                    r.emb = emb[i]
                    self._win_buf.add(r, now, deadline=r.deadline)
                    continue
                j0 = int(entries[i]) if entries is not None else 0
                verdict = admit_decision(
                    len(self._waiting[j0]), self.slo,
                    est=self.estimators[j0], now=now, deadline=r.deadline)
                if verdict == ADMIT or verdict == DEGRADE:
                    if verdict == DEGRADE:
                        # cost-aware degradation: cheapest tier whose
                        # predicted accept clears the reduced bar
                        # (tier 0 without a router, as before). The
                        # re-target must honour the hard 2x bound on
                        # ITS queue too — degrading into a different
                        # tier must not create an unbounded queue.
                        j0 = (strat.degrade_entry(probs[i], m)
                              if probs is not None else 0)
                        cap = self.slo.queue_cap
                        if (cap is not None
                                and len(self._waiting[j0]) >= 2 * cap):
                            r.shed = True
                            r.stopped_at = -2
                            self.shed_count += 1
                            self._finish_locked(r, now)
                            continue
                        r.degraded = True
                        self.degraded_count += 1
                    r.entry = j0
                    if probs is not None:
                        r.pred_accept = float(probs[i, j0])
                        r.probs = probs[i]  # speculation candidates read
                                            # the full per-tier vector
                    if keep_emb:            # only queued misses keep the
                        r.emb = emb[i]      # embedding (insert-on-finish);
                    self._enqueue_locked(r, j0, now)
                else:                       # shed: nothing to insert, so
                    r.shed = True           # don't pin the row for the
                    r.stopped_at = -2       # scheduler's lifetime
                    self.shed_count += 1
                    self._finish_locked(r, now)
            self._cv.notify_all()

    # -- window assignment (driver thread; see repro.serving.assign) -------
    def _window_pressure(self) -> float:
        """Seconds of slack the window must leave before its earliest
        deadline: the safety-scaled predicted service of the whole
        cascade chain (conservative — a drained query may still have to
        climb every tier), so holding an arrival for its window never
        pushes it past an SLO deadline the chain could have met."""
        svc = sum(e.predicted_service() for e in self.estimators)
        return self.slo.service_safety * svc

    def _drain_window(self, now: float, force: bool = False):
        """Drain every currently-due window (a burst that outgrew one
        window drains as several). ``force`` flushes the partial
        remainder once ingress has drained — nothing will top it up."""
        buf = self._win_buf
        while buf is not None and len(buf):
            if not force and not buf.due(now, self._window_pressure()):
                return
            self._solve_window(buf.drain(buf.cfg.window_size), now)

    def _solve_window(self, items: list, now: float):
        """Score + solve ONE arrival window and enqueue the results.
        Runs on the driver thread; scoring and the solver stay outside
        the lock (like stage-1 embed/cache traffic). Shed/degrade still
        apply, per assigned tier, at enqueue time."""
        strat, asg = self._strategy, self._strategy.assigner
        emb_w = np.stack([r.emb for r in items])
        toks = np.stack([r.tokens for r in items])
        t0 = time.perf_counter()
        util = ([e.utilization(now) for e in self.estimators]
                if now > 0 else None)
        res = asg.assign(emb_w, self.pipeline._tier_prices(toks),
                         governor=strat.governor, utilization=util)
        probs = asg.meta.accept_probs(emb_w)
        solve_s = time.perf_counter() - t0
        m = len(self._tiers)
        keep_emb = self.pipeline.cache is not None
        with self._cv:
            self.latency["assign"] += solve_s
            for i, r in enumerate(items):
                if not keep_emb:
                    r.emb = None
                j0 = int(res["assignment"][i])
                verdict = admit_decision(
                    len(self._waiting[j0]), self.slo,
                    est=self.estimators[j0], now=now, deadline=r.deadline)
                if verdict == ADMIT or verdict == DEGRADE:
                    if verdict == DEGRADE:
                        # cost-aware degradation off the meta-model's
                        # accept probabilities (router-compatible)
                        j0 = strat.degrade_entry(probs[i], m)
                        cap = self.slo.queue_cap
                        if (cap is not None
                                and len(self._waiting[j0]) >= 2 * cap):
                            r.shed = True
                            r.stopped_at = -2
                            self.shed_count += 1
                            self._finish_locked(r, now)
                            continue
                        r.degraded = True
                        self.degraded_count += 1
                    r.entry = j0
                    r.pred_accept = float(probs[i, j0])
                    r.probs = probs[i]
                    self._enqueue_locked(r, j0, now)
                else:
                    r.shed = True
                    r.stopped_at = -2
                    self.shed_count += 1
                    self._finish_locked(r, now)
            self._cv.notify_all()

    def _enqueue_locked(self, r: RequestState, j: int, now: float):
        r.tier_pos = j
        r.t_enqueued = now
        if not r.shadow:        # tier_counts reflect service traffic only
            self.tier_counts[j] += 1
        q = self._waiting[j]
        q.append(r)
        if len(q) > self.queue_peak[j]:
            self.queue_peak[j] = len(q)

    def _finish_shadow_locked(self, r: RequestState, now: float):
        """A shadow clone came back from the reference tier: fold the
        comparison into the guarantee controller (cost on the shadow
        meter) and feed the online router retrainer's shadow label at
        the audited stopping position. Clones lost to faults/overload
        abort cleanly — no observation, no telemetry pollution."""
        r.t_done = now
        self._inflight -= 1
        guar = self._guarantee
        if guar is None:
            return
        if r.shed or r.answer is None:
            r.emb = None
            guar.abort()
            return
        agree = bool(np.all(np.asarray(r.answer == r.orig_answer)))
        guar.observe(0.0 if agree else 1.0, r.cost, invoked=True)
        rt = getattr(guar, "retrainer", None)
        if rt is not None and r.emb is not None:
            rt.observe(r.emb, int(r.orig_stop), agree)
            rt.maybe_step()
        r.emb = None

    def _finish_locked(self, r: RequestState, now: float):
        if r.shadow:
            self._finish_shadow_locked(r, now)
            return
        r.t_done = now
        self._inflight -= 1
        if r.deadline is not None and not r.shed:
            self.deadline_total += 1
            if now <= r.deadline:
                self.deadline_hits += 1
        if self._strategy is not None and not r.shed:
            if r.stopped_at == -1:          # cache hit: zero-cost serve
                self._strategy.observe_request(r.cost)
            elif r.degraded:                # forced accept: no signal for
                self._strategy.observe_request(r.cost, entry=r.entry)
            else:                           # the accept-rate telemetry
                self._strategy.observe_request(
                    r.cost, entry=r.entry, pred=r.pred_accept,
                    accepted=(r.stopped_at == r.entry))
            if self._assign and r.stopped_at >= 0:
                # realized counterpart of the window solver's prediction
                self._strategy.assigner.observe(
                    [r.cost], [r.stopped_at == r.entry])
        guar = self._guarantee
        if guar is not None and not r.shed and r.stopped_at >= 0:
            top = len(self._tiers) - 1
            rt = getattr(guar, "retrainer", None)
            if (rt is not None and r.emb is not None
                    and not r.degraded and r.pred_accept is not None
                    and r.entry != top):
                # realized accept at the routed entry as an online label
                # (final position is supervised by shadow agreement
                # only — entering there accepts unconditionally)
                rt.observe(r.emb, int(r.entry), r.stopped_at == r.entry)
                rt.maybe_step()
            if guar.should_sample():
                if r.stopped_at == top:
                    # the served answer IS the reference answer: a free
                    # zero-gap observation, no invoke
                    guar.observe(0.0, 0.0, invoked=False)
                else:
                    cap = self.slo.queue_cap
                    if (cap is not None
                            and len(self._waiting[top]) >= cap):
                        guar.abort()    # overload sheds the audit, never
                    else:               # the service traffic
                        sh = RequestState(
                            rid=self._shadow_rid, tokens=r.tokens,
                            arrival=r.arrival, shadow=True,
                            orig_answer=r.answer,
                            orig_stop=r.stopped_at, emb=r.emb)
                        self._shadow_rid -= 1
                        self._inflight += 1
                        self._enqueue_locked(sh, top, now)
            r.emb = None
        if r.future is not None:
            # workers are plain threads: hand resolution to the loop
            r.future.get_loop().call_soon_threadsafe(
                lambda f=r.future, rr=r: f.done() or f.set_result(rr))

    # -- governor dials ----------------------------------------------------
    def _governor(self):
        strat = self._strategy
        return getattr(strat, "governor", None) if strat is not None else None

    def _effective_chunk(self) -> int:
        """Chunk-size cap with the budget governor's dial applied:
        overspend grows chunks (fuller buckets, better amortization),
        spare budget shrinks them (lower holdback latency). Read at
        each dispatch decision — racing a governor window update just
        means this decision uses the previous window's dial."""
        gov = self._governor()
        return self.max_chunk if gov is None else gov.max_chunk(
            self.max_chunk)

    def _effective_holdback(self) -> float | None:
        """Holdback-window override from the governor's dial (None =
        use the SLOConfig window unchanged)."""
        gov = self._governor()
        return None if gov is None else gov.holdback_s(
            self.slo.max_holdback_s)

    # -- dispatch decision (under _mu) -------------------------------------
    def _upstream_quiet(self, j: int) -> bool:
        """Nothing can ever flow into tier j again: ingress is drained
        and every earlier tier is empty and idle."""
        if not self._ingress_drained:
            return False
        return all(not self._waiting[i] and self._busy[i] == 0
                   for i in range(j))

    def _next_chunk_locked(self, j: int, now: float):
        """(batch, wait_s): the chunk tier j should run now, or the
        seconds to wait before re-deciding (None = nothing queued)."""
        q = self._waiting[j]
        if not q:
            return None, None
        if len(q) >= self._effective_chunk():
            return self._pop_locked(j, now), 0.0
        wait = holdback_timeout(q[0], self.estimators[j], now, self.slo,
                                max_holdback_s=self._effective_holdback())
        if wait <= 0.0 or self._upstream_quiet(j):
            return self._pop_locked(j, now), 0.0
        return None, wait

    def _pop_locked(self, j: int, now: float) -> list[RequestState]:
        q = self._waiting[j]
        batch = [q.popleft()
                 for _ in range(min(self._effective_chunk(), len(q)))]
        for r in batch:
            self.estimators[j].observe_wait(now - r.t_enqueued)
        self._busy[j] += len(batch)
        if self.slo.speculate:
            # expose the chunk as downstream speculation candidates for
            # the duration of the decode (cleared in _run_chunk)
            self._decoding[j] = {r.rid: r for r in batch}
        self._cv.notify_all()       # wake workers blocked on a full queue
        return batch

    # -- speculation (see module docstring) --------------------------------
    def _next_speculation_locked(self, t: int, now: float):
        """Rows tier ``t``'s idle worker should pre-invoke now, or None.
        Only consulted when tier t has no real chunk to run; real work
        always wins. Candidates are rows decoding at positions within
        ``spec_depth`` upstream whose router probabilities predict
        rejection all the way here (cold router: every row qualifies),
        excluding rows already speculated on and degraded rows (their
        forced accept upstream makes the pre-invoke guaranteed waste),
        gated by the idle budget with the tier's EWMA-predicted chunk
        time counted up front."""
        if t == 0 or not self.slo.speculate or self._waiting[t]:
            return None
        if self._health is not None and not self._health.available(t, now):
            return None         # never speculate against a tripped tier
        predicted = self.estimators[t].predicted_service(
            self.slo.init_service_s)
        if not may_speculate(self.slo, self.spec_wasted_s, now,
                             predicted_s=predicted):
            return None
        cap = self._effective_chunk()
        rows, pos = [], []
        for i in range(max(0, t - self.slo.spec_depth), t):
            for r in self._decoding[i].values():
                if (r.rid in self._spec_ready[t]
                        or r.rid in self._spec_inflight[t]
                        or r.degraded):
                    continue
                if not speculation_candidate(r.probs, i, t,
                                             self.slo.spec_bar):
                    continue
                rows.append(r)
                pos.append(i)
        if not rows:
            return None
        # idle budget covers one chunk: when more rows qualify, keep
        # the best by expected value (router reject-probability product
        # x predicted service) — queue order only breaks EV ties, so the
        # cold-router path selects exactly what it did before ranking
        rows = rank_speculation(rows, pos, t, predicted, cap)
        for r in rows:
            self._spec_inflight[t].add(r.rid)
        self.spec_issued += len(rows)
        return rows

    def _run_speculation(self, t: int, rows: list[RequestState]):
        """Pre-invoke tier t on ``rows`` (no scheduler lock held) and
        park the per-row (answer, cost) for commit. Runs on tier t's own
        worker thread — the same thread that runs its real chunks — so
        the one-invoke-at-a-time backend contract holds. Rows that were
        accepted upstream while we were invoking are cancelled here."""
        toks, b = pad_pow2_rows(np.stack([r.tokens for r in rows]))
        t0 = time.perf_counter()
        try:
            a, c = self._tiers[t].invoke(toks)
        except TierFault:
            # speculation is opportunistic — no retries, just release
            # the rows (they stay eligible for the real escalation
            # path) and feed the breaker its free failure signal
            with self._cv:
                self.spec_aborted += len(rows)
                self.spec_issued -= len(rows)
                for r in rows:
                    self._spec_inflight[t].discard(r.rid)
                self._cv.notify_all()
            if (self._health is not None
                    and self._health.record(t, False, self._clock())):
                self._on_trip(t)
            return
        spent = time.perf_counter() - t0
        if self._health is not None:
            self._health.record(t, True, self._clock())
        a = np.asarray(a)[:b]
        c = np.asarray(c, np.float64)[:b]
        row_s = spent / len(rows)
        with self._cv:
            self.spec_busy_s[t] += spent
            self.spec_chunks[t] += 1
            for i, r in enumerate(rows):
                self._spec_inflight[t].discard(r.rid)
                if r.done:          # accepted upstream mid-invoke
                    self.spec_cancelled += 1
                    self.spec_wasted_s += row_s
                else:
                    self._spec_ready[t][r.rid] = (a[i], float(c[i]), row_s)
            self._cv.notify_all()

    def _take_speculation(self, j: int, batch: list[RequestState],
                          padded: int, b: int):
        """Collect parked speculative results for this real chunk as the
        ``tier_step(prefilled=...)`` triple, or None when no row of the
        chunk was speculated on. The pow2 filler rows replicate the last
        true row (``pad_pow2_rows``), so its prefilled answer/cost are
        replicated onto them too — keeping the padded invoke exact."""
        with self._mu:
            ready = self._spec_ready[j]
            hits = [(i, ready.pop(r.rid)) for i, r in enumerate(batch)
                    if r.rid in ready]
            if not hits:
                return None
            self.spec_committed += len(hits)
        mask = np.zeros(padded, bool)
        pa = np.empty(padded, object)
        pc = np.zeros(padded, np.float64)
        for i, (ans, cost, _row_s) in hits:
            mask[i] = True
            pa[i] = ans
            pc[i] = cost
        if mask[b - 1]:
            mask[b:] = True
            for k in range(b, padded):
                pa[k] = pa[b - 1]
            pc[b:] = pc[b - 1]
        return mask, pa, pc

    # -- resilience: retry, breaker feed, failover -------------------------
    def _resilient_tier(self, j: int, deadline: float | None,
                        meta: dict) -> CascadeTier:
        """Tier j's invoke wrapped with the retry policy (bounded,
        deadline-aware, deterministic backoff jitter) and breaker
        outcome recording. ``meta`` accumulates the chunk's retry count
        and backoff seconds for telemetry; the breaker sees the *final*
        outcome of each invoke (an invoke that succeeds on retry is a
        success — the window measures availability, not flakiness)."""
        inner = self._tiers[j]
        pol = self.slo.retry

        def call(chunk):
            fails = [0]

            def _fail(_attempt, _exc):
                fails[0] += 1

            try:
                if pol is None:
                    try:
                        a, c = inner.invoke(chunk)
                    except TierFault as e:
                        _fail(0, e)
                        raise
                    attempts = 1
                else:
                    predicted = self.estimators[j].predicted_service(
                        self.slo.init_service_s)

                    def _waited(w):
                        # per-backoff credit: terminally-failed chunks
                        # keep their wasted backoff seconds too
                        meta["backoff"] += w

                    a, c, attempts, _ = invoke_with_retry(
                        inner, chunk, pol, clock=self._clock,
                        sleep=self._sleep, deadline=deadline,
                        predicted_s=predicted, token=j,
                        on_attempt_fail=_fail, on_backoff=_waited)
            except TierFault:
                meta["retries"] += max(0, fails[0] - 1)
                if (self._health is not None
                        and self._health.record(j, False, self._clock())):
                    self._on_trip(j)
                raise
            meta["retries"] += attempts - 1
            if self._health is not None:
                self._health.record(j, True, self._clock())
            return a, c

        return CascadeTier(inner.name, call)

    def _on_trip(self, t: int):
        """Tier t's breaker tripped: in-flight speculation against it is
        dead weight. Drop its parked speculative results (counted as
        cancelled waste) and cancel engine-level prefill futures through
        the pool's existing ``cancel_all`` when the pipeline exposes
        one."""
        with self._cv:
            for _a, _c, row_s in self._spec_ready[t].values():
                self.spec_cancelled += 1
                self.spec_wasted_s += row_s
            self._spec_ready[t].clear()
            self._cv.notify_all()
        pool = getattr(self.pipeline, "engine_pool", None)
        if pool is not None:
            pool.cancel_all()

    def _resolve_failed_locked(self, r: RequestState, now: float):
        """The last reachable tier failed for this row: serve the
        best-scoring answer an earlier tier produced (a degraded answer
        — availability over accuracy), or account the row as shed when
        no tier ever answered it."""
        if r.shadow:
            # a failed audit clone is silently aborted: no fallback, no
            # shed/degraded accounting — shadow traffic is measurement
            r.shed = True
            self._finish_locked(r, now)
            return
        if r.fb_tier >= 0:
            r.answer = r.fb_answer
            r.score = r.fb_score
            r.stopped_at = r.fb_tier
            r.degraded = True
            self.fallback_count += 1
            self.degraded_count += 1
        else:
            r.shed = True
            r.stopped_at = -2
            self.res_shed += 1
            self.shed_count += 1
        self._finish_locked(r, now)

    def _failover_chunk(self, j: int, batch: list[RequestState],
                        prefilled, meta: dict):
        """Tier j failed this chunk even after retries: escalate the
        rows forward — the cascade structure IS the failover path — or,
        at the last tier, resolve each row from its recorded fallback
        (or as an accounted shed). The failed invoke returned no
        answers, so nothing is charged for tier j itself."""
        clock = self._clock
        last = j == len(self._tiers) - 1
        now = clock()
        with self._cv:
            self.retry_count += meta["retries"]
            self.retry_backoff_s += meta["backoff"]
            self.failover_count += len(batch)
            if self.slo.speculate:
                self._decoding[j] = {}
                if prefilled is not None:
                    # pre-invokes consumed by this chunk died with it:
                    # they were counted committed in _take_speculation
                    n_hit = int(np.asarray(
                        prefilled[0], bool)[:len(batch)].sum())
                    self.spec_committed -= n_hit
                    self.spec_cancelled += n_hit
            if last:
                for r in batch:
                    self._resolve_failed_locked(r, now)
            else:
                cap = self.slo.queue_cap
                for r in batch:
                    while (cap is not None
                           and len(self._waiting[j + 1]) >= cap
                           and not self._stop):
                        self._cv.notify_all()
                        self._cv.wait(self.IDLE_POLL)
                    self._enqueue_locked(r, j + 1, clock())
            self._busy[j] -= len(batch)
            self._cv.notify_all()

    def _skip_open_tier_locked(self, j: int, now: float):
        """Tier j's breaker is open: rows waiting on it skip the tier
        and escalate to j+1 (forward-only, no invoke, nothing charged).
        Called with the scheduler lock held, from tier j's own worker.
        The last tier never skips — its worker instead waits out the
        cooldown and lets the half-open probe chunk through (a failed
        probe resolves via the failover path), so a recovering top tier
        starts answering again without a full outage window of sheds."""
        rows = list(self._waiting[j])
        self._waiting[j].clear()
        self._busy[j] += len(rows)      # drain detection holds off
        self.failover_count += len(rows)
        cap = self.slo.queue_cap
        for r in rows:
            while (cap is not None and len(self._waiting[j + 1]) >= cap
                   and not self._stop):
                self._cv.notify_all()
                self._cv.wait(self.IDLE_POLL)
            self._enqueue_locked(r, j + 1, self._clock())
        self._busy[j] -= len(rows)
        self._cv.notify_all()

    @staticmethod
    def _batch_deadline(batch: list[RequestState]) -> float | None:
        """The chunk's binding SLO deadline: the earliest row deadline —
        a retry that would push past it serves nobody in the chunk on
        time."""
        return min((r.deadline for r in batch if r.deadline is not None),
                   default=None)

    # -- the per-tier worker ----------------------------------------------
    def _run_chunk(self, j: int, batch: list[RequestState]):
        """Execute one chunk on tier j (no scheduler lock held)."""
        pipe = self.pipeline
        clock = self._clock
        last = j == len(self._tiers) - 1
        # the governor retunes thresholds between windows: read the
        # current set at dispatch (a plain tuple swap — racing an update
        # just means this chunk uses the previous window's thresholds)
        thresholds = (self._strategy.thresholds(pipe.thresholds)
                      if self._strategy is not None else pipe.thresholds)
        toks, b = pad_pow2_rows(np.stack([r.tokens for r in batch]))
        prefilled = (self._take_speculation(j, batch, len(toks), b)
                     if self.slo.speculate else None)
        meta = {"retries": 0, "backoff": 0.0}
        tier = (self._resilient_tier(j, self._batch_deadline(batch), meta)
                if self._resilient else self._tiers[j])
        t0 = time.perf_counter()
        try:
            ans, cost, scores, accept = tier_step(
                tier, toks, j, scorer=pipe._pos_scorer,
                threshold=None if last else thresholds[j], last=last,
                scorer_lock=self._scorer_mu, prefilled=prefilled)
        except TierFault:
            if not self._resilient:     # no resilience layer: fatal, as
                raise                   # any tier exception always was
            self._failover_chunk(j, batch, prefilled, meta)
            return
        ans, cost, scores, accept = (ans[:b], cost[:b], scores[:b],
                                     accept[:b])
        chunk_s = time.perf_counter() - t0
        now = clock()
        finished, escalate, cacheable = [], [], []
        for i, r in enumerate(batch):
            r.n_chunks += 1
            r.cost += float(cost[i])
            # a degraded request takes the cheapest tier's answer even
            # when the scorer would escalate it (overload trades
            # accuracy, not availability)
            if accept[i] or r.degraded:
                r.answer = ans[i]
                r.score = float(scores[i])
                r.stopped_at = j
                finished.append(r)
                # never cache an answer the scorer rejected: a forced
                # degraded answer would otherwise be served to future
                # near-duplicates long after the overload has passed
                # (nor a shadow clone — its answer audits, not serves)
                if accept[i] and not r.shadow:
                    cacheable.append(r)
            else:
                if self._resilient:
                    # remember the best-scoring rejected answer: the
                    # failover fallback if every remaining tier is down
                    s_i = float(scores[i])
                    if s_i > r.fb_score:
                        r.fb_answer, r.fb_score, r.fb_tier = ans[i], s_i, j
                escalate.append(r)
        insert_s = 0.0
        if pipe.cache is not None and cacheable:
            t0 = time.perf_counter()
            with self._cache_mu:
                pipe._cache_insert(
                    np.stack([r.emb for r in cacheable]),
                    np.asarray([r.answer for r in cacheable]),
                    np.asarray([r.score for r in cacheable]))
            insert_s = time.perf_counter() - t0
        # the embedding served its cache purpose — but the guarantee's
        # online retrainer still consumes it as a label feature in
        # _finish_locked, which clears it after use
        if (self._guarantee is None
                or getattr(self._guarantee, "retrainer", None) is None):
            for r in finished:
                r.emb = None
        m = len(self._tiers)
        with self._cv:
            self.retry_count += meta["retries"]
            self.retry_backoff_s += meta["backoff"]
            self.estimators[j].observe_chunk(chunk_s, len(batch))
            self.chunks_per_tier[j] += 1
            self._fill.append(len(batch) / self.max_chunk)
            self.latency["cascade"] += chunk_s   # summed busy time: with
            self.latency["insert"] += insert_s   # parallel tiers this can
            if self.slo.speculate:               # exceed wall clock
                self._decoding[j] = {}
            for r in finished:
                self._finish_locked(r, now)
                if self.slo.speculate:
                    # the row stops here: cancel any speculation parked
                    # for it downstream (targets can only be within
                    # spec_depth of some earlier position <= j)
                    hi = min(j + self.slo.spec_depth, m - 1)
                    for t2 in range(j + 1, hi + 1):
                        hit = self._spec_ready[t2].pop(r.rid, None)
                        if hit is not None:
                            self.spec_cancelled += 1
                            self.spec_wasted_s += hit[2]
            # bounded escalation: block (releasing the lock) while the
            # downstream queue is full — strictly forward flow, so this
            # backpressure cannot deadlock; _busy[j] stays raised until
            # the handoff completes so drain detection holds off
            cap = self.slo.queue_cap
            for r in escalate:
                while (cap is not None
                       and len(self._waiting[j + 1]) >= cap
                       and not self._stop):
                    self._cv.notify_all()
                    self._cv.wait(self.IDLE_POLL)
                self._enqueue_locked(r, j + 1, clock())
            self._busy[j] -= len(batch)
            self._cv.notify_all()

    def _worker(self, j: int):
        clock = self._clock
        last = j == len(self._tiers) - 1
        try:
            while True:
                spec = None
                with self._cv:
                    batch = None
                    while batch is None:
                        if self._stop:
                            return
                        now = clock()
                        if (self._health is not None and self._waiting[j]
                                and not self._health.available(j, now)):
                            if not last:    # open breaker: route past it
                                self._skip_open_tier_locked(j, now)
                                continue
                            # last tier: wait out the cooldown — the
                            # half-open probe (or its failover) resolves
                            self._cv.wait(self.IDLE_POLL)
                            continue
                        batch, wait = self._next_chunk_locked(j, now)
                        if batch is not None:
                            break
                        # idle: maybe burn the wait on speculation —
                        # real work always wins the next loop iteration
                        spec = self._next_speculation_locked(j, now)
                        if spec is not None:
                            break
                        timeout = (self.IDLE_POLL if wait is None else
                                   min(max(wait, 1e-4), self.IDLE_POLL))
                        self._cv.wait(timeout)
                if batch is not None:
                    self._run_chunk(j, batch)
                elif spec is not None:
                    self._run_speculation(j, spec)
        except BaseException as e:         # surface worker crashes to the
            with self._cv:                 # driver instead of hanging it
                self._error = e
                self._stop = True
                self._fail_pending_locked(e)
                self._cv.notify_all()

    def _fail_pending_locked(self, exc: BaseException):
        """A worker died: no chunk will ever finish the admitted
        requests still in flight, so fail their futures NOW — a caller
        awaiting one would otherwise hang past the driver's next poll
        (and forever, once the driver re-raised and stopped polling)."""
        for r in self._requests:
            if not r.done and r.future is not None and not r.future.done():
                try:
                    r.future.get_loop().call_soon_threadsafe(
                        lambda f=r.future, e=exc: f.done()
                        or f.set_exception(e))
                except RuntimeError:        # event loop already closed
                    pass

    # -- drivers -----------------------------------------------------------
    def _start(self, clock):
        if self._threads:
            raise RuntimeError("scheduler already started; build a fresh "
                               "TierScheduler per stream")
        self._clock = clock
        for t in self._tiers:               # wire the stream clock into
            if isinstance(t, FaultyTier):   # fault windows and spikes
                t.clock = clock
                t.sleep = self._sleep
        for j in range(len(self._tiers)):
            t = threading.Thread(target=self._worker, args=(j,),
                                 name=f"tier-worker-{j}", daemon=True)
            t.start()
            self._threads.append(t)

    def _shutdown(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=10.0)

    async def serve_async(self, queue: IngressQueue, clock=None):
        """Asyncio driver over an (optionally still-open) queue:
        producers may keep submitting — with ``with_future=True`` each
        request's future resolves the moment it finishes — until
        ``queue.close()`` lets the stream drain. Returns the folded
        ``ServeResult``."""
        t_start = time.perf_counter()
        if clock is None:
            def clock() -> float:
                return time.perf_counter() - t_start
        else:
            # an injected clock owns time: backoff and latency-spike
            # waits are recorded in the telemetry, not slept — the test
            # (or its fake clock) advances time itself
            self._sleep = lambda _s: None
        self._start(clock)
        try:
            while True:
                now = clock()
                self._admit(queue.due(now), now)
                drained = queue.closed and len(queue) == 0
                if self._win_buf is not None:
                    # window formation: drain on fill/age/deadline
                    # pressure — or force-flush a partial window once
                    # no further arrival can ever top it up
                    self._drain_window(now, force=drained)
                with self._cv:
                    self._ingress_drained = drained
                    if self._error is not None:
                        break
                    if drained and self._inflight == 0:
                        break
                    self._cv.notify_all()
                nxt = queue.next_arrival()
                pause = (self.IDLE_POLL if nxt is None else
                         min(max(nxt - clock(), 0.0), self.IDLE_POLL))
                # always yield so producers run, even at pause=0
                await asyncio.sleep(pause)
        finally:
            self._shutdown()
        if self._error is not None:
            raise self._error
        return self.result(clock())

    def run_trace(self, tokens: np.ndarray,
                  arrivals: Sequence[float] | None = None, *,
                  clock=None):
        """Synchronous trace replay: requests (rows of ``tokens``)
        become visible at their ``arrivals`` offsets on a wall clock —
        or on an injected monotonic ``clock`` (deadline/holdback tests
        use a fake clock so they can't flake on loaded CI; an injected
        clock must eventually pass every arrival offset or the trace
        never drains). Returns the folded ``ServeResult``."""
        queue = IngressQueue()
        queue.submit_burst(tokens, arrivals)
        queue.close()
        return asyncio.run(self.serve_async(queue, clock=clock))

    # -- folding into ServeResult ------------------------------------------
    def stats(self, total_s: float) -> dict:
        """Ingress + scheduler telemetry (superset of the serial
        batcher's ``stats``): per-tier utilization and EWMA estimates,
        deadline-hit rate, shed/degraded counts, queue peaks."""
        from repro.sharding.tier_mesh import mesh_desc as _mesh_desc
        served = [r for r in self._requests if r.done and not r.shed]
        lat = np.asarray([r.latency for r in served], np.float64)
        wait = np.asarray([r.queue_wait for r in served], np.float64)
        return {
            "request_latency": lat,
            "queue_wait": wait,
            "chunks_per_tier": list(self.chunks_per_tier),
            "chunk_occupancy": float(np.mean(self._fill)) if self._fill
            else 0.0,
            "n_chunks": int(sum(self.chunks_per_tier)),
            # scheduler extensions
            "tier_utilization": [e.utilization(total_s)
                                 for e in self.estimators],
            "service_ewma_s": [e.service.value for e in self.estimators],
            "queue_delay_ewma_s": [e.queue_delay.value
                                   for e in self.estimators],
            "deadline_hit_rate": (self.deadline_hits / self.deadline_total
                                  if self.deadline_total else None),
            "deadline_total": self.deadline_total,
            "shed": self.shed_count,
            "degraded": self.degraded_count,
            "queue_peak": list(self.queue_peak),
            # per-tier device pins (sharding.placement) — None entries
            # mean the tier shares the default device; with every tier
            # pinned to its own device the workers' chunk overlap is no
            # longer serialized on one device's queue
            "tier_devices": [None if s.device is None else
                             f"{s.device.platform}:{s.device.id}"
                             for s in self.pipeline.tiers],
            # per-tier mesh slices (sharding.tier_mesh) — the sharded
            # analogue of tier_devices: each worker dispatches to its
            # tier's device *set*
            "tier_meshes": [None if getattr(s, "mesh", None) is None
                            else _mesh_desc(s.mesh)
                            for s in self.pipeline.tiers],
            # speculative execution (None when the dial is off):
            # committed/cancelled row counts, the device-seconds burnt on
            # cancelled rows, and per-tier speculative busy time — the
            # overlap the cascade's wall clock gained
            "speculation": None if not self.slo.speculate else {
                "issued": self.spec_issued,
                "committed": self.spec_committed,
                "cancelled": self.spec_cancelled,
                "wasted_s": self.spec_wasted_s,
                "spec_busy_s": list(self.spec_busy_s),
                "spec_chunks": list(self.spec_chunks),
                "overlap_frac": [sb / total_s if total_s > 0 else 0.0
                                 for sb in self.spec_busy_s],
            },
            # resilience (None when no retry/breaker/faults are wired):
            # retry volume and its added latency, failover escalations,
            # degraded fallback answers, accounted sheds, and breaker
            # trip/recovery state per tier
            "resilience": None if not self._resilient else {
                "retries": self.retry_count,
                "backoff_s": self.retry_backoff_s,
                "failovers": self.failover_count,
                "fallback_answers": self.fallback_count,
                "shed": self.res_shed,
                "spec_aborted": self.spec_aborted,
                "trips": self._health.trips if self._health else 0,
                "recoveries": (self._health.recoveries
                               if self._health else 0),
                "breakers": (self._health.snapshot(total_s)
                             if self._health else None),
                "faults_injected": {
                    t.name: dict(t.injected) for t in self._tiers
                    if isinstance(t, FaultyTier)} or None,
            },
        }

    def result(self, total_s: float):
        """Fold the finished stream into a ``ServeResult`` bit-compatible
        with ``ServingPipeline.serve`` (see the equivalence guarantee in
        the module docstring); shed requests carry answer ``None``,
        ``stopped_at -2`` and zero cost."""
        return fold_stream_result(
            self.pipeline, self._requests, tier_counts=self.tier_counts,
            cache_hits=self.cache_hits, cache_misses=self.cache_misses,
            latency=self.latency, total_s=total_s,
            ingress=self.stats(total_s),
            strategy=(self._strategy.snapshot(len(self._tiers))
                      if self._strategy is not None else None))
