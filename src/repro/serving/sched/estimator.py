"""Per-tier load estimation for the SLO-aware scheduler.

The scheduler's admission and holdback decisions (``sched.policy``) need
two online estimates per tier:

  * **service time** — seconds one chunk takes on this tier, an EWMA
    over observed chunk wall times. Chunks reuse the bucketed
    ``GenerationEngine`` shapes, so chunk service time is close to flat
    in occupancy and a scalar EWMA tracks it well.
  * **queue delay** — seconds a request waits in this tier's queue
    before riding a chunk, an EWMA over observed waits.

Both are EWMAs rather than windowed means: service time drifts (jit
warmup, host load, tier models swapped under the pipeline) and the
holdback decision must follow the drift within a few chunks.
"""
from __future__ import annotations


class Ewma:
    """Exponentially-weighted moving average; the first sample seeds it."""

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._v = 0.0
        self.n = 0

    def update(self, x: float) -> float:
        x = float(x)
        self._v = x if self.n == 0 else \
            self.alpha * x + (1.0 - self.alpha) * self._v
        self.n += 1
        return self._v

    @property
    def value(self) -> float:
        return self._v


class TierEstimator:
    """Service-time / queue-delay estimators plus utilization counters
    for ONE tier. Mutated only under the scheduler's lock."""

    def __init__(self, alpha: float = 0.25):
        self.service = Ewma(alpha)        # seconds per chunk
        self.queue_delay = Ewma(alpha)    # seconds waiting in the queue
        self.busy_s = 0.0                 # total seconds inside chunks
        self.chunks = 0
        self.rows = 0                     # requests served over all chunks

    def observe_chunk(self, seconds: float, rows: int):
        self.service.update(seconds)
        self.busy_s += float(seconds)
        self.chunks += 1
        self.rows += int(rows)

    def observe_wait(self, seconds: float):
        self.queue_delay.update(seconds)

    def predicted_service(self, default: float = 0.0) -> float:
        """Expected seconds for the next chunk — ``default`` before any
        chunk has been observed (a cold tier predicts optimistically, so
        the first dispatch is driven by the holdback cap instead)."""
        return self.service.value if self.service.n else float(default)

    def utilization(self, total_s: float) -> float:
        """Fraction of the stream's wall clock this tier spent decoding."""
        return self.busy_s / total_s if total_s > 0 else 0.0

    def snapshot(self) -> dict:
        return {
            "service_ewma_s": self.service.value,
            "queue_delay_ewma_s": self.queue_delay.value,
            "busy_s": self.busy_s,
            "chunks": self.chunks,
            "rows": self.rows,
        }
