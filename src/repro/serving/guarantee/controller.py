"""Online SMART calibration: shadow sampling + sequential guarantee.

The offline grid search freezes cascade thresholds on a build-time
split; under drift the frozen grid can silently trade accuracy for
cost.  This module inverts the contract (SMART, arXiv 2403.13835): the
user states a tolerable accuracy gap ``delta`` vs. the *reference*
model — the cascade's top tier — and a failure level ``alpha``, and the
controller enforces ``P(gap > delta) <= alpha`` online:

1. **Shadow sampling.**  A seeded, deterministic fraction
   ``sample_frac`` of served queries is also routed to the reference
   tier in shadow.  The comparison yields a gap observation in
   ``[0, 1]`` (answer disagreement upper-bounds the accuracy gap).
   Shadow invocations are charged to a separate meter — they never
   touch per-request cost or the governor's spend rate.
2. **Per-configuration sequential intervals.**  Control authority is a
   ladder of ``levels`` tighten settings, each mapping to a cap on the
   governor's threshold shift (level 0 = no veto, top level = force
   full tightening).  Each level keeps its own anytime-valid
   confidence sequence (``bounds.GapStat``), so evidence gathered
   under one threshold configuration is never silently attributed to
   another.
3. **Sequential-test triad.**  Every ``window`` observations the
   controller reads the *current* level's interval and acts only on
   certified evidence: LCB above ``delta`` → the gap provably exceeds
   the contract, climb the ladder (two levels when the violation is
   gross); UCB at or below ``delta`` → the configuration is certified
   safe, relax one level toward 0; anything in between → hold.  Under
   H0 (true gap ``<= delta``) a spurious tighten therefore has
   probability ``<= alpha`` per evidence segment — the anytime-valid
   guarantee.  Two hygiene rules keep the evidence honest under drift:
   a level revisited after ``stale_after`` observations of absence is
   reset before being trusted, and any level's stream restarts after
   ``stat_cap`` observations (rolling segments — a long-gone regime
   cannot pin the test forever; each segment is its own anytime-valid
   test, so ``alpha`` is spent per segment, not per lifetime).

The ladder position is exposed to :class:`~repro.serving.strategy.
governor.BudgetGovernor` as :meth:`shift_cap` — the guarantee-side
multiplier of the governor's dual: the cost side may *want* to loosen
thresholds (positive shift) but the effective shift is clamped to the
cap, so the accuracy floor vetoes cost-driven loosening.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from repro.serving.guarantee.bounds import GapStat

__all__ = ["GuaranteeConfig", "GuaranteeController"]


@dataclasses.dataclass(frozen=True)
class GuaranteeConfig:
    """User-facing accuracy-guarantee contract.

    ``delta``        tolerable gap-to-reference (disagreement rate).
    ``alpha``        failure probability of the sequential guarantee.
    ``sample_frac``  fraction of served queries shadowed to the
                     reference tier (charged separately).
    ``window``       shadow observations between controller decisions.
    ``bound``        ``"bernstein"`` (variance-adaptive, default) or
                     ``"hoeffding"``.
    ``levels``       size of the tighten ladder (control resolution).
    ``min_samples``  interval is not acted on before this many
                     observations at the current level.
    ``stale_after``  per-level evidence older than this many global
                     observations is discarded on re-entry.
    ``stat_cap``     per-level evidence horizon: the level's stream
                     restarts (a fresh sequential test) after this many
                     observations, so old regimes age out.
    ``seed``         seeds the deterministic shadow sampler.
    ``retrain``      also retrain the entry router online from shadow
                     labels (needs a contextual strategy).
    """

    delta: float = 0.05
    alpha: float = 0.05
    sample_frac: float = 0.1
    window: int = 32
    bound: str = "bernstein"
    levels: int = 8
    min_samples: int = 8
    stale_after: int = 512
    stat_cap: int = 2048
    seed: int = 0
    retrain: bool = True
    trace_len: int = 256

    def __post_init__(self) -> None:
        if not (0.0 < self.delta < 1.0):
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")
        if not (0.0 < self.alpha < 1.0):
            raise ValueError(f"alpha must be in (0, 1), got {self.alpha}")
        if not (0.0 < self.sample_frac <= 1.0):
            raise ValueError(
                f"sample_frac must be in (0, 1], got {self.sample_frac}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.levels < 2:
            raise ValueError(f"levels must be >= 2, got {self.levels}")
        if self.bound not in ("bernstein", "hoeffding"):
            raise ValueError(f"unknown bound {self.bound!r}")


class GuaranteeController:
    """Sequential gap monitor + tighten-ladder controller.

    Thread-safety contract matches the rest of the strategy layer:
    callers serialize mutation (the scheduler holds its lock around
    ``observe``/``should_sample``; the batch path is single-threaded).
    """

    def __init__(self, cfg: GuaranteeConfig,
                 retrainer: Optional[Any] = None) -> None:
        self.cfg = cfg
        self.retrainer = retrainer
        k = cfg.levels
        self._stats: List[GapStat] = [GapStat() for _ in range(k)]
        self.level = 0
        self.clock = 0           # global gap-observation counter
        self._win = 0            # observations since last decision
        self._next_id = 0        # shadow-sampling draw counter
        self.n_shadow = 0        # sampled queries (incl. free top-tier)
        self.n_invoked = 0       # sampled queries that cost a reference call
        self.n_aborted = 0       # sampled queries lost to faults/overload
        self.shadow_cost = 0.0   # $ charged to the shadow meter
        self.dropped_obs = 0     # invalid observations refused
        self.trace: Deque[Dict[str, float]] = deque(maxlen=cfg.trace_len)

    # -- shadow sampling -------------------------------------------------
    def should_sample(self) -> bool:
        """Deterministic coin for the next served query.

        Draws are keyed on ``(seed, draw index)`` so a fixed seed
        reproduces the exact shadow subset regardless of wall clock or
        worker interleaving *within one serve order*.
        """
        k = self._next_id
        self._next_id += 1
        u = float(np.random.default_rng([self.cfg.seed, k]).random())
        return u < self.cfg.sample_frac

    # -- gap stream ------------------------------------------------------
    def observe(self, gap: float, cost: float = 0.0,
                invoked: bool = False) -> None:
        """Fold one shadow comparison into the current level's stream.

        ``gap`` in [0, 1] (1 = cascade disagreed with the reference),
        ``cost`` the reference-tier invocation charged to the shadow
        meter, ``invoked`` whether a real reference call was made (a
        query that already stopped at the top tier is a free zero-gap
        observation).
        """
        gap = float(gap)
        cost = float(cost)
        if not (0.0 <= gap <= 1.0) or gap != gap or not (cost >= 0.0) \
                or cost != cost or not np.isfinite(cost):
            self.dropped_obs += 1
            return
        self.clock += 1
        self._stats[self.level].add(gap, clock=self.clock)
        self.n_shadow += 1
        if invoked:
            self.n_invoked += 1
            self.shadow_cost += cost
        self._win += 1
        while self._win >= self.cfg.window:
            self._win -= self.cfg.window
            self._decide()

    def abort(self) -> None:
        """A sampled query's shadow call failed — no observation."""
        self.n_aborted += 1

    # -- ladder ----------------------------------------------------------
    def _enter(self, level: int) -> None:
        st = self._stats[level]
        if st.n and self.clock - st.last_fed > self.cfg.stale_after:
            st.reset()  # drift: evidence from a past regime is void
        self.level = level

    def _decide(self) -> None:
        cfg = self.cfg
        st = self._stats[self.level]
        if st.n >= cfg.stat_cap:
            # rolling evidence horizon: restart the level's sequential
            # test so a long-passed regime cannot pin it forever
            st.reset()
        ucb = st.ucb(cfg.alpha, cfg.bound)
        lcb = st.lcb(cfg.alpha, cfg.bound)
        if st.n >= cfg.min_samples:
            if lcb > cfg.delta:
                # certified violating: the gap provably exceeds delta
                # at this setting — tighten (harder when gross)
                step = 2 if lcb > 2.0 * cfg.delta else 1
                self._enter(min(cfg.levels - 1, self.level + step))
            elif ucb <= cfg.delta and self.level > 0:
                # certified safe: probe one level looser so the cost
                # savings are recovered once the drift passes
                self._enter(self.level - 1)
            # in between: uncertain — hold the current setting
        self.trace.append({
            "clock": self.clock,
            "level": self.level,
            "gap_hat": st.mean,
            "gap_ucb": ucb,
            "gap_lcb": lcb,
            "cap": self.shift_cap(1.0),
        })

    def shift_cap(self, max_shift: float) -> float:
        """Largest governor shift the guarantee allows, in
        ``[-max_shift, +max_shift]``.

        Level 0 returns ``+max_shift`` (no veto); the top level returns
        ``-max_shift`` (force full tightening).  The governor applies
        ``effective_shift = min(cost_shift, shift_cap)``.
        """
        frac = self.level / (self.cfg.levels - 1)
        return float(max_shift) * (1.0 - 2.0 * frac)

    # -- introspection ---------------------------------------------------
    @property
    def gap_hat(self) -> float:
        return self._stats[self.level].mean

    @property
    def gap_ucb(self) -> float:
        return self._stats[self.level].ucb(self.cfg.alpha, self.cfg.bound)

    @property
    def gap_lcb(self) -> float:
        return self._stats[self.level].lcb(self.cfg.alpha, self.cfg.bound)

    @property
    def certified(self) -> bool:
        """Current configuration's gap is certified <= delta."""
        st = self._stats[self.level]
        return st.n >= self.cfg.min_samples and self.gap_ucb <= self.cfg.delta

    def snapshot(self) -> Dict[str, Any]:
        out = {
            "delta": self.cfg.delta,
            "alpha": self.cfg.alpha,
            "sample_frac": self.cfg.sample_frac,
            "bound": self.cfg.bound,
            "level": self.level,
            "levels": self.cfg.levels,
            "n_shadow": self.n_shadow,
            "n_invoked": self.n_invoked,
            "n_aborted": self.n_aborted,
            "shadow_cost": self.shadow_cost,
            "dropped_obs": self.dropped_obs,
            "gap_hat": self.gap_hat,
            "gap_ucb": self.gap_ucb,
            "gap_lcb": self.gap_lcb,
            "certified": self.certified,
            "trace": list(self.trace),
        }
        if self.retrainer is not None:
            out["retrain"] = self.retrainer.snapshot()
        return out
