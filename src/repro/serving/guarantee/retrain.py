"""Online entry-router retraining from serving telemetry.

The contextual router ships frozen from the offline build; under drift
its accept predictions go stale, and the predicted-vs-realized accept
telemetry the strategy layer already records was collected but never
consumed.  The guarantee layer closes that loop with two label
streams, both free at serve time:

* **realized accepts** — every routed query yields ``(embedding,
  entry position, was the entry tier's answer accepted)``, exactly the
  event the router predicts at non-final positions;
* **shadow labels** — every shadow-sampled query yields ``(embedding,
  stopping position, did the answer agree with the reference tier)``,
  a correctness proxy that supervises positions (notably the final
  one, whose offline label was build-split correctness).

Observations land in a fixed-capacity ring buffer; every ``interval``
observations one masked-BCE AdamW step runs over the buffer and the
router's parameters are swapped in place.  Buffers are fixed-shape so
the jitted step compiles once per (capacity, d, m).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.strategy.router import _mlp_forward
from repro.training.optim import OptConfig, adamw_update, init_opt_state

__all__ = ["RouterRetrainer"]


class RouterRetrainer:
    """Masked-BCE online updates for a ``ContextualRouter``.

    Mutation contract matches the strategy layer: callers serialize
    ``observe``/``maybe_step`` (scheduler lock / single-threaded batch
    path).  The router's ``params`` attribute is replaced atomically
    after each step, so concurrent readers only ever see a full
    parameter set.
    """

    def __init__(self, router: Any, *, lr: float = 1e-3,
                 capacity: int = 512, interval: int = 64,
                 min_fill: int = 32) -> None:
        if capacity < 1 or interval < 1 or min_fill < 1:
            raise ValueError("capacity/interval/min_fill must be >= 1")
        self.router = router
        self.lr = lr
        self.capacity = capacity
        self.interval = interval
        self.min_fill = min(min_fill, capacity)
        self.steps = 0
        self.n_observed = 0
        self.last_loss = float("nan")
        self._since = 0
        self._fill = 0
        self._head = 0
        self._emb: Optional[np.ndarray] = None   # (capacity, d)
        self._pos: Optional[np.ndarray] = None   # (capacity,)
        self._lab: Optional[np.ndarray] = None
        self._opt = OptConfig(lr=lr, warmup=1, total_steps=100_000,
                              weight_decay=0.0)
        self._state = init_opt_state(router.params)
        self._step_fn = None

    # -- label streams ---------------------------------------------------
    def observe(self, emb, pos: int, label: float) -> None:
        """Record one (embedding, position, accept/agree label)."""
        emb = np.asarray(emb, np.float32).reshape(-1)
        if not np.all(np.isfinite(emb)):
            return
        pos = int(pos)
        if not (0 <= pos < self.router.n_tiers):
            return
        if self._emb is None:
            self._emb = np.zeros((self.capacity, emb.shape[0]), np.float32)
            self._pos = np.zeros((self.capacity,), np.int32)
            self._lab = np.zeros((self.capacity,), np.float32)
        self._emb[self._head] = emb
        self._pos[self._head] = pos
        self._lab[self._head] = float(bool(label))
        self._head = (self._head + 1) % self.capacity
        self._fill = min(self._fill + 1, self.capacity)
        self.n_observed += 1
        self._since += 1

    # -- updates ---------------------------------------------------------
    def _build_step(self):
        opt = self._opt

        def step(params, state, x, pos, y, w):
            def loss_fn(p):
                logit = _mlp_forward(p, x)
                z = logit[jnp.arange(x.shape[0]), pos]
                bce = (jnp.maximum(z, 0) - z * y
                       + jnp.log1p(jnp.exp(-jnp.abs(z))))
                return jnp.sum(w * bce) / jnp.maximum(jnp.sum(w), 1.0)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, state, _ = adamw_update(opt, params, grads, state)
            return params, state, loss

        return jax.jit(step)

    def maybe_step(self) -> bool:
        """Run one update if enough new observations accrued."""
        if (self._since < self.interval or self._fill < self.min_fill
                or self._emb is None):
            return False
        if self._step_fn is None:
            self._step_fn = self._build_step()
        w = np.zeros((self.capacity,), np.float32)
        w[: self._fill] = 1.0
        params, self._state, loss = self._step_fn(
            self.router.params, self._state,
            jnp.asarray(self._emb), jnp.asarray(self._pos),
            jnp.asarray(self._lab), jnp.asarray(w))
        self.router.params = params
        self.last_loss = float(loss)
        self.steps += 1
        self._since = 0
        return True

    def snapshot(self) -> Dict[str, Any]:
        return {
            "steps": self.steps,
            "n_observed": self.n_observed,
            "buffer_fill": self._fill,
            "last_loss": self.last_loss,
        }
