"""``repro.serving.guarantee`` — accuracy-guaranteed frugality.

Online SMART calibration (arXiv 2403.13835) for the cascade: the user
states a tolerable accuracy gap ``delta`` vs. the reference (top) tier
and a level ``alpha``; a seeded shadow sample of live traffic is also
sent to the reference tier, anytime-valid sequential confidence
intervals track each threshold configuration's gap-to-reference, and a
tighten ladder caps the budget governor's threshold shift so that
``P(gap > delta) <= alpha`` holds under drift the frozen offline grid
would violate.  Shadow labels additionally retrain the contextual
entry router online.

Modules: ``bounds`` (time-uniform Hoeffding / empirical-Bernstein
confidence sequences), ``controller`` (``GuaranteeConfig`` /
``GuaranteeController``: shadow sampler, per-level intervals, shift
cap), ``retrain`` (``RouterRetrainer``: masked-BCE online router
updates from realized accepts + shadow agreement labels).

Opt-in: with ``guarantee=None`` every serve path is bit-identical to a
strategy without the layer (proven by the equivalence-matrix legs in
``tests/test_placement.py``).
"""
from repro.serving.guarantee.bounds import (  # noqa: F401
    GapStat,
    bernstein_radius,
    hoeffding_radius,
)
from repro.serving.guarantee.controller import (  # noqa: F401
    GuaranteeConfig,
    GuaranteeController,
)
from repro.serving.guarantee.retrain import RouterRetrainer  # noqa: F401
