"""Anytime-valid sequential confidence bounds on a bounded mean.

The guarantee layer watches a stream of *gap observations* ``g_t`` in
``[0, 1]`` (1 when the cascade's answer disagrees with the reference
tier's, 0 when it agrees — the disagreement rate upper-bounds the
accuracy gap, since queries where both are right or both are wrong
cancel).  It needs a confidence interval on ``E[g]`` that is valid *at
every stopping time simultaneously*: the controller peeks after every
window and acts on what it sees, so a fixed-``n`` Hoeffding/Bernstein
interval would silently lose its coverage.

Both bounds here are time-uniform via a union over doubling epochs
(the "stitching" construction of Howard et al., 2021): the failure
budget ``alpha`` is spread over epochs ``[2^k, 2^{k+1})`` with an
``O(1/k^2)`` schedule, which costs only an ``O(log log n)`` widening
over the fixed-``n`` radius.

* :func:`hoeffding_radius` — distribution-free, scales as
  ``sqrt(log(..)/n)``.  Simple, but loose for the small disagreement
  rates the guarantee cares about.
* :func:`bernstein_radius` — empirical-Bernstein (Maurer & Pontil,
  2009, stitched): scales with the *empirical variance*, so for a
  Bernoulli(``p``) gap stream with small ``p`` the radius shrinks like
  ``sqrt(p log(..)/n)`` — the reason it is the default bound.

Coverage is exercised empirically in ``tests/test_guarantee.py``
(uniform-over-time violation rate under H0 stays below ``alpha``).
"""
from __future__ import annotations

import math

__all__ = [
    "bernstein_radius",
    "hoeffding_radius",
    "GapStat",
]


def _union_log(n: int, alpha: float) -> float:
    """Log failure-budget term, time-uniform over doubling epochs.

    ``log(1/alpha_k)`` where epoch ``k = floor(log2 n)`` receives
    ``alpha_k = alpha / (2 (k+1)^2)`` of the budget (``sum_k alpha_k
    <= alpha * pi^2/12 < alpha``).
    """
    if not (0.0 < alpha < 1.0):
        raise ValueError(f"alpha must be in (0, 1), got {alpha}")
    k = int(math.log2(n)) if n >= 1 else 0
    return math.log(2.0 * (k + 1) ** 2 / alpha)


def hoeffding_radius(n: int, alpha: float) -> float:
    """Time-uniform Hoeffding radius for a mean of ``[0, 1]`` variables.

    ``P(exists n >= 1: |mean_n - mu| > radius(n)) <= alpha``.
    """
    if n <= 0:
        return 1.0
    return min(1.0, math.sqrt(_union_log(n, alpha) / (2.0 * n)))


def bernstein_radius(n: int, var: float, alpha: float) -> float:
    """Time-uniform empirical-Bernstein radius (Maurer–Pontil form).

    ``var`` is the empirical variance of the first ``n`` observations.
    The ``sqrt(2 var L / n)`` term dominates once the stream settles;
    the ``7L/(3(n-1))`` term pays for estimating the variance.
    """
    if n <= 1:
        return 1.0
    ell = _union_log(n, alpha)
    var = max(0.0, float(var))
    return min(1.0, math.sqrt(2.0 * var * ell / n) + 7.0 * ell / (3.0 * (n - 1)))


class GapStat:
    """Running (n, mean, variance) of one configuration's gap stream,
    with anytime-valid upper/lower confidence bounds.

    Welford accumulation keeps the variance numerically stable; the
    bound family is chosen per call so the controller can expose both.
    """

    __slots__ = ("n", "_mean", "_m2", "last_fed")

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self._mean = 0.0
        self._m2 = 0.0
        self.last_fed = 0

    def add(self, gap: float, *, clock: int = 0) -> None:
        """Fold one observation ``gap`` in ``[0, 1]`` into the stream.

        ``clock`` is the controller's global observation counter, kept
        so stale configurations can be detected and re-tested after
        drift rather than trusted forever.
        """
        if not (0.0 <= gap <= 1.0) or gap != gap:
            raise ValueError(f"gap observation must be in [0, 1], got {gap}")
        self.n += 1
        d = gap - self._mean
        self._mean += d / self.n
        self._m2 += d * (gap - self._mean)
        self.last_fed = clock

    @property
    def mean(self) -> float:
        return self._mean if self.n else 0.0

    @property
    def var(self) -> float:
        return self._m2 / self.n if self.n else 0.0

    def radius(self, alpha: float, bound: str = "bernstein") -> float:
        if bound == "bernstein":
            return bernstein_radius(self.n, self.var, alpha)
        if bound == "hoeffding":
            return hoeffding_radius(self.n, alpha)
        raise ValueError(f"unknown bound {bound!r} (want bernstein|hoeffding)")

    def ucb(self, alpha: float, bound: str = "bernstein") -> float:
        """Anytime-valid upper bound on the true gap (1.0 until data)."""
        if self.n == 0:
            return 1.0
        return min(1.0, self.mean + self.radius(alpha, bound))

    def lcb(self, alpha: float, bound: str = "bernstein") -> float:
        if self.n == 0:
            return 0.0
        return max(0.0, self.mean - self.radius(alpha, bound))
