"""Fleet-scale window assignment: the third routing mode.

FrugalGPT's cascade routes every query greedily and independently. At
fleet scale the right abstraction is an *assignment problem* over an
arrival window: a meta-model scores each (query, tier) pair
(``assign.meta`` — predicted success probability and expected
downstream cost, the meta-modeling framing of Šakota et al. combined
with Zhang et al.'s budget-constrained entry rule), and a jit-compiled
on-device solver (``assign.solver`` — LP relaxation via iterative
proportional scaling + pair-move local search) picks entry tiers that
maximize expected accuracy under a global $/window budget and per-tier
capacity caps. ``assign.window`` accumulates arrivals into windows and
dispatches through the existing ``execute_cascade(entry=)`` mechanism.

Opt-in beside fixed-threshold and contextual entry routing:
``ServingStrategy(mode="assign", assigner=...)`` /
``BuildConfig(assign=AssignConfig(...))`` — off means structurally
absent from every serving path.
"""
from repro.serving.assign.meta import (WindowMeta, correctness_labels,
                                       train_window_meta)
from repro.serving.assign.solver import (SOLVER_METHODS, SolverConfig,
                                         pow2_rows, solve_assignment)
from repro.serving.assign.window import (AssignConfig, WindowAssigner,
                                         WindowBuffer)

__all__ = [
    "AssignConfig",
    "SOLVER_METHODS",
    "SolverConfig",
    "WindowAssigner",
    "WindowBuffer",
    "WindowMeta",
    "correctness_labels",
    "pow2_rows",
    "solve_assignment",
    "train_window_meta",
]
