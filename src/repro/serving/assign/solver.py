"""On-device budgeted window assignment: LP relaxation + greedy swaps.

The assignment problem one arrival window poses (ROADMAP item 2, the
meta-modeling framing of Šakota et al. combined with Zhang et al.'s
budget-constrained entry rule): given per-(query, tier) predicted
utilities ``u`` (expected answer quality entering the cascade at that
tier, ``assign.meta``) and expected downstream costs ``c``, choose one
entry tier per query

    maximize    sum_i u[i, a_i]
    subject to  sum_i c[i, a_i] <= budget          (global $/window)
                |{i : a_i = j}| <= caps[j]          (per-tier capacity)

Everything runs inside ONE jitted solve over pow2-padded window shapes
so a stream of ragged windows never retraces — the same discipline
``serving.ingress.pad_pow2_rows`` applies to embed/scorer calls. Padded
rows carry ``valid = 0`` and zero cost/utility, so they influence
nothing; iteration counts are static (from ``SolverConfig``), making
the solve a fixed-shape dataflow graph. Inputs are normalized on the
host (costs by their max, utilities to unit span) so the device math is
well-conditioned in default f32; the reported cost/utility accounting
is redone on the host in f64 at the original scales.

Two cooperating stages (``method`` picks):

  * **LP relaxation via iterative proportional scaling** (``sinkhorn``,
    the ``auto`` start): a temperature-softened score matrix
    ``(u - lam * c) / T`` is row-normalized and column-capped in
    alternation (Sinkhorn-style IPS with *inequality* column marginals
    — columns only ever scale down, to their capacity), while an outer
    bisection on the budget multiplier ``lam`` drives the relaxation's
    expected cost to the budget. Rounding takes each row's argmax.
  * **greedy with swaps** (``greedy``, also the rounding repair): from
    the current assignment, a bounded sequence of vectorized repair
    moves — demote the smallest-margin rows out of over-capacity tiers
    (the exact top-``cap``-by-margin ranking per tier, applied
    iteratively), walk cost down to the budget by the best
    saved-$-per-utility move, then climb utility back with single-row
    swaps that keep both constraints slack. Each phase is a
    ``lax.while_loop`` whose body applies the single best move, so the
    result is deterministic and the move counts come back as telemetry.

Infeasible inputs degrade gracefully, never raise: a budget below even
the cheapest assignment returns a least-cost-leaning assignment with
``feasible = False`` in the result — the caller's governor sees the
overrun through the realized spend and tightens the next window.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

SOLVER_METHODS = ("auto", "sinkhorn", "greedy")

#: traced-body counter: the solve body only executes while jax traces
#: it, so this counts (re)compilations — the jit-stability tests pin
#: down that pow2-padded window streams never grow it per window
TRACE_COUNT = [0]

_BIG = 1e30
_EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Static dials of one solve graph (part of the jit cache key)."""

    method: str = "auto"
    temperature: float = 0.05       # IPS softmax temperature
    sinkhorn_iters: int = 24        # row/column scaling rounds per plan
    bisect_iters: int = 16          # budget-multiplier bisection steps
    repair_iters: int = 192         # cap + budget repair move bound
    swap_iters: int = 96            # utility-improvement move bound

    def __post_init__(self):
        if self.method not in SOLVER_METHODS:
            raise ValueError(f"unknown method {self.method!r}; expected "
                             f"one of {SOLVER_METHODS}")
        if self.temperature <= 0:
            raise ValueError("temperature must be > 0")
        for f in ("sinkhorn_iters", "bisect_iters", "repair_iters",
                  "swap_iters"):
            if getattr(self, f) < 1:
                raise ValueError(f"{f} must be >= 1")


def pow2_rows(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _pad_rows(x: np.ndarray, n_pad: int) -> np.ndarray:
    n = len(x)
    if n == n_pad:
        return x
    return np.concatenate(
        [x, np.zeros((n_pad - n,) + x.shape[1:], x.dtype)])


# -- the jitted solve (static: shapes + config) ------------------------------

def _counts(a, valid, m):
    """(m,) valid rows per tier under assignment ``a``."""
    return jnp.sum(jax.nn.one_hot(a, m) * valid[:, None], axis=0)


def _ips_relaxation(u, c, caps, budget, valid, cfg: SolverConfig):
    """Entropic LP relaxation: transportation by iterative proportional
    scaling under an outer budget-multiplier bisection. Returns the
    relaxed plan's row argmax — a (possibly infeasible) integral start
    the repair phases make exact. ``u``/``c`` arrive normalized to unit
    scale, so the temperature and multiplier bracket are dimensionless."""
    t = cfg.temperature
    caps_f = jnp.maximum(caps, _EPS)

    def plan_for(lam):
        logp = (u - lam * c) / t

        def scale(_k, logp):
            logp = logp - jax.scipy.special.logsumexp(
                logp, axis=1, keepdims=True)                # rows sum to 1
            col = jnp.sum(jnp.exp(logp) * valid[:, None], axis=0)
            down = jnp.minimum(0.0, jnp.log(caps_f)
                               - jnp.log(jnp.maximum(col, 1e-30)))
            return logp + down[None, :]                     # cap columns

        logp = jax.lax.fori_loop(0, cfg.sinkhorn_iters, scale, logp)
        logp = logp - jax.scipy.special.logsumexp(logp, axis=1,
                                                  keepdims=True)
        return jnp.exp(logp)

    def exp_cost(lam):
        return jnp.sum(plan_for(lam) * c * valid[:, None])

    # bisection bracket: at lam_hi the cost term towers over the unit-
    # span utilities even through the softmax, so every row leans to its
    # cheapest tier — costs cannot go meaningfully lower
    lam_hi = 8.0 / t

    def bisect(_k, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        over = exp_cost(mid) > budget
        return jnp.where(over, mid, lo), jnp.where(over, hi, mid)

    feasible_at_zero = exp_cost(0.0) <= budget
    _lo, hi = jax.lax.fori_loop(0, cfg.bisect_iters, bisect,
                                (0.0, lam_hi))
    lam = jnp.where(feasible_at_zero, 0.0, hi)
    return jnp.argmax(plan_for(lam), axis=1).astype(jnp.int32)


def _repair_caps(u, a, caps, valid, cfg: SolverConfig):
    """Demote rows out of over-capacity tiers, one best move per round:
    among rows on the most-over-cap tier, move the one losing the least
    utility to its best tier with spare capacity — exactly the
    top-``cap``-by-margin ranking, applied iteratively."""
    n, m = u.shape

    def over(state):
        a, moves = state
        return jnp.logical_and(jnp.any(_counts(a, valid, m) > caps),
                               moves < cfg.repair_iters)

    def step(state):
        a, moves = state
        cnt = _counts(a, valid, m)
        j_over = jnp.argmax(cnt - caps)          # most over-capacity tier
        on_j = jnp.logical_and(a == j_over, valid > 0)
        spare = cnt < caps                        # destinations with room
        alt_u = jnp.where(spare[None, :], u, -_BIG)
        best_j = jnp.argmax(alt_u, axis=1)
        best_u = jnp.max(alt_u, axis=1)
        loss = jnp.where(on_j, u[jnp.arange(n), a] - best_u, _BIG)
        i = jnp.argmin(loss)                      # smallest-margin row
        movable = jnp.logical_and(on_j[i], jnp.any(spare))
        a = jnp.where(movable, a.at[i].set(best_j[i]), a)
        return a, moves + 1

    return jax.lax.while_loop(over, step, (a, jnp.int32(0)))


def _pair_machinery(u, c, caps, valid):
    """Shared scaffolding for the pair-move repair phases.

    A *pair move* is two single-row reassignments applied together — one
    may be the appended null move, so singles are a special case. Pairs
    are what single-move local search cannot express: trading one row
    down in cost to afford another row's upgrade, and moving into a
    full tier by simultaneously vacating it. Returns ``deltas(a)`` → all
    (M+1)² candidate pairs' utility/cost deltas + legality mask, and
    ``apply(a, k)`` → ``a`` with flattened pair ``k`` applied."""
    n, m = u.shape
    rows = jnp.arange(n)
    row_f = jnp.concatenate([jnp.repeat(rows, m), jnp.array([-1])])
    dest_f = jnp.concatenate([jnp.tile(jnp.arange(m), n),
                              jnp.array([-1])])
    okrow_f = jnp.concatenate([jnp.repeat(valid > 0, m),
                               jnp.array([True])])
    M = n * m + 1

    def deltas(a):
        cur_u = u[rows, a]
        cur_c = c[rows, a]
        dU = jnp.concatenate([(u - cur_u[:, None]).ravel(),
                              jnp.zeros(1)])
        dC = jnp.concatenate([(c - cur_c[:, None]).ravel(),
                              jnp.zeros(1)])
        src_f = jnp.concatenate([jnp.repeat(a, m), jnp.array([-2])])
        cnt = _counts(a, valid, m)
        room = cnt < caps
        dest_c = jnp.maximum(dest_f, 0)
        ok1 = jnp.logical_and(okrow_f,
                              jnp.where(dest_f >= 0, room[dest_c], True))
        # either move may enter a full tier the OTHER move vacates — the
        # exchange case tight caps force; a no-op move vacates nothing
        vacates = jnp.logical_and(okrow_f, dest_f != src_f)
        relief_a = jnp.logical_and(  # move1's dest freed by move2
            okrow_f[:, None], jnp.logical_and(
                dest_f[:, None] == src_f[None, :], vacates[None, :]))
        relief_b = jnp.logical_and(  # move2's dest freed by move1
            okrow_f[None, :], jnp.logical_and(
                dest_f[None, :] == src_f[:, None], vacates[:, None]))
        pair_ok = jnp.logical_and(
            jnp.logical_or(ok1[:, None], relief_a),
            jnp.logical_or(ok1[None, :], relief_b))
        pair_ok = jnp.logical_and(
            pair_ok, row_f[:, None] != row_f[None, :])
        # both moves into the same tier need two spare slots
        two_slots = cnt[dest_c] <= caps[dest_c] - 2.0
        pair_ok = jnp.logical_and(pair_ok, jnp.logical_or(
            dest_f[:, None] != dest_f[None, :], two_slots[:, None]))
        G = dU[:, None] + dU[None, :]
        DC = dC[:, None] + dC[None, :]
        return G, DC, pair_ok

    def apply_one(a, k):
        r, d = row_f[k], dest_f[k]
        rc = jnp.maximum(r, 0)
        return a.at[rc].set(jnp.where(r >= 0, d, a[rc]))

    def apply(a, flat):
        return apply_one(apply_one(a, flat // M), flat % M)

    return M, deltas, apply


def _repair_budget(u, c, a, caps, budget, valid, cfg: SolverConfig,
                   machinery):
    """Walk realized cost down to the budget: per round, the single
    capacity-respecting cost-reducing pair move with the best
    (saved $ / lost utility) ratio. Stops when on budget or no
    cost-reducing pair remains (infeasible — graceful degradation)."""
    n, _m = u.shape
    M, deltas, apply = machinery

    def total(a):
        return jnp.sum(c[jnp.arange(n), a] * valid)

    def cont(state):
        a, moves, stuck = state
        return jnp.logical_and(
            jnp.logical_and(total(a) > budget, ~stuck),
            moves < cfg.repair_iters)

    def step(state):
        a, moves, _ = state
        G, DC, pair_ok = deltas(a)
        ok = jnp.logical_and(pair_ok, DC < -_EPS * 1e-3)
        ratio = jnp.where(ok, -DC / jnp.maximum(-G, _EPS), -_BIG)
        flat = jnp.argmax(ratio)
        can = ratio[flat // M, flat % M] > -_BIG
        a = jnp.where(can, apply(a, flat), a)
        return a, moves + 1, ~can

    a, moves, _ = jax.lax.while_loop(
        cont, step, (a, jnp.int32(0), jnp.bool_(False)))
    return a, moves


def _improve_swaps(u, c, a, caps, budget, valid, cfg: SolverConfig,
                   machinery):
    """Climb utility under slack constraints: per round, the pair move
    with the largest combined utility gain whose combined cost delta
    still fits the remaining budget — including trades that push one
    row cheaper to afford another row's upgrade."""
    n, _m = u.shape
    M, deltas, apply = machinery

    def total(a):
        return jnp.sum(c[jnp.arange(n), a] * valid)

    def cont(state):
        _a, moves, done = state
        return jnp.logical_and(~done, moves < cfg.swap_iters)

    def step(state):
        a, moves, _ = state
        G, DC, pair_ok = deltas(a)
        slack = budget - total(a)
        ok = jnp.logical_and(pair_ok, G > _EPS)
        ok = jnp.logical_and(ok, DC <= slack)
        score = jnp.where(ok, G, -_BIG)
        flat = jnp.argmax(score)
        can = score[flat // M, flat % M] > -_BIG
        a = jnp.where(can, apply(a, flat), a)
        return a, moves + 1, ~can

    a, moves, _ = jax.lax.while_loop(
        cont, step, (a, jnp.int32(0), jnp.bool_(False)))
    return a, moves


def _solve_body(u, c, caps, budget, valid, cfg: SolverConfig):
    TRACE_COUNT[0] += 1                 # body runs only while tracing
    u = u * valid[:, None]
    c = c * valid[:, None]
    if cfg.method == "greedy":
        a = jnp.argmax(u, axis=1).astype(jnp.int32)
    else:
        a = _ips_relaxation(u, c, caps, budget, valid, cfg)
    machinery = _pair_machinery(u, c, caps, valid)
    a, cap_moves = _repair_caps(u, a, caps, valid, cfg)
    a, cost_moves = _repair_budget(u, c, a, caps, budget, valid, cfg,
                                   machinery)
    a, swap_moves = _improve_swaps(u, c, a, caps, budget, valid, cfg,
                                   machinery)
    return a, cap_moves + cost_moves + swap_moves


@functools.cache
def _jitted_solve(cfg: SolverConfig):
    """One compiled solve per SolverConfig; shapes key the jit cache, so
    pow2-padded windows of the same size share a single trace."""
    return jax.jit(functools.partial(_solve_body, cfg=cfg))


def solve_assignment(utility: np.ndarray, cost: np.ndarray,
                     caps, budget: float,
                     cfg: SolverConfig | None = None) -> dict:
    """Assign each of n queries an entry tier under the window budget
    and per-tier capacity caps.

    utility/cost: (n, m) predicted matrices (``assign.meta``); caps:
    (m,) capacities (``None`` for the whole argument or per entry =
    uncapped); budget: total predicted $ the window may commit.

    Returns a dict: ``assignment`` (n,) int32, ``predicted_cost``,
    ``predicted_utility``, ``feasible`` (both constraints met — False
    means graceful degradation, not an error), ``iterations`` (repair +
    swap moves applied), ``n_padded`` (the pow2 row count solved).
    """
    cfg = cfg or SolverConfig()
    u = np.asarray(utility, np.float64)
    c = np.asarray(cost, np.float64)
    if u.shape != c.shape or u.ndim != 2:
        raise ValueError(f"utility {u.shape} and cost {c.shape} must be "
                         "matching (n, m) matrices")
    n, m = u.shape
    if n == 0:
        return {"assignment": np.zeros(0, np.int32), "predicted_cost": 0.0,
                "predicted_utility": 0.0, "feasible": True,
                "iterations": 0, "n_padded": 0}
    caps_arr = np.full(m, np.inf) if caps is None else \
        np.asarray([np.inf if x is None else float(x) for x in caps],
                   np.float64)
    if caps_arr.shape != (m,):
        raise ValueError(f"caps must be (m,) = ({m},), got "
                         f"{caps_arr.shape}")
    # an over-constrained window must still fit somewhere: scale finite
    # caps up to a feasible total rather than failing the whole window
    finite = np.isfinite(caps_arr)
    room = caps_arr[finite].sum() + (~finite).sum() * n
    if finite.any() and room < n:
        caps_arr = np.where(
            finite, np.ceil(caps_arr * n / max(caps_arr[finite].sum(),
                                               1e-9)), caps_arr)
    caps_arr = np.minimum(np.floor(caps_arr), float(n))
    # normalize for well-conditioned default-dtype device math: costs by
    # their max, utilities to unit span (a global shift never reorders
    # assignments — every row contributes exactly one term)
    c_scale = max(float(c.max()), 1e-12)
    u_lo, u_hi = float(u.min()), float(u.max())
    u_scale = max(u_hi - u_lo, 1e-12)
    budget_n = min(float(budget) / c_scale, float(n) * 2.0)
    n_pad = pow2_rows(n)
    valid = np.zeros(n_pad, np.float32)
    valid[:n] = 1.0
    un = _pad_rows(((u - u_lo) / u_scale).astype(np.float32), n_pad)
    cn = _pad_rows((c / c_scale).astype(np.float32), n_pad)
    a_dev, iters = _jitted_solve(cfg)(
        jnp.asarray(un), jnp.asarray(cn),
        jnp.asarray(caps_arr.astype(np.float32)),
        jnp.float32(budget_n), jnp.asarray(valid))
    a = np.asarray(a_dev)[:n].astype(np.int32)
    # exact f64 accounting at the original scales
    rows = np.arange(n)
    pred_cost = float(c[rows, a].sum())
    pred_util = float(u[rows, a].sum())
    over_cap = np.any(np.bincount(a, minlength=m) > caps_arr + 1e-9)
    feasible = (pred_cost <= float(budget) * (1.0 + 1e-6) + 1e-12
                and not over_cap)
    return {
        "assignment": a,
        "predicted_cost": pred_cost,
        "predicted_utility": pred_util,
        "feasible": bool(feasible),
        "iterations": int(iters),
        "n_padded": n_pad,
    }
