"""Window meta-model: per-(query, tier) success and downstream cost.

The contextual router (``strategy.router``) predicts, per query, which
cascade position would *accept* — and picks an entry greedily against a
bar. The assignment subsystem needs more: for a whole arrival window at
once, an (n × m) matrix of what each entry choice is *worth* and what
it is *expected to cost*, so a global solver can trade queries against
each other under a shared budget (Šakota et al.'s meta-modeling framing
combined with Zhang et al.'s budget-constrained entry rule).

``WindowMeta`` is a two-head MLP over the same scorer-encoder
embeddings the router and the completion cache already use (no extra
encoder): a shared gelu trunk with an *accept* head (would position
k's answer clear its threshold — the router's target, reused verbatim
via ``strategy.router.accept_labels``) and a *correct* head (would
position k's answer actually be right — supervised by the recorded
correctness of the offline build's MarketData). The two heads compose
into entry-conditional expectations by unrolling the cascade chain:
entering at ``e``, the query reaches position ``k`` with probability
``prod_{l in [e, k)} (1 - p_acc[l])``, stops there with probability
``reach * p_acc[k]`` (the final position stops unconditionally), and
pays that position's price whenever it reaches it. Hence

    utility[:, e]  = sum_k stop[k | e] * p_correct[:, k]
    exp_cost[:, e] = sum_{k >= e} reach[k | e] * price[:, k]

— expected answer quality and expected realized $ of entering each
query at each tier, exactly the matrices ``assign.solver`` consumes.
This also subsumes the cost-aware-entry follow-up: expected *downstream*
cost, not a single accept bar, is what the assignment optimizes.

Prices are per-(query, tier) and exact (adapted-prompt token counts via
``ServingPipeline._tier_cost``), passed in at scoring time; the chain
composition itself is one jitted function shared by every instance.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optim import OptConfig, adamw_update, init_opt_state


def _meta_forward(params, emb):
    """(n, d) embeddings -> accept and correct logits, both (n, m)."""
    h = jax.nn.gelu(emb @ params["w1"] + params["b1"])
    return h @ params["wa"] + params["ba"], h @ params["wc"] + params["bc"]


def _chain_scores(p_acc, p_cor, prices):
    """Compose head probabilities into entry-conditional expectations.

    All (n, m). Unrolled over the (small, static) tier count: for each
    entry column ``e`` walk positions ``e..m-1`` carrying the reach
    probability. Returns (utility, exp_cost), both (n, m).
    """
    n, m = p_acc.shape
    util_cols, cost_cols = [], []
    for e in range(m):
        reach = jnp.ones((n,), p_acc.dtype)
        util = jnp.zeros((n,), p_acc.dtype)
        cost = jnp.zeros((n,), p_acc.dtype)
        for k in range(e, m):
            cost = cost + reach * prices[:, k]
            stop = reach if k == m - 1 else reach * p_acc[:, k]
            util = util + stop * p_cor[:, k]
            reach = reach * (1.0 - p_acc[:, k])
        util_cols.append(util)
        cost_cols.append(cost)
    return jnp.stack(util_cols, axis=1), jnp.stack(cost_cols, axis=1)


@functools.cache
def _jitted_scores():
    """One jitted forward+chain shared by every WindowMeta — shapes are
    part of the jit cache key, so window sizes pad to pow2 upstream."""

    def fwd(params, emb, prices):
        acc_logit, cor_logit = _meta_forward(params, emb)
        return _chain_scores(jax.nn.sigmoid(acc_logit),
                             jax.nn.sigmoid(cor_logit), prices)

    return jax.jit(fwd)


@functools.cache
def _jitted_predict():
    def fwd(params, emb):
        acc_logit, cor_logit = _meta_forward(params, emb)
        return jax.nn.sigmoid(acc_logit), jax.nn.sigmoid(cor_logit)

    return jax.jit(fwd)


def init_meta_params(key, d_in: int, n_tiers: int, hidden: int = 64):
    k1, k2, k3 = jax.random.split(key, 3)
    scale = 1.0 / np.sqrt(d_in)
    return {
        "w1": scale * jax.random.normal(k1, (d_in, hidden)),
        "b1": jnp.zeros((hidden,)),
        "wa": 0.02 * jax.random.normal(k2, (hidden, n_tiers)),
        "ba": jnp.zeros((n_tiers,)),
        "wc": 0.02 * jax.random.normal(k3, (hidden, n_tiers)),
        "bc": jnp.zeros((n_tiers,)),
    }


def correctness_labels(correct: np.ndarray, apis) -> np.ndarray:
    """(n, m) supervision for the correct head: the recorded correctness
    of each cascade position's API on each build query."""
    return np.asarray(correct)[:, np.asarray(apis)].astype(np.float32)


def train_window_meta(emb: np.ndarray, accept: np.ndarray,
                      correct: np.ndarray, *, hidden: int = 64,
                      steps: int = 300, batch: int = 256,
                      lr: float = 3e-3, seed: int = 0) -> "WindowMeta":
    """Train both heads jointly with BCE; mirrors
    ``strategy.router.train_entry_router`` (same optimizer, same
    minibatch discipline) so build times stay comparable.

    emb (n, d) scorer-encoder embeddings; accept (n, m) from
    ``strategy.router.accept_labels``; correct (n, m) from
    ``correctness_labels``.
    """
    emb = jnp.asarray(emb, jnp.float32)
    accept = jnp.asarray(accept, jnp.float32)
    correct = jnp.asarray(correct, jnp.float32)
    n, d = emb.shape
    m = accept.shape[1]
    params = init_meta_params(jax.random.PRNGKey(seed), d, m, hidden)
    opt = OptConfig(lr=lr, warmup=10, total_steps=steps, weight_decay=1e-4)
    state = init_opt_state(params)
    rng = np.random.default_rng(seed)

    def bce(logit, y):
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    @jax.jit
    def step_fn(params, state, x, ya, yc):
        def loss_fn(p):
            acc_logit, cor_logit = _meta_forward(p, x)
            return bce(acc_logit, ya) + bce(cor_logit, yc)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = adamw_update(opt, params, grads, state)
        return params, state, loss

    for _ in range(steps):
        idx = rng.choice(n, size=min(batch, n), replace=False)
        params, state, _ = step_fn(params, state, emb[idx],
                                   accept[idx], correct[idx])
    return WindowMeta(params=params, n_tiers=m)


@dataclasses.dataclass
class WindowMeta:
    """Trained two-head window scorer over scorer-encoder embeddings."""

    params: dict
    n_tiers: int

    def predict(self, emb: np.ndarray):
        """emb (n, d) -> (accept, correct) probabilities, both (n, m)."""
        emb = np.atleast_2d(np.asarray(emb, np.float32))
        pa, pc = _jitted_predict()(self.params, jnp.asarray(emb))
        return np.asarray(pa, np.float64), np.asarray(pc, np.float64)

    def scores(self, emb: np.ndarray, prices: np.ndarray):
        """emb (n, d), prices (n, m) $ per (query, tier) -> the solver's
        (utility, exp_cost) matrices, both (n, m) float64.

        Prices are normalized by their max before the f32 device chain
        and rescaled after, so marketplace magnitudes (~1e-5 $/query)
        keep full precision.
        """
        emb = np.atleast_2d(np.asarray(emb, np.float32))
        prices = np.atleast_2d(np.asarray(prices, np.float64))
        if prices.shape != (emb.shape[0], self.n_tiers):
            raise ValueError(f"prices {prices.shape} must be "
                             f"({emb.shape[0]}, {self.n_tiers})")
        p_scale = max(float(prices.max()), 1e-12)
        util, cost = _jitted_scores()(
            self.params, jnp.asarray(emb),
            jnp.asarray((prices / p_scale).astype(np.float32)))
        return (np.asarray(util, np.float64),
                np.asarray(cost, np.float64) * p_scale)

    def accept_probs(self, emb: np.ndarray) -> np.ndarray:
        """Router-compatible accept probabilities (n, m) — lets the
        greedy entry rule and the assignment share one trained model in
        head-to-head comparisons."""
        return self.predict(emb)[0]
