"""Window accumulation + budgeted dispatch for assignment routing.

The third routing mode's runtime half: collect an arrival window
(``WindowBuffer`` — drain on fill, age, or deadline pressure), score it
as one batch (``WindowMeta``), solve the budgeted assignment
(``assign.solver``), and hand per-query entry tiers to the existing
``execute_cascade(entry=)`` dispatch. ``WindowAssigner`` owns the
per-window policy — where the $ budget comes from (an explicit
``window_budget`` or the governor's target rate, tightened by its
current spend pressure) and where per-tier capacity caps come from (a
static window fraction, derated by the scheduler's utilization
estimators when they exist) — plus the realized-vs-predicted telemetry
``ServeResult.strategy`` reports.

Batch serve() uses only ``WindowAssigner`` (misses are already a batch;
it chunks them into windows); the stream scheduler adds
``WindowBuffer`` to turn an arrival *stream* into windows without
violating SLO deadlines.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

import numpy as np

from repro.serving.assign.solver import SolverConfig, solve_assignment


@dataclasses.dataclass(frozen=True)
class AssignConfig:
    """Dials of the window-assignment routing mode.

    Build-time: ``hidden``/``steps``/``batch``/``lr``/``seed`` train the
    window meta-model (mirroring the contextual router's dials).
    Run-time: windows of up to ``window_size`` queries are assigned
    together under ``window_budget`` $ (None derives it from the
    governor: ``budget_rate * n``, tightened by the live spend
    pressure); ``max_wait_s`` bounds how long the stream path may hold
    an arrival for its window; ``capacity_frac`` caps each tier at that
    fraction of the window (None = uncapped), derated by live tier
    utilization when the scheduler's estimators are wired in;
    ``solver`` carries the on-device solver's static dials.
    """

    window_size: int = 32
    window_budget: float | None = None   # $ per full window (pro-rated)
    max_wait_s: float = 0.05
    capacity_frac: float | None = None
    solver: SolverConfig = SolverConfig()
    hidden: int = 64
    steps: int = 300
    batch: int = 256
    lr: float = 3e-3
    seed: int = 0

    def __post_init__(self):
        if self.window_size < 1:
            raise ValueError("window_size must be >= 1")
        if self.window_budget is not None and self.window_budget <= 0:
            raise ValueError("window_budget must be > 0 (None to derive "
                             "from the governor)")
        if self.max_wait_s < 0:
            raise ValueError("max_wait_s must be >= 0")
        if self.capacity_frac is not None and not (
                0.0 < self.capacity_frac <= 1.0):
            raise ValueError("capacity_frac must be in (0, 1]")


class WindowBuffer:
    """Accumulates stream arrivals into assignment windows.

    ``due(now)`` when the window filled, the oldest arrival waited
    ``max_wait_s``, or an item's deadline leaves less than
    ``pressure_s`` of slack — the stream scheduler drains then, so
    window formation never pushes a request past its SLO deadline."""

    def __init__(self, cfg: AssignConfig):
        self.cfg = cfg
        self._rows: list[tuple] = []    # (item, t_add, deadline)

    def __len__(self):
        return len(self._rows)

    def add(self, item, now: float, deadline: float | None = None):
        self._rows.append((item, now,
                           math.inf if deadline is None else deadline))

    def due(self, now: float, pressure_s: float = 0.0) -> bool:
        if not self._rows:
            return False
        if len(self._rows) >= self.cfg.window_size:
            return True
        if now - self._rows[0][1] >= self.cfg.max_wait_s:
            return True
        return now + pressure_s >= min(d for _, _, d in self._rows)

    def next_due(self) -> float:
        """Earliest absolute time the buffer becomes due by age alone
        (inf when empty) — the scheduler's poll horizon."""
        if not self._rows:
            return math.inf
        return min(self._rows[0][1] + self.cfg.max_wait_s,
                   min(d for _, _, d in self._rows))

    def drain(self, k: int | None = None) -> list:
        """Pop the oldest ``k`` items (all, when None) — a burst that
        outgrew one window drains as several ``window_size`` windows,
        each solved on its own, with the remainder keeping its own age
        and deadline bookkeeping."""
        k = len(self._rows) if k is None else min(k, len(self._rows))
        popped, self._rows = self._rows[:k], self._rows[k:]
        return [item for item, _, _ in popped]


@dataclasses.dataclass
class WindowAssigner:
    """Per-window budgeted assignment policy + telemetry.

    Stateless per window except for the telemetry counters; safe to
    share across windows under the caller's serialization domain (the
    scheduler's lock, or the single-threaded batch path)."""

    meta: object                          # WindowMeta
    cfg: AssignConfig = AssignConfig()

    def __post_init__(self):
        self.n_windows = 0
        self.n_assigned = 0
        self.n_infeasible = 0
        self.fill_sum = 0.0
        self.budget_sum = 0.0
        self.pred_cost_sum = 0.0
        self.pred_util_sum = 0.0
        self.realized_cost_sum = 0.0
        self.realized_acc_sum = 0.0
        self.n_observed = 0
        self.solver_iters = 0
        self.solver_secs = 0.0
        self.entry_hist: dict[int, int] = {}

    # -- policy ------------------------------------------------------------
    def budget_for(self, n: int, governor=None) -> float:
        """$ this window may commit: the explicit per-window budget
        pro-rated to the actual fill, else the governor's target rate —
        tightened by its live spend pressure (positive shift = the
        stream is running hot, so windows get leaner until the dual
        controller re-centers)."""
        if self.cfg.window_budget is not None:
            return self.cfg.window_budget * n / self.cfg.window_size
        if governor is not None:
            return governor.window_budget(n)
        return math.inf

    def caps_for(self, n: int, n_tiers: int,
                 utilization: Sequence[float] | None = None):
        """Per-tier caps: ``capacity_frac`` of the window each, derated
        by live utilization (a tier at 80% load offers only 20% of its
        static cap, floored at one slot so no tier is ever fully
        fenced — the breaker owns hard unavailability)."""
        if self.cfg.capacity_frac is None:
            return None
        base = self.cfg.capacity_frac * n
        caps = np.full(n_tiers, base, np.float64)
        if utilization is not None:
            u = np.clip(np.asarray(utilization, np.float64), 0.0, 1.0)
            caps = caps * (1.0 - u)
        return np.maximum(1.0, np.ceil(caps))

    # -- the per-window solve ----------------------------------------------
    def assign(self, emb: np.ndarray, prices: np.ndarray, *,
               governor=None, utilization=None,
               budget: float | None = None) -> dict:
        """Score + solve one window. emb (n, d), prices (n, m) exact
        per-(query, tier) $. Returns the solver dict plus the scoring
        matrices (``utility``/``exp_cost``) and the window ``budget``."""
        n = len(emb)
        m = self.meta.n_tiers
        utility, exp_cost = self.meta.scores(emb, prices)
        if budget is None:
            budget = self.budget_for(n, governor)
        caps = self.caps_for(n, m, utilization)
        t0 = time.perf_counter()
        res = solve_assignment(utility, exp_cost, caps, budget,
                               self.cfg.solver)
        secs = time.perf_counter() - t0
        self.n_windows += 1
        self.n_assigned += n
        self.fill_sum += n / self.cfg.window_size
        if math.isfinite(budget):
            self.budget_sum += budget
        self.pred_cost_sum += res["predicted_cost"]
        self.pred_util_sum += res["predicted_utility"]
        self.n_infeasible += 0 if res["feasible"] else 1
        self.solver_iters += res["iterations"]
        self.solver_secs += secs
        for e in res["assignment"]:
            self.entry_hist[int(e)] = self.entry_hist.get(int(e), 0) + 1
        res.update(utility=utility, exp_cost=exp_cost, budget=budget,
                   solver_secs=secs)
        return res

    # -- telemetry ---------------------------------------------------------
    def observe(self, costs, accepted) -> None:
        """Fold one window's realized outcome back in: per-query $ and
        0/1 answer acceptance/correctness — the realized counterparts of
        the solver's predicted cost and utility."""
        costs = np.asarray(costs, np.float64)
        self.realized_cost_sum += float(costs.sum())
        self.realized_acc_sum += float(np.sum(accepted))
        self.n_observed += len(costs)

    def snapshot(self) -> dict:
        nw = max(1, self.n_windows)
        na = max(1, self.n_assigned)
        return {
            "n_windows": self.n_windows,
            "n_assigned": self.n_assigned,
            "window_fill": self.fill_sum / nw,
            "n_infeasible": self.n_infeasible,
            "entry_hist": dict(sorted(self.entry_hist.items())),
            "predicted_cost_per_q": self.pred_cost_sum / na,
            "predicted_utility_per_q": self.pred_util_sum / na,
            "realized_cost_per_q": (
                self.realized_cost_sum / self.n_observed
                if self.n_observed else 0.0),
            "realized_accept_rate": (
                self.realized_acc_sum / self.n_observed
                if self.n_observed else 0.0),
            "budget_utilization": (
                self.pred_cost_sum / self.budget_sum
                if self.budget_sum > 0 else 0.0),
            "solver_iterations": self.solver_iters,
            "solver_secs": self.solver_secs,
            "solver_secs_per_window": self.solver_secs / nw,
        }
