"""The unified FrugalGPT serving pipeline: all three cost-reduction
strategies composed on ONE batched request path (paper §3, Fig. 2).

A token batch flows through three stages:

  1. completion cache (§3.2, LLM approximation) — queries are embedded
     with the scorer's encoder (no extra model) and answered from the
     nearest-neighbour cache when similarity clears the threshold;
  2. prompt adaptation (§3.1) — every cache miss is billed against the
     *adapted* per-tier few-shot prefix (``PromptSpec``) instead of the
     full prompt, with exact ``ApiCost`` token accounting;
  3. LLM cascade (§3.3) — misses run tier-by-tier with compaction
     through the repo's single cascade executor
     (``repro.core.cascade.execute_cascade``); answer, cost and scorer
     calls are all chunked to ``batch_size``.

Fresh answers are inserted back into the cache, and every request batch
returns a ``ServeResult`` telemetry record: per-tier counts, cache hit
rate, per-stage latency, and cost against the always-top-tier baseline.

Two request paths share these stages:

  * ``serve``        — batch-at-a-time: one closed token batch through
    all three stages;
  * ``serve_stream`` / ``aserve`` — continuous batching over an arrival
    trace (``repro.serving.ingress``): cache lookup runs per-admission,
    tier chunks are packed from whatever is waiting, and per-request
    latency telemetry lands in ``ServeResult.ingress``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import numpy as np

from repro.core.approx import CompletionCache
from repro.core.cascade import CascadeTier, execute_cascade
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec


def _merge_answers(n: int, parts: Sequence[tuple]) -> np.ndarray:
    """Scatter ``(indices, values)`` parts into one (n,) answers array,
    preserving the values' dtype: int cache hits merged with int cascade
    answers densify to an integer array, string/object generation answers
    stay as they came from the executor instead of being forced through
    ``np.int32`` (which crashed on strings and silently truncated
    floats)."""
    if n == 0:
        return np.zeros(0, np.int32)
    out = np.empty(n, dtype=object)
    for idx, vals in parts:
        idx = np.asarray(idx).ravel()
        vals = np.asarray(vals)
        if vals.dtype == object or vals.ndim != 1:
            for i_local, i_global in enumerate(idx):
                out[i_global] = vals[i_local]
        else:
            out[idx] = vals
    try:                                     # densify when answers are scalar
        # unbox numpy scalars first so both fill branches above densify
        # to the same dtype (fancy assignment into an object array boxes
        # to Python scalars; per-element assignment keeps np scalars)
        dense = np.array([x.item() if isinstance(x, np.generic) else x
                          for x in out])
        if dense.ndim == 1 and dense.dtype != object:
            return dense
    except ValueError:                       # heterogeneous answer objects
        pass
    return out


@dataclasses.dataclass
class TierSpec:
    """One serving tier: a live model plus its economics.

    ``answer(tokens (b, L)) -> answers (b,)``; ``price`` is the exact
    3-term API cost model; ``prompt`` is the tier's adapted few-shot
    prefix (None = bill the full, unadapted prompt).
    """

    name: str
    answer: Callable
    price: ApiCost
    prompt: PromptSpec | None = None
    n_out: int = 1
    # the jax.Device this tier's model is pinned to (sharding.placement);
    # None = wherever the backend already lives (shared default device).
    # Placement happens where the tier's params are created/moved — this
    # field records the decision for telemetry and scheduling.
    device: object | None = None
    # ... or the mesh slice the tier's model is sharded over
    # (sharding.tier_mesh): params sharded per sharding.rules, batches
    # device_put onto the slice by the engine. Mutually exclusive with
    # ``device``; like it, this records the decision for telemetry.
    mesh: object | None = None


@dataclasses.dataclass
class ServeResult:
    """Telemetry for one served batch."""

    answers: np.ndarray          # (n,) final answers
    cost: np.ndarray             # (n,) accounted USD per query
    stopped_at: np.ndarray       # (n,) cascade position; -1 = cache hit
    tier_counts: list            # queries reaching each tier (compaction)
    tier_names: list
    cache_hits: int
    cache_misses: int
    prompt_tokens_saved: int     # adapted vs full prompt, summed over calls
    baseline_cost: float         # top tier + full prompt for every query
    latency: dict                # per-stage seconds
    # streaming telemetry (stream paths only): per-request latency and
    # queue-wait arrays, chunks per tier, chunk occupancy; the parallel
    # scheduler adds per-tier utilization/EWMA estimates, deadline-hit
    # rate, shed/degraded counts and queue peaks
    ingress: dict | None = None
    # contextual-strategy telemetry (pipelines with a ServingStrategy):
    # entry-tier histogram, realized spend rate, predicted-vs-realized
    # accept rate, governor state + threshold trace — cumulative over
    # the strategy's lifetime (it outlives individual batches/streams)
    strategy: dict | None = None

    @property
    def n(self) -> int:
        return len(self.answers)

    @property
    def cache_hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0

    @property
    def savings_frac(self) -> float:
        if self.baseline_cost <= 0:
            return 0.0
        return 1.0 - float(self.cost.sum()) / self.baseline_cost

    def summary(self) -> str:
        lat = ", ".join(f"{k} {v * 1e3:.0f}ms" for k, v in
                        self.latency.items())
        tiers = ", ".join(f"{nm}: {c}" for nm, c in
                          zip(self.tier_names, self.tier_counts))
        extra = ""
        if self.ingress is not None and len(self.ingress["request_latency"]):
            rl = self.ingress["request_latency"]
            extra = (f" | per-request p50 {np.percentile(rl, 50) * 1e3:.0f}ms"
                     f" p95 {np.percentile(rl, 95) * 1e3:.0f}ms over "
                     f"{self.ingress['n_chunks']} chunks (occupancy "
                     f"{self.ingress['chunk_occupancy']:.2f})")
        if self.ingress is not None and "tier_utilization" in self.ingress:
            util = ", ".join(f"{u:.2f}" for u in
                             self.ingress["tier_utilization"])
            extra += f" | tier util [{util}]"
            dhr = self.ingress.get("deadline_hit_rate")
            if dhr is not None:
                extra += f" | deadline hit rate {dhr:.2f}"
            if self.ingress.get("shed") or self.ingress.get("degraded"):
                extra += (f" | overload: {self.ingress['shed']} shed, "
                          f"{self.ingress['degraded']} degraded")
        spec = (self.ingress or {}).get("speculation")
        if spec is not None:
            extra += (f" | speculation: {spec['committed']}/{spec['issued']}"
                      f" committed, {spec['cancelled']} cancelled "
                      f"({spec['wasted_s'] * 1e3:.0f}ms wasted)")
        res = (self.ingress or {}).get("resilience")
        if res is not None:
            extra += (f" | resilience: {res['retries']} retries "
                      f"(+{res['backoff_s'] * 1e3:.0f}ms backoff), "
                      f"{res['failovers']} failovers, {res['trips']} trips/"
                      f"{res['recoveries']} recoveries, "
                      f"{res['fallback_answers']} degraded answers, "
                      f"{res['shed']} shed")
        if self.strategy is not None:
            extra += (f" | entry tiers {self.strategy['entry_hist']} "
                      f"(bar {self.strategy['entry_bar']:.2f}) | spend "
                      f"${self.strategy['spend_rate']:.6f}/q")
            gov = self.strategy.get("governor")
            if gov is not None:
                extra += (f" vs ${gov['budget_rate']:.6f} target "
                          f"(shift {gov['shift']:+.3f})")
            gtee = self.strategy.get("guarantee")
            if gtee is not None:
                extra += (
                    f" | guarantee: gap {gtee['gap_hat']:.3f} "
                    f"(ucb {gtee['gap_ucb']:.3f}) vs delta "
                    f"{gtee['delta']:.3f} at alpha {gtee['alpha']:.2f}, "
                    f"level {gtee['level']}/{gtee['levels'] - 1}, "
                    f"{gtee['n_shadow']} shadowed "
                    f"({gtee['n_invoked']} invoked, "
                    f"${gtee['shadow_cost']:.6f} shadow)")
            asg = self.strategy.get("assign")
            if asg is not None:
                extra += (
                    f" | assign: {asg['n_windows']} windows "
                    f"(fill {asg['window_fill']:.2f}), budget util "
                    f"{asg['budget_utilization']:.2f}, predicted "
                    f"{asg['predicted_utility_per_q']:.2f} vs realized "
                    f"{asg['realized_accept_rate']:.2f} accept, solver "
                    f"{asg['solver_iterations']} moves/"
                    f"{asg['solver_secs_per_window'] * 1e3:.1f}ms per window")
        return (
            f"served {self.n} queries | cache hit rate "
            f"{self.cache_hit_rate:.2f} ({self.cache_hits} hits) | "
            f"tier compaction [{tiers}] | prompt tokens saved "
            f"{self.prompt_tokens_saved} | cost ${self.cost.sum():.6f} vs "
            f"${self.baseline_cost:.6f} top-tier baseline "
            f"({100 * self.savings_frac:.0f}% saved) | {lat}{extra}")


@dataclasses.dataclass
class ServingPipeline:
    """Completion cache -> prompt adaptation -> LLM cascade, batched."""

    tiers: Sequence[TierSpec]
    thresholds: Sequence[float]          # len = len(tiers) - 1
    scorer: Callable                     # (tokens, answers) -> scores (n,)
    cache: CompletionCache | None = None
    embed: Callable | None = None        # tokens (n, L) -> embeddings (n, d)
    full_prompt_tokens: int = 0          # unadapted few-shot prefix length
    pad_token: int = 0
    batch_size: int = 256
    # economics of the marketplace's top tier, for the savings baseline —
    # the learned cascade may not end there (budget fallback), so this
    # must not default to whatever tier happens to be last in the cascade
    baseline_price: ApiCost | None = None
    baseline_n_out: int = 1
    # contextual routing + budget governance (repro.serving.strategy):
    # a ServingStrategy, or None for the classic fixed cascade — every
    # serving path is bit-identical to the fixed cascade when unset
    strategy: object | None = None
    # pending-set compaction mode for the batch cascade ("host" numpy |
    # "device" jitted gather+prefix-sum | "pallas" kernel) — opt-in,
    # bit-identical to "host" (repro.kernels.cascade_compact)
    compact: str = "host"
    # speculative cascade execution (repro.serving.sched): idle tier
    # workers pre-invoke predicted-reject rows still decoding upstream.
    # A *stream-scheduler* knob: serve()/the serial batcher have no idle
    # tier workers, so it is a no-op there by construction — which is
    # what keeps the {serve, serial, scheduler} equivalence matrix
    # closed. An explicit slo= passed to the stream entry points wins
    # (it carries its own speculation dials).
    speculate: bool = False
    # fault tolerance (repro.serving.resilience) — all three default
    # off, and off means structurally absent (no wrappers, no extra
    # branches), which is what keeps every serve path bit-identical:
    # per-tier fault injection (a FaultSpec, an index-aligned list of
    # FaultSpec/None, or None), ...
    faults: object | None = None
    # ... per-tier retry for TierFault invoke failures, ...
    retry: object | None = None
    # ... and per-tier circuit breakers (BreakerConfig) driving
    # failover escalation past unavailable tiers. An explicit slo=
    # passed to the stream entry points wins, as for speculate.
    breaker: object | None = None
    # the EnginePool backing generation tiers, when there is one — a
    # breaker trip cancels its in-flight speculative prefills
    # (EnginePool.cancel_all); None for marketplace/toy tiers
    engine_pool: object | None = None

    def __post_init__(self):
        from repro.core.cascade import COMPACT_MODES
        if self.compact not in COMPACT_MODES:
            raise ValueError(f"unknown compact mode {self.compact!r}; "
                             f"expected one of {COMPACT_MODES}")
        if self.cache is not None and self.embed is None:
            raise ValueError("a completion cache needs an embed function "
                             "(reuse the scorer encoder, see builder)")
        if (self.strategy is not None
                and getattr(self.strategy, "router", None) is not None
                and self.embed is None):
            raise ValueError("a contextual router routes on embeddings: "
                             "give the pipeline an embed function (reuse "
                             "the scorer encoder, see builder)")
        if (self.strategy is not None
                and getattr(self.strategy, "mode", "entry") == "assign"
                and self.embed is None):
            raise ValueError("window assignment scores on embeddings: "
                             "give the pipeline an embed function (reuse "
                             "the scorer encoder, see builder)")

    @staticmethod
    def _block(x):
        """Force pending async JAX work at a stage boundary — jax
        dispatch is asynchronous, so without a sync the *next* stage's
        timer pays for this stage's compute. No-op on numpy."""
        return jax.block_until_ready(x)

    # -- stage 2: exact per-tier cost with the adapted prompt --------------
    def _query_tokens(self, tokens: np.ndarray) -> np.ndarray:
        return np.asarray((tokens != self.pad_token).sum(-1), np.int64)

    def _tier_cost(self, spec: TierSpec, tokens: np.ndarray) -> np.ndarray:
        prefix = (spec.prompt.n_tokens if spec.prompt is not None
                  else self.full_prompt_tokens)
        n_q = self._query_tokens(tokens)
        n_out = np.full_like(n_q, spec.n_out)
        return np.asarray(spec.price.query_cost(n_q + prefix, n_out),
                          np.float64)

    def _tier_prices(self, tokens: np.ndarray) -> np.ndarray:
        """(n, m) exact per-(query, tier) $ with each tier's adapted
        prompt — the window meta-model's price input."""
        return np.stack([self._tier_cost(s, tokens) for s in self.tiers],
                        axis=1)

    def _baseline_cost(self, tokens: np.ndarray) -> float:
        """Everything to the marketplace top tier, full prompt, no cache."""
        if self.baseline_price is not None:
            price, n_out = self.baseline_price, self.baseline_n_out
        else:
            price, n_out = self.tiers[-1].price, self.tiers[-1].n_out
        n_q = self._query_tokens(tokens)
        return float(np.asarray(price.query_cost(
            n_q + self.full_prompt_tokens,
            np.full_like(n_q, n_out))).sum())

    # -- pieces shared with the continuous batcher (serving.ingress) -------
    def _cascade_tiers(self, clock=None, sleep=None) -> list[CascadeTier]:
        """The live tiers as cascade stages: one invoke = answer + the
        exact adapted-prompt cost for the same chunk. With ``faults``
        configured, the affected tiers come back wrapped in
        ``FaultyTier`` (the stream scheduler wires its clock into the
        wrappers at start; the batch path sees draw-based faults at
        t=0 unless a ``clock`` — e.g. a ``VirtualClock`` — is passed
        through ``serve``)."""
        tiers = [CascadeTier(
                     s.name,
                     lambda q, s=s: (s.answer(q), self._tier_cost(s, q)))
                 for s in self.tiers]
        if self.faults is not None:
            from repro.serving.resilience import wrap_tiers
            tiers = wrap_tiers(tiers, self.faults, clock=clock, sleep=sleep)
        return tiers

    def _pos_scorer(self, q, a, _j):
        return self.scorer(q, a)

    def _prompt_saved(self, tier_counts: Sequence[int]) -> int:
        saved = 0
        for spec, c in zip(self.tiers, tier_counts):
            if spec.prompt is not None:
                saved += c * (self.full_prompt_tokens - spec.prompt.n_tokens)
        return int(saved)

    def _cache_refresh(self):
        """Refresh the completion cache's *similarity threshold* from the
        budget governor when it owns one (``BudgetGovernor.
        base_threshold``) — overspend admits more near-duplicate hits
        (free answers), spare budget tightens back toward exactness.
        Called at every lookup site (``serve``, ``stage1_lookup``) so
        both serving paths read the same window's dial."""
        if self.cache is None:
            return
        strat = self.strategy
        gov = getattr(strat, "governor", None) if strat is not None else None
        if gov is not None:
            thr = gov.cache_threshold()
            if thr is not None:
                self.cache.threshold = thr

    def _cache_insert(self, emb_rows: np.ndarray, answers,
                      scores=None) -> bool:
        """Insert fresh answers — the cache is int-keyed, so non-integer
        (string/object generation) answers are skipped rather than
        crashed on or silently truncated. ``scores`` (accept-time
        reliability) feed the cache's ``min_score`` confidence floor.
        When the strategy's budget governor owns that floor
        (``BudgetGovernor.base_min_score``), the cache's floor is
        refreshed from it first, so spend overruns loosen what is
        cacheable and spare budget tightens it."""
        strat = self.strategy
        gov = getattr(strat, "governor", None) if strat is not None else None
        if gov is not None:
            ms = gov.min_score()
            if ms is not None:
                self.cache.min_score = ms
        a = np.asarray(answers)
        if a.dtype == object:
            try:
                a = np.array(a.tolist())
            except ValueError:
                return False
        if a.ndim != 1 or not np.issubdtype(a.dtype, np.integer):
            return False
        self.cache.insert(emb_rows, a, scores)
        return True

    # -- stage 3.5: accuracy-guarantee shadow audit ------------------------
    def _shadow_audit(self, tokens, miss, res_ans, stopped, emb, guar):
        """Shadow-sample this batch's served misses against the
        reference (top) tier (``repro.serving.guarantee``).

        Picks are drawn from the controller's seeded per-query coin (in
        row order, so a fixed seed reproduces the subset). A picked row
        that already stopped at the top tier IS the reference answer —
        a free zero-gap observation. The rest invoke the raw reference
        tier in ``batch_size`` chunks; shadow calls bypass fault
        injection (they are measurement, not service) and their cost is
        charged to the controller's separate shadow meter, never to the
        request or the governor's spend rate. Shadow agreement also
        labels the online router retrainer at the stopping position
        (skipping top-tier rows, whose agreement is trivial)."""
        top = len(self.tiers) - 1
        spec = self.tiers[top]
        picks = [i for i in range(len(miss)) if guar.should_sample()]
        if not picks:
            return
        need = [i for i in picks if stopped[i] != top]
        ref_ans: dict = {}
        ref_cost: dict = {}
        for s in range(0, len(need), self.batch_size):
            rows = need[s:s + self.batch_size]
            sub = tokens[miss[rows]]
            ans = np.asarray(spec.answer(sub))
            c = self._tier_cost(spec, sub)
            for k, i in enumerate(rows):
                ref_ans[i] = ans[k]
                ref_cost[i] = float(c[k])
        rt = getattr(guar, "retrainer", None)
        for i in picks:
            if stopped[i] == top:
                guar.observe(0.0, 0.0, invoked=False)
                continue
            agree = bool(np.all(res_ans[i] == ref_ans[i]))
            guar.observe(0.0 if agree else 1.0, ref_cost[i], invoked=True)
            if rt is not None and emb is not None:
                rt.observe(emb[miss[i]], int(stopped[i]), agree)

    def serve(self, tokens: np.ndarray, *, clock=None,
              sleep=None) -> ServeResult:
        """One closed token batch through all three stages. ``clock``/
        ``sleep`` (optional, e.g. a ``resilience.VirtualClock`` and its
        ``.sleep``) own time on the cascade's resilience path — fault
        windows, retry backoff and latency spikes then advance virtual
        time instead of wall-sleeping, with identical accounting."""
        t0 = time.perf_counter()
        n = tokens.shape[0]
        cost = np.zeros(n, np.float64)
        stopped_at = np.full(n, -1, np.int32)
        latency: dict = {}

        # stage 1: completion cache
        hits = 0
        emb = None
        hit_idx = np.zeros(0, np.int64)
        hit_ans = np.zeros(0, np.int32)
        miss = np.arange(n)
        if self.cache is not None:
            t = time.perf_counter()
            emb = np.asarray(self._block(self.embed(tokens)))
            latency["embed"] = time.perf_counter() - t
            t = time.perf_counter()
            self._cache_refresh()   # governor-owned similarity threshold
            hit_mask, cached = self.cache.lookup(emb)
            hit_idx = np.flatnonzero(hit_mask)
            hit_ans = cached[hit_idx]
            hits = len(hit_idx)
            miss = np.flatnonzero(~hit_mask)
            latency["cache"] = time.perf_counter() - t

        # stage 2.5: contextual entry routing (strategy layer) — the
        # router predicts each miss's cascade entry position from the
        # same embeddings the cache keys on; the governor supplies the
        # current (budget-adjusted) thresholds
        strat = self.strategy
        entries = probs = None
        thresholds = self.thresholds
        assign_mode = (strat is not None
                       and getattr(strat, "mode", "entry") == "assign")
        if strat is not None:
            thresholds = strat.thresholds(self.thresholds)
            if assign_mode and len(miss):
                # window assignment: chunk the misses into arrival
                # windows, score each as a batch, and solve entry tiers
                # under the window budget (repro.serving.assign)
                if emb is None:             # no cache stage ran: embed now
                    t = time.perf_counter()
                    emb = np.asarray(self._block(self.embed(tokens)))
                    latency["embed"] = time.perf_counter() - t
                t = time.perf_counter()
                asg = strat.assigner
                prices = self._tier_prices(tokens[miss])
                w = asg.cfg.window_size
                entries = np.concatenate([
                    asg.assign(emb[miss[i:i + w]], prices[i:i + w],
                               governor=strat.governor)["assignment"]
                    for i in range(0, len(miss), w)])
                latency["assign"] = time.perf_counter() - t
            elif getattr(strat, "router", None) is not None and len(miss):
                if emb is None:             # no cache stage ran: embed now
                    t = time.perf_counter()
                    emb = np.asarray(self._block(self.embed(tokens)))
                    latency["embed"] = time.perf_counter() - t
                t = time.perf_counter()
                entries, probs = strat.route(emb[miss])
                latency["route"] = time.perf_counter() - t

        # stages 2+3: adapted prompts + cascade over the misses
        t = time.perf_counter()
        tier_counts = [0] * len(self.tiers)
        res_ans = np.zeros(0, np.int32)
        ingress = None
        if len(miss):
            res = execute_cascade(self._cascade_tiers(clock, sleep),
                                  thresholds,
                                  self._pos_scorer, tokens[miss],
                                  batch_size=self.batch_size, entry=entries,
                                  compact=self.compact, retry=self.retry,
                                  breaker=self.breaker, clock=clock,
                                  sleep=sleep)
            res_ans = np.asarray(res["answers"])
            cost[miss] = res["cost"]
            stopped_at[miss] = res["stopped_at"]
            tier_counts = res["tier_counts"]
            if "resilience" in res:
                # surface the executor's retry/failover counters (incl.
                # backoff credited on terminally-failed chunks) the same
                # way the stream paths do; trips/recoveries only exist
                # with a breaker, but summary() reads them regardless
                ingress = {"request_latency": np.zeros(0),
                           "resilience": {"trips": 0, "recoveries": 0,
                                          **res["resilience"]}}
        latency["cascade"] = time.perf_counter() - t
        answers = _merge_answers(n, [(hit_idx, hit_ans), (miss, res_ans)])

        # write fresh answers back into the cache (int-keyed; skip others)
        if self.cache is not None and len(miss):
            t = time.perf_counter()
            self._cache_insert(emb[miss], res_ans, res["scores"])
            latency["insert"] = time.perf_counter() - t

        # stage 3.5: accuracy-guarantee shadow audit (separate meter)
        guar = getattr(strat, "guarantee", None) if strat is not None else None
        if guar is not None and len(miss):
            t = time.perf_counter()
            self._shadow_audit(tokens, miss, res_ans, stopped_at[miss],
                               emb, guar)
            latency["shadow"] = time.perf_counter() - t

        # feed the strategy: cache hits are zero-cost served queries,
        # misses carry entry/accept telemetry when the router routed them
        strategy_snap = None
        if strat is not None:
            strat.observe_batch(cost[hit_idx])
            if len(miss):
                strat.observe_batch(cost[miss], entries,
                                    stopped_at[miss], probs)
                if assign_mode:
                    # realized counterparts of the solver's predictions:
                    # per-query $ and acceptance at the assigned entry
                    strat.assigner.observe(
                        cost[miss], stopped_at[miss] == entries)
            rt = getattr(guar, "retrainer", None) if guar is not None else None
            if rt is not None and len(miss):
                if entries is not None and emb is not None:
                    # realized accepts at the routed entry — the
                    # predicted-vs-realized telemetry, consumed as
                    # labels (final position is supervised by shadow
                    # agreement only: its offline label was correctness,
                    # and entering there accepts unconditionally)
                    top = len(self.tiers) - 1
                    sub_stop = stopped_at[miss]
                    for i in range(len(miss)):
                        if int(entries[i]) != top:
                            rt.observe(emb[miss[i]], int(entries[i]),
                                       bool(sub_stop[i] == entries[i]))
                rt.maybe_step()
            strategy_snap = strat.snapshot(len(self.tiers))

        latency["total"] = time.perf_counter() - t0
        return ServeResult(
            answers=answers, cost=cost, stopped_at=stopped_at,
            tier_counts=list(tier_counts),
            tier_names=[s.name for s in self.tiers],
            cache_hits=hits, cache_misses=len(miss),
            prompt_tokens_saved=self._prompt_saved(tier_counts),
            baseline_cost=self._baseline_cost(tokens),
            latency=latency, ingress=ingress, strategy=strategy_snap)

    # -- continuous-batching entry points (ingress + sched subsystems) -----
    def _stream_backend(self, max_chunk, holdback, parallel, slo):
        """The stream path's executor: the parallel SLO-aware tier
        scheduler (default) or the serial continuous batcher
        (``parallel=False`` — the reference implementation the
        scheduler is benchmarked against). ``holdback`` and ``slo`` are
        mutually exclusive: an ``SLOConfig`` carries its own
        ``max_holdback_s``, so a separately-passed window would be
        silently dropped."""
        if holdback is not None and slo is not None:
            raise ValueError("pass either holdback= or slo= (SLOConfig "
                             "carries its own max_holdback_s), not both")
        if parallel:
            from repro.serving.sched import SLOConfig, TierScheduler
            if slo is None:
                slo = SLOConfig(max_holdback_s=0.02 if holdback is None
                                else holdback, speculate=self.speculate,
                                retry=self.retry, breaker=self.breaker)
            return TierScheduler(self, max_chunk=max_chunk, slo=slo)
        from repro.serving.ingress import ContinuousBatcher
        if slo is not None:
            raise ValueError("SLO config needs the parallel scheduler "
                             "(parallel=True)")
        if self.strategy is not None:
            raise ValueError("a contextual strategy runs on the parallel "
                             "scheduler (parallel=True); the serial "
                             "batcher is the fixed-cascade reference")
        return ContinuousBatcher(self, max_chunk=max_chunk,
                                 holdback=0.02 if holdback is None
                                 else holdback)

    def serve_stream(self, tokens: np.ndarray, arrivals=None, *,
                     max_chunk: int | None = None,
                     holdback: float | None = None,
                     parallel: bool = True, slo=None) -> ServeResult:
        """Replay an arrival trace through the streaming path: row i of
        ``tokens`` becomes visible at offset ``arrivals[i]`` seconds
        (all at t=0 when None). Cache lookup and prompt accounting run
        per-admission; answers come back in submission order. By default
        tiers decode concurrently under the SLO-aware scheduler
        (``repro.serving.sched``; pass ``slo=SLOConfig(...)`` for
        deadlines/backpressure); ``parallel=False`` selects the serial
        ``ContinuousBatcher``. For a fixed request set under greedy
        decoding both paths are bit-identical to ``serve``
        (tests/test_ingress.py, tests/test_sched.py)."""
        return self._stream_backend(max_chunk, holdback, parallel,
                                    slo).run_trace(tokens, arrivals)

    async def aserve(self, tokens: np.ndarray, arrivals=None, *,
                     max_chunk: int | None = None,
                     holdback: float | None = None,
                     parallel: bool = True, slo=None) -> ServeResult:
        """Async flavour of ``serve_stream`` — cooperates with other
        coroutines while idle. For live producer/consumer streams build
        an ``IngressQueue`` and drive ``TierScheduler.serve_async`` (or
        ``ContinuousBatcher.serve_async``) directly — per-request
        futures resolve as answers land."""
        from repro.serving.ingress import IngressQueue
        backend = self._stream_backend(max_chunk, holdback, parallel, slo)
        queue = IngressQueue()
        queue.submit_burst(tokens, arrivals)
        queue.close()
        return await backend.serve_async(queue)
