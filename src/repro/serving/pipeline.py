"""The unified FrugalGPT serving pipeline: all three cost-reduction
strategies composed on ONE batched request path (paper §3, Fig. 2).

A token batch flows through three stages:

  1. completion cache (§3.2, LLM approximation) — queries are embedded
     with the scorer's encoder (no extra model) and answered from the
     nearest-neighbour cache when similarity clears the threshold;
  2. prompt adaptation (§3.1) — every cache miss is billed against the
     *adapted* per-tier few-shot prefix (``PromptSpec``) instead of the
     full prompt, with exact ``ApiCost`` token accounting;
  3. LLM cascade (§3.3) — misses run tier-by-tier with compaction
     through the repo's single cascade executor
     (``repro.core.cascade.execute_cascade``); answer, cost and scorer
     calls are all chunked to ``batch_size``.

Fresh answers are inserted back into the cache, and every request batch
returns a ``ServeResult`` telemetry record: per-tier counts, cache hit
rate, per-stage latency, and cost against the always-top-tier baseline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.approx import CompletionCache
from repro.core.cascade import CascadeTier, execute_cascade
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec


@dataclasses.dataclass
class TierSpec:
    """One serving tier: a live model plus its economics.

    ``answer(tokens (b, L)) -> answers (b,)``; ``price`` is the exact
    3-term API cost model; ``prompt`` is the tier's adapted few-shot
    prefix (None = bill the full, unadapted prompt).
    """

    name: str
    answer: Callable
    price: ApiCost
    prompt: PromptSpec | None = None
    n_out: int = 1


@dataclasses.dataclass
class ServeResult:
    """Telemetry for one served batch."""

    answers: np.ndarray          # (n,) final answers
    cost: np.ndarray             # (n,) accounted USD per query
    stopped_at: np.ndarray       # (n,) cascade position; -1 = cache hit
    tier_counts: list            # queries reaching each tier (compaction)
    tier_names: list
    cache_hits: int
    cache_misses: int
    prompt_tokens_saved: int     # adapted vs full prompt, summed over calls
    baseline_cost: float         # top tier + full prompt for every query
    latency: dict                # per-stage seconds

    @property
    def n(self) -> int:
        return len(self.answers)

    @property
    def cache_hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0

    @property
    def savings_frac(self) -> float:
        if self.baseline_cost <= 0:
            return 0.0
        return 1.0 - float(self.cost.sum()) / self.baseline_cost

    def summary(self) -> str:
        lat = ", ".join(f"{k} {v * 1e3:.0f}ms" for k, v in
                        self.latency.items())
        tiers = ", ".join(f"{nm}: {c}" for nm, c in
                          zip(self.tier_names, self.tier_counts))
        return (
            f"served {self.n} queries | cache hit rate "
            f"{self.cache_hit_rate:.2f} ({self.cache_hits} hits) | "
            f"tier compaction [{tiers}] | prompt tokens saved "
            f"{self.prompt_tokens_saved} | cost ${self.cost.sum():.6f} vs "
            f"${self.baseline_cost:.6f} top-tier baseline "
            f"({100 * self.savings_frac:.0f}% saved) | {lat}")


@dataclasses.dataclass
class ServingPipeline:
    """Completion cache -> prompt adaptation -> LLM cascade, batched."""

    tiers: Sequence[TierSpec]
    thresholds: Sequence[float]          # len = len(tiers) - 1
    scorer: Callable                     # (tokens, answers) -> scores (n,)
    cache: CompletionCache | None = None
    embed: Callable | None = None        # tokens (n, L) -> embeddings (n, d)
    full_prompt_tokens: int = 0          # unadapted few-shot prefix length
    pad_token: int = 0
    batch_size: int = 256
    # economics of the marketplace's top tier, for the savings baseline —
    # the learned cascade may not end there (budget fallback), so this
    # must not default to whatever tier happens to be last in the cascade
    baseline_price: ApiCost | None = None
    baseline_n_out: int = 1

    def __post_init__(self):
        if self.cache is not None and self.embed is None:
            raise ValueError("a completion cache needs an embed function "
                             "(reuse the scorer encoder, see builder)")

    # -- stage 2: exact per-tier cost with the adapted prompt --------------
    def _query_tokens(self, tokens: np.ndarray) -> np.ndarray:
        return np.asarray((tokens != self.pad_token).sum(-1), np.int64)

    def _tier_cost(self, spec: TierSpec, tokens: np.ndarray) -> np.ndarray:
        prefix = (spec.prompt.n_tokens if spec.prompt is not None
                  else self.full_prompt_tokens)
        n_q = self._query_tokens(tokens)
        n_out = np.full_like(n_q, spec.n_out)
        return np.asarray(spec.price.query_cost(n_q + prefix, n_out),
                          np.float64)

    def _baseline_cost(self, tokens: np.ndarray) -> float:
        """Everything to the marketplace top tier, full prompt, no cache."""
        if self.baseline_price is not None:
            price, n_out = self.baseline_price, self.baseline_n_out
        else:
            price, n_out = self.tiers[-1].price, self.tiers[-1].n_out
        n_q = self._query_tokens(tokens)
        return float(np.asarray(price.query_cost(
            n_q + self.full_prompt_tokens,
            np.full_like(n_q, n_out))).sum())

    def serve(self, tokens: np.ndarray) -> ServeResult:
        t0 = time.time()
        n = tokens.shape[0]
        answers = np.zeros(n, np.int32)
        cost = np.zeros(n, np.float64)
        stopped_at = np.full(n, -1, np.int32)
        latency: dict = {}

        # stage 1: completion cache
        hits = 0
        emb = None
        miss = np.arange(n)
        if self.cache is not None:
            t = time.time()
            emb = self.embed(tokens)
            latency["embed"] = time.time() - t
            t = time.time()
            hit_mask, cached = self.cache.lookup(emb)
            answers[hit_mask] = cached[hit_mask]
            hits = int(hit_mask.sum())
            miss = np.flatnonzero(~hit_mask)
            latency["cache"] = time.time() - t

        # stages 2+3: adapted prompts + cascade over the misses
        t = time.time()
        tier_counts = [0] * len(self.tiers)
        prompt_saved = 0
        if len(miss):
            ct = [CascadeTier(
                      s.name,
                      lambda q, s=s: (s.answer(q), self._tier_cost(s, q)))
                  for s in self.tiers]
            res = execute_cascade(ct, self.thresholds,
                                  lambda q, a, _j: self.scorer(q, a),
                                  tokens[miss], batch_size=self.batch_size)
            answers[miss] = np.asarray(res["answers"]).astype(np.int32)
            cost[miss] = res["cost"]
            stopped_at[miss] = res["stopped_at"]
            tier_counts = res["tier_counts"]
            for spec, c in zip(self.tiers, tier_counts):
                if spec.prompt is not None:
                    prompt_saved += c * (self.full_prompt_tokens
                                         - spec.prompt.n_tokens)
        latency["cascade"] = time.time() - t

        # write fresh answers back into the cache
        if self.cache is not None and len(miss):
            t = time.time()
            self.cache.insert(emb[miss], answers[miss])
            latency["insert"] = time.time() - t

        latency["total"] = time.time() - t0
        return ServeResult(
            answers=answers, cost=cost, stopped_at=stopped_at,
            tier_counts=list(tier_counts),
            tier_names=[s.name for s in self.tiers],
            cache_hits=hits, cache_misses=len(miss),
            prompt_tokens_saved=int(prompt_saved),
            baseline_cost=self._baseline_cost(tokens),
            latency=latency)
