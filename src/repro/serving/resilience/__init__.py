"""``repro.serving.resilience`` — fault-tolerant cascade serving.

FrugalGPT's cascade runs over commercial-API-style tiers that rate-
limit, time out, and throw transient errors. This package turns tier
failure from a fatal event into a *routing signal* — the cascade
structure already provides the failover path (escalate past the sick
tier):

``faults``   deterministic, seeded fault injection: ``FaultSpec`` (a
             reproducible schedule of transient errors, timeouts,
             latency spikes, rate-limit windows, sustained outages) and
             ``FaultyTier`` (wraps any tier; injectable clock/sleep;
             ``wrap_tiers`` leaves disabled tiers untouched — zero
             overhead off). ``TierFault`` and its subclasses are the
             only exceptions the resilience machinery absorbs.
``retry``    per-tier ``RetryPolicy``: bounded attempts, exponential
             backoff with deterministic jitter, deadline awareness
             (never retry past the request's SLO deadline), and the
             ``"success"``/``"all_attempts"`` cost-accounting modes;
             ``invoke_with_retry`` is the shared execution helper.
``breaker``  per-tier circuit breakers (closed/open/half-open over a
             sliding failure-rate window; explicit ``now`` everywhere,
             so fake clocks drive them) feeding a ``TierHealth``
             registry — the scheduler's availability map.

Failover itself lives at the call sites: ``core.cascade.
execute_cascade(retry=, breaker=)`` and the parallel scheduler
(``SLOConfig.retry``/``SLOConfig.breaker``) route rows past open or
exhausted tiers (forward-only escalation), fall back to the best-scoring
earlier answer on last-tier failure (or an accounted shed), and report
everything under ``ingress["resilience"]``.
"""
from repro.serving.resilience.breaker import (  # noqa: F401
    BREAKER_STATES,
    BreakerConfig,
    CircuitBreaker,
    TierHealth,
)
from repro.serving.resilience.faults import (  # noqa: F401
    FAULT_KINDS,
    FaultSpec,
    FaultyTier,
    RateLimitError,
    TierFault,
    TierTimeout,
    TransientError,
    VirtualClock,
    wrap_tiers,
)
from repro.serving.resilience.retry import (  # noqa: F401
    RETRY_ACCOUNTING,
    RetryPolicy,
    invoke_with_retry,
)
