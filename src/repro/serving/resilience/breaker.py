"""Per-tier circuit breakers feeding a ``TierHealth`` registry.

The classic three-state machine over a sliding failure-rate window:

  * **closed**    — traffic flows; outcomes land in a bounded window of
    the most recent results. When at least ``min_samples`` have been
    seen and the window's failure fraction reaches ``fail_rate``, the
    breaker **trips** to open.
  * **open**      — the tier is presumed down; ``available`` is False,
    so the scheduler routes rows *past* it (failover escalation)
    instead of burning retries. After ``cooldown_s`` the breaker moves
    to half-open.
  * **half-open** — probe traffic is metered by a token bucket:
    entering half-open grants ``probe_bucket`` tokens, each recorded
    probe outcome consumes one, and (optionally) tokens refill at
    ``probe_refill_per_s`` while half-open — so a large fleet cannot
    thundering-herd a barely-recovered tier the moment its cooldown
    expires. ``recovery_successes`` successful probes close the breaker
    (a **recovery**, window reset); any probe failure re-trips it for
    another cooldown. The defaults (bucket 1, one success, no refill)
    reduce to the classic single-probe half-open.

Every method takes an explicit ``now`` — the breaker holds no clock, so
fake-clock tests (and the scheduler's injected stream clock) drive state
transitions without wall time. Each breaker is only ever touched by its
tier's worker thread (the scheduler's one-worker-per-tier contract), so
no internal locking is needed; the registry's cross-tier counters are
summed at snapshot time.
"""
from __future__ import annotations

import collections
import dataclasses

BREAKER_STATES = ("closed", "open", "half_open")


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Dials for one tier's circuit breaker."""

    #: sliding outcome window (most recent invokes)
    window: int = 16
    #: trip when failures/window >= this, once min_samples seen
    fail_rate: float = 0.5
    min_samples: int = 4
    #: seconds open before allowing a half-open probe
    cooldown_s: float = 0.5
    #: half-open probe tokens granted when the cooldown expires (bucket
    #: size 1 = the classic single-probe half-open)
    probe_bucket: int = 1
    #: token refill rate while half-open (0 = burst only)
    probe_refill_per_s: float = 0.0
    #: successful probes required to close (ramped recovery)
    recovery_successes: int = 1

    def __post_init__(self):
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if not 0.0 < self.fail_rate <= 1.0:
            raise ValueError("fail_rate must be in (0, 1]")
        if self.min_samples < 1 or self.min_samples > self.window:
            raise ValueError("min_samples must be in [1, window]")
        if self.cooldown_s < 0:
            raise ValueError("cooldown_s must be >= 0")
        if self.probe_bucket < 1:
            raise ValueError("probe_bucket must be >= 1")
        if self.probe_refill_per_s < 0:
            raise ValueError("probe_refill_per_s must be >= 0")
        if self.recovery_successes < 1:
            raise ValueError("recovery_successes must be >= 1")
        if (self.probe_refill_per_s == 0
                and self.recovery_successes > self.probe_bucket):
            raise ValueError(
                "recovery_successes > probe_bucket with no refill can "
                "never close the breaker; raise probe_bucket or set "
                "probe_refill_per_s > 0")


class CircuitBreaker:
    """One tier's breaker (see module docstring for the state machine)."""

    def __init__(self, cfg: BreakerConfig):
        self.cfg = cfg
        self._state = "closed"
        self._outcomes = collections.deque(maxlen=cfg.window)
        self._opened_at = 0.0
        self._tokens = 0.0          # half-open probe bucket
        self._refill_at = 0.0       # last token-refill timestamp
        self._probe_oks = 0         # successes into the current ramp
        self.trips = 0
        self.recoveries = 0

    def state(self, now: float) -> str:
        """Current state, applying the open -> half-open cooldown edge
        (which grants the probe bucket's burst and arms the ramp)."""
        if (self._state == "open"
                and now - self._opened_at >= self.cfg.cooldown_s):
            self._state = "half_open"
            self._tokens = float(self.cfg.probe_bucket)
            self._refill_at = now
            self._probe_oks = 0
        return self._state

    def _refill(self, now: float) -> None:
        """Advance the half-open token bucket to ``now``."""
        if self.cfg.probe_refill_per_s > 0:
            dt = max(0.0, now - self._refill_at)
            self._tokens = min(float(self.cfg.probe_bucket),
                               self._tokens + dt
                               * self.cfg.probe_refill_per_s)
        self._refill_at = now

    def available(self, now: float) -> bool:
        """May traffic be sent to this tier right now? False while open
        and still cooling down, and while half-open with the probe
        bucket drained (the ramp: a recovering tier sees at most
        ``probe_bucket`` probes per refill interval, not the fleet)."""
        state = self.state(now)
        if state == "half_open":
            self._refill(now)
            return self._tokens >= 1.0
        return state != "open"

    def record(self, ok: bool, now: float) -> bool:
        """Record one invoke outcome. Returns True when this outcome
        *tripped* the breaker (closed/half-open -> open) — the caller's
        hook for cancelling in-flight speculation against the tier."""
        state = self.state(now)
        if state == "half_open":
            self._refill(now)
            self._tokens = max(0.0, self._tokens - 1.0)  # probe spent
            if ok:
                self._probe_oks += 1
                if self._probe_oks >= self.cfg.recovery_successes:
                    self._state = "closed"  # ramp complete: recover
                    self._outcomes.clear()
                    self.recoveries += 1
                return False
            self._state = "open"        # probe failed: re-trip
            self._opened_at = now
            self.trips += 1
            return True
        self._outcomes.append(bool(ok))
        if state == "closed" and len(self._outcomes) >= self.cfg.min_samples:
            fails = sum(1 for o in self._outcomes if not o)
            if fails / len(self._outcomes) >= self.cfg.fail_rate:
                self._state = "open"
                self._opened_at = now
                self._outcomes.clear()
                self.trips += 1
                return True
        return False

    def snapshot(self, now: float) -> dict:
        return {"state": self.state(now), "trips": self.trips,
                "recoveries": self.recoveries,
                "window_fails": sum(1 for o in self._outcomes if not o),
                "window_n": len(self._outcomes),
                "probe_tokens": self._tokens,
                "probe_oks": self._probe_oks}


class TierHealth:
    """Registry of per-tier breakers — the scheduler's availability map."""

    def __init__(self, n_tiers: int, cfg: BreakerConfig):
        self.cfg = cfg
        self.breakers = [CircuitBreaker(cfg) for _ in range(n_tiers)]

    def available(self, j: int, now: float) -> bool:
        return self.breakers[j].available(now)

    def record(self, j: int, ok: bool, now: float) -> bool:
        """Record tier j's invoke outcome; True when it tripped."""
        return self.breakers[j].record(ok, now)

    @property
    def trips(self) -> int:
        return sum(b.trips for b in self.breakers)

    @property
    def recoveries(self) -> int:
        return sum(b.recoveries for b in self.breakers)

    def snapshot(self, now: float) -> list[dict]:
        return [b.snapshot(now) for b in self.breakers]
