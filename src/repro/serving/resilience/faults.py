"""Deterministic, seeded fault injection for cascade tiers.

FrugalGPT cascades run over *commercial LLM APIs* — services that
rate-limit, time out, and throw transient 5xx errors. This module makes
those failure modes reproducible: a ``FaultSpec`` describes a seeded
schedule of faults and ``FaultyTier`` wraps any ``CascadeTier``-shaped
object (``.name`` + ``.invoke``) so its invokes raise (or stall) exactly
where the schedule says, run after run.

Determinism contract: each wrapper owns one ``numpy`` generator seeded
from its spec, and draws exactly one uniform per invoke — so the fault
sequence is a pure function of ``(seed, invoke index)``. Tier backends
are only ever entered by one thread at a time (the scheduler's
one-worker-per-tier contract), so the invoke index is well defined.
Window faults (rate-limit windows, sustained outages) are keyed off an
*injectable clock* instead of the draw, so fake-clock tests can walk a
tier into and out of an outage without wall time passing.

Zero overhead when disabled: ``wrap_tiers`` returns the original tier
object untouched for a ``None``/inactive spec — the disabled path has no
wrapper at all, which is what keeps it trivially bit-identical.

The exception taxonomy mirrors what API clients actually see:

  ``TransientError``  — retryable 5xx-style failure (also used for
                        sustained outage windows);
  ``TierTimeout``     — the call gave up waiting;
  ``RateLimitError``  — 429 inside a configured rate-limit window.

All three subclass ``TierFault`` — the *only* exception type the
retry/failover machinery treats as a routing signal. Anything else a
tier raises is still a programming error and still surfaces.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


class VirtualClock:
    """Deterministic virtual time: ``clock()`` reads it, ``sleep(s)``
    advances it. Inject the pair into the batch executor
    (``pipeline.serve(clock=vc, sleep=vc.sleep)``) or the fault wrappers
    so retry backoff and injected latency spikes advance *virtual* time
    — a resilience bench with seconds of accumulated backoff finishes in
    milliseconds, with identical telemetry (backoff is credited from the
    slept amounts, which are the same numbers either way)."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def sleep(self, s: float) -> None:
        self.t += max(0.0, float(s))

    advance = sleep


class TierFault(RuntimeError):
    """A tier invoke failed in a way the resilience layer may absorb."""


class TransientError(TierFault):
    """Retryable transient failure (injected 5xx / sustained outage)."""


class TierTimeout(TierFault):
    """The tier call exceeded its time budget."""


class RateLimitError(TierFault):
    """The tier is rate-limiting (429) for a window."""


def _window(w):
    if w is None:
        return None
    lo, hi = float(w[0]), float(w[1])
    if not lo < hi:
        raise ValueError(f"fault window needs start < end, got ({lo}, {hi})")
    return (lo, hi)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded fault schedule for one tier.

    Rates are per-invoke probabilities drawn from one deterministic
    generator (at most one rate fault fires per invoke; error wins over
    timeout wins over spike). Windows are ``(start_s, end_s)`` on the
    stream clock and fire regardless of the draw.
    """

    #: P(TransientError) per invoke
    error_rate: float = 0.0
    #: P(TierTimeout) per invoke
    timeout_rate: float = 0.0
    #: P(latency spike) per invoke — the invoke still succeeds, after
    #: ``spike_s`` extra seconds
    spike_rate: float = 0.0
    spike_s: float = 0.05
    #: RateLimitError window (start_s, end_s) on the stream clock
    rate_limit: tuple | None = None
    #: sustained-outage window (start_s, end_s): every invoke inside it
    #: raises TransientError — the breaker-trip scenario
    outage: tuple | None = None
    #: cap on total injected faults (None = unlimited); spikes count
    max_faults: int | None = None
    seed: int = 0
    #: correlated-failure group: tiers whose specs share a group name
    #: share ONE fault schedule (same seed, so draw-based faults fire on
    #: the same invoke indices; window faults already share the clock).
    #: Models a common upstream dependency — one provider backing
    #: several cascade tiers goes down, they all go down. None = the
    #: default independent-failures model.
    group: str | None = None

    def __post_init__(self):
        for name in ("error_rate", "timeout_rate", "spike_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.error_rate + self.timeout_rate + self.spike_rate > 1.0:
            raise ValueError("error_rate + timeout_rate + spike_rate "
                             "must be <= 1 (one draw decides the invoke)")
        if self.spike_s < 0:
            raise ValueError("spike_s must be >= 0")
        if self.max_faults is not None and self.max_faults < 0:
            raise ValueError("max_faults must be >= 0")
        object.__setattr__(self, "rate_limit", _window(self.rate_limit))
        object.__setattr__(self, "outage", _window(self.outage))

    @property
    def enabled(self) -> bool:
        return (self.error_rate > 0 or self.timeout_rate > 0
                or self.spike_rate > 0 or self.rate_limit is not None
                or self.outage is not None)

    @staticmethod
    def parse(spec: str) -> "FaultSpec":
        """Parse the launcher's ``--faults`` grammar: comma-separated
        ``key=value`` pairs — ``error``/``timeout`` (rates),
        ``spike=RATE@SECONDS``, ``rlim=START:END``, ``outage=START:END``,
        ``max=N``, ``seed=N``. E.g. ``error=0.05,outage=0.5:2.0,seed=1``.
        """
        kw: dict = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "=" not in part:
                raise ValueError(f"--faults entry {part!r} is not key=value")
            k, v = part.split("=", 1)
            k = k.strip()
            if k == "error":
                kw["error_rate"] = float(v)
            elif k == "timeout":
                kw["timeout_rate"] = float(v)
            elif k == "spike":
                rate, _, secs = v.partition("@")
                kw["spike_rate"] = float(rate)
                if secs:
                    kw["spike_s"] = float(secs)
            elif k in ("rlim", "outage"):
                lo, _, hi = v.partition(":")
                kw["rate_limit" if k == "rlim" else k] = (float(lo),
                                                          float(hi))
            elif k == "max":
                kw["max_faults"] = int(v)
            elif k == "seed":
                kw["seed"] = int(v)
            elif k == "group":
                kw["group"] = v.strip()
            else:
                raise ValueError(f"unknown --faults key {k!r}")
        return FaultSpec(**kw)


#: fault kinds counted by FaultyTier.injected
FAULT_KINDS = ("error", "timeout", "spike", "rate_limit", "outage")


class FaultyTier:
    """A ``CascadeTier`` wrapped with a ``FaultSpec`` schedule.

    Duck-typed to the tier contract (``.name``, ``.invoke``), so every
    call site — ``tier_step``, the scheduler workers, speculation —
    takes it unchanged. ``clock``/``sleep`` are injectable: the stream
    scheduler wires its own clock in at start (fake clocks included),
    and tests inject a recording ``sleep`` so latency spikes advance
    virtual time instead of stalling pytest.
    """

    def __init__(self, tier, spec: FaultSpec, clock=None, sleep=None):
        self.name = tier.name
        self.inner = tier
        self.spec = spec
        self.clock = clock              # None until a driver wires one in
        self.sleep = sleep if sleep is not None else time.sleep
        self._rng = np.random.default_rng(spec.seed)
        self.calls = 0
        self.injected = dict.fromkeys(FAULT_KINDS, 0)

    def _now(self) -> float:
        return float(self.clock()) if self.clock is not None else 0.0

    def _in(self, w, now: float) -> bool:
        return w is not None and w[0] <= now < w[1]

    def _fire(self, kind: str, exc: TierFault):
        self.injected[kind] += 1
        raise exc

    def invoke(self, chunk):
        sp = self.spec
        self.calls += 1
        u = self._rng.random()          # always drawn: the fault sequence
        now = self._now()               # is a function of (seed, call #)
        if (sp.max_faults is None
                or sum(self.injected.values()) < sp.max_faults):
            if self._in(sp.outage, now):
                self._fire("outage", TransientError(
                    f"{self.name}: injected outage at t={now:.3f}s"))
            if self._in(sp.rate_limit, now):
                self._fire("rate_limit", RateLimitError(
                    f"{self.name}: injected rate limit at t={now:.3f}s"))
            if u < sp.error_rate:
                self._fire("error", TransientError(
                    f"{self.name}: injected transient error "
                    f"(call {self.calls})"))
            if u < sp.error_rate + sp.timeout_rate:
                self._fire("timeout", TierTimeout(
                    f"{self.name}: injected timeout (call {self.calls})"))
            if u < sp.error_rate + sp.timeout_rate + sp.spike_rate:
                self.injected["spike"] += 1
                self.sleep(sp.spike_s)
        return self.inner.invoke(chunk)


def wrap_tiers(tiers, specs, clock=None, sleep=None) -> list:
    """Wrap each tier with its (index-aligned) spec; ``None``/inactive
    specs return the original tier object — no wrapper, no overhead.
    ``specs`` may also be a single ``FaultSpec`` applied to every tier
    (each wrapper still draws from its own per-tier generator, offset by
    the tier index so tiers don't fault in lockstep). A spec with a
    ``group`` opts out of that decorrelation: a grouped broadcast
    replicates the seed verbatim, and grouped entries of a per-tier list
    adopt the group's first member's seed — either way the group's tiers
    share one draw sequence and fault together (the shared-upstream
    outage the breaker fleet has to survive as a fleet)."""
    if specs is None:
        return list(tiers)
    if isinstance(specs, FaultSpec):
        specs = [specs if specs.group is not None
                 else dataclasses.replace(specs, seed=specs.seed + 7919 * j)
                 for j in range(len(tiers))]
    else:
        group_seed: dict = {}
        specs = [s if s is None or s.group is None
                 else dataclasses.replace(
                     s, seed=group_seed.setdefault(s.group, s.seed))
                 for s in specs]
    if len(specs) != len(tiers):
        raise ValueError(f"{len(specs)} fault specs for {len(tiers)} tiers")
    return [t if s is None or not s.enabled
            else FaultyTier(t, s, clock=clock, sleep=sleep)
            for t, s in zip(tiers, specs)]
