"""Per-tier retry with bounded attempts, exponential backoff,
deterministic jitter, and deadline awareness.

A ``RetryPolicy`` is a frozen value object; ``invoke_with_retry`` is the
one execution helper both cascade paths share (the offline executor and
the parallel scheduler). Three properties the tests pin down:

  * **deterministic jitter** — the jitter multiplier is drawn from a
    generator seeded by ``(seed, token, attempt)``, so a retried chunk
    backs off by the exact same amounts run after run (``token`` is the
    caller's stable identity, e.g. the tier index);
  * **deadline awareness** — a retry is never issued when
    ``now + backoff + predicted_s`` already overshoots the request's SLO
    deadline: failing fast into failover beats answering late;
  * **accounting modes** — only ``TierFault`` attempts are retried, and
    failed attempts return no cost, so what retries *charge* is a
    policy: ``"success"`` bills only the attempt that answered (the
    provider refunded the 5xx), ``"all_attempts"`` bills every attempt
    at the same per-row price (the provider bills timeouts too) by
    scaling the successful cost by the attempt count.

Clocks and sleeps are injected by the caller: the scheduler passes its
stream clock (fake clocks included) and a no-op sleep when time is
virtual, so retry tests never wall-sleep (tier-1 discipline from PR 5).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.resilience.faults import TierFault

#: what retried invokes charge: only the successful attempt, or every
#: attempt at the same per-row price
RETRY_ACCOUNTING = ("success", "all_attempts")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded, deadline-aware retry for one tier's invokes."""

    #: total attempts including the first (1 = no retry)
    max_attempts: int = 3
    #: backoff before retry k (0-indexed) is ``backoff_s * mult**k``,
    #: capped at ``max_backoff_s``, jittered by ``±jitter_frac``
    backoff_s: float = 0.02
    backoff_mult: float = 2.0
    max_backoff_s: float = 1.0
    jitter_frac: float = 0.25
    accounting: str = "success"
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff_s and max_backoff_s must be >= 0")
        if self.backoff_mult < 1.0:
            raise ValueError("backoff_mult must be >= 1")
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ValueError("jitter_frac must be in [0, 1)")
        if self.accounting not in RETRY_ACCOUNTING:
            raise ValueError(f"unknown accounting {self.accounting!r}; "
                             f"expected one of {RETRY_ACCOUNTING}")

    def backoff(self, attempt: int, token: int = 0) -> float:
        """Seconds to wait before retry ``attempt`` (0-indexed), with
        deterministic jitter keyed by ``(seed, token, attempt)``."""
        base = min(self.backoff_s * self.backoff_mult ** attempt,
                   self.max_backoff_s)
        if self.jitter_frac == 0.0:
            return base
        u = np.random.default_rng([self.seed, token, attempt]).random()
        return base * (1.0 + self.jitter_frac * (2.0 * u - 1.0))

    def may_retry(self, attempt: int, *, now: float,
                  deadline: float | None, predicted_s: float = 0.0,
                  token: int = 0) -> bool:
        """May attempt ``attempt`` (0-indexed, just failed) be retried?
        Bounded by ``max_attempts``, and never past the deadline: the
        retry only makes sense if backoff + the tier's predicted service
        time still lands before it."""
        if attempt + 1 >= self.max_attempts:
            return False
        if deadline is None:
            return True
        return now + self.backoff(attempt, token) + predicted_s <= deadline


def invoke_with_retry(tier, chunk, policy: RetryPolicy, *, clock, sleep,
                      deadline: float | None = None,
                      predicted_s: float = 0.0, token: int = 0,
                      on_attempt_fail=None, on_backoff=None):
    """Run ``tier.invoke(chunk)`` under ``policy``.

    Returns ``(answers, costs, attempts, backoff_total_s)``; re-raises
    the last ``TierFault`` once attempts are exhausted or the deadline
    forbids another try. Only ``TierFault`` is retried — anything else
    is a programming error and propagates immediately. ``costs`` come
    back scaled by the attempt count under ``"all_attempts"``
    accounting. ``on_attempt_fail(attempt, exc)`` (optional) observes
    each failed attempt — the circuit breaker's failure-rate signal.
    ``on_backoff(wait_s)`` (optional) observes each backoff as it is
    slept — unlike the returned total, it also fires on the attempts
    *before* a terminal failure, so telemetry can credit the seconds a
    chunk spent backing off even when every retry was wasted.
    """
    attempt = 0
    backoff_total = 0.0
    while True:
        try:
            a, c = tier.invoke(chunk)
        except TierFault as e:
            if on_attempt_fail is not None:
                on_attempt_fail(attempt, e)
            if not policy.may_retry(attempt, now=clock(), deadline=deadline,
                                    predicted_s=predicted_s, token=token):
                raise
            wait = policy.backoff(attempt, token)
            backoff_total += wait
            sleep(wait)
            if on_backoff is not None:
                on_backoff(wait)
            attempt += 1
            continue
        if attempt and policy.accounting == "all_attempts":
            c = np.asarray(c, np.float64) * (attempt + 1)
        return a, c, attempt + 1, backoff_total
