"""Async ingress with continuous batching in front of the unified
pipeline (ROADMAP: "async request streams").

``ServingPipeline.serve`` is batch-at-a-time: the whole request set
arrives at once, runs stage by stage, and a tier sits idle while earlier
chunks decode. A real deployment sees a *stream* — requests arrive
individually or in small bursts, each with its own arrival time — and
the serving layer only pays off (paper §3.3) when tiers stay saturated.

This module closes that gap:

  * ``RequestState``  — one in-flight request: tokens, arrival time,
    the cascade position it is waiting on, accumulated cost, and
    per-request telemetry (queue wait, end-to-end latency, chunk count).
  * ``IngressQueue``  — arrival-ordered admission queue. Producers
    ``submit`` requests (optionally with an ``asyncio`` future that
    resolves when the request finishes); the batcher pops whatever has
    arrived by "now".
  * ``ContinuousBatcher`` — the admission loop. Each tick it (a) admits
    newly-arrived requests: cache lookup (per-admission embed + nearest
    neighbour) resolves hits immediately, misses enter tier 0's wait
    queue; (b) packs up to ``max_chunk`` waiting requests of ONE tier
    into the next chunk and runs it through ``repro.core.cascade.
    tier_step`` — the same compaction step the offline executor uses.
    New arrivals land in wait queues while earlier chunks are decoding,
    so a tier's next chunk is packed from everything waiting on it, not
    just the survivors of one closed batch.

Scheduling policy (classic continuous batching): a tier is dispatched
when its queue can fill a chunk, when its head-of-line waiter has aged
past the ``holdback`` window (so partial chunks still ship under light
load), or unconditionally once the stream is draining (queue closed,
nothing left to arrive). Among dispatchable tiers, overdue heads win
(oldest first), then the fullest queue — half-empty chunks cost the
same padded-bucket compute as full ones, so occupancy IS throughput.
Within a tier, requests are served FIFO. Chunks reuse the bucketed
``GenerationEngine`` shapes, so mixed-size chunks stay O(log) compiles.

``ContinuousBatcher`` dispatches one chunk at a time on ONE thread: it
is the serial reference implementation (and benchmark baseline) for the
SLO-aware parallel scheduler in ``repro.serving.sched``, which runs the
same admission stages and the same ``tier_step`` with one worker per
tier, deadline-driven holdback, and bounded-queue backpressure.
``serve_stream``/``aserve`` default to the parallel scheduler;
``parallel=False`` selects this batcher.

Equivalence guarantee (tested in tests/test_ingress.py): for a fixed
request set under greedy decoding — row-wise tier ``answer``/``scorer``
callables, which all repo tiers are — the continuous path returns
bit-identical answers and costs to ``ServingPipeline.serve``. Per-tier
costs are row-wise ``ApiCost`` terms and per-request cost is summed in
ascending tier order on float64 in both paths. The one deliberate
divergence: a duplicate query that *arrives after* its twin completes
hits the completion cache here, where ``serve`` (which looks up the
whole batch upfront) would miss — strictly fewer tier calls, never a
different answer for non-duplicates.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import heapq
import time
from typing import Iterator, Sequence

import numpy as np

from repro.core.cascade import tier_step


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """n arrival offsets (seconds) of a Poisson process at ``rate``/s —
    the shared trace generator for the stream CLI, example and bench."""
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0 requests/s, got {rate}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def pad_pow2_rows(toks: np.ndarray) -> tuple[np.ndarray, int]:
    """Pad a burst/chunk to the next power-of-two row count by
    replicating the last row. Streams produce arbitrary batch sizes;
    jitted embed/scorer callables would otherwise recompile per
    distinct size, charging multi-second XLA compiles to per-request
    latency mid-stream. Row-wise callables make the padding exact —
    the filler rows are sliced off every output. Returns
    ``(padded, original_row_count)``."""
    b = len(toks)
    b_pad = 1
    while b_pad < b:
        b_pad *= 2
    if b_pad == b:
        return toks, b
    return np.concatenate([toks, np.repeat(toks[-1:], b_pad - b, 0)]), b


def stage1_lookup(pipeline, reqs, cache_lock=None, need_emb=False):
    """The admission stage both stream backends share: stack the burst's
    token rows, embed them (pow2-padded), and probe the completion
    cache. Returns ``(hit_mask, cached_answers, emb, embed_s, cache_s)``
    — ``emb`` is None when the pipeline has no cache, unless
    ``need_emb`` forces the embed anyway (the contextual router routes
    on embeddings even for cache-less pipelines). ``cache_lock``
    serializes the lookup against concurrent inserts (the parallel
    scheduler's workers); the embed call itself needs no lock (only the
    admission thread runs it)."""
    toks = np.stack([r.tokens for r in reqs])
    hit_mask = np.zeros(len(reqs), bool)
    cached = emb = None
    embed_s = cache_s = 0.0
    if pipeline.cache is not None or need_emb:
        padded, b = pad_pow2_rows(toks)
        t0 = time.perf_counter()
        emb = np.asarray(pipeline._block(pipeline.embed(padded)))[:b]
        embed_s = time.perf_counter() - t0
    if pipeline.cache is not None:
        t0 = time.perf_counter()
        pipeline._cache_refresh()   # governor-owned similarity threshold
        if cache_lock is not None:
            with cache_lock:
                hit_mask, cached = pipeline.cache.lookup(emb)
        else:
            hit_mask, cached = pipeline.cache.lookup(emb)
        cache_s = time.perf_counter() - t0
    return hit_mask, cached, emb, embed_s, cache_s


def fold_stream_result(pipeline, requests: Sequence[RequestState], *,
                       tier_counts: Sequence[int], cache_hits: int,
                       cache_misses: int, latency: dict, total_s: float,
                       ingress: dict, strategy: dict | None = None):
    """Fold a finished stream into a ``ServeResult`` bit-compatible with
    ``ServingPipeline.serve`` (answers/cost/stopped_at indexed by
    submission order) — shared by the serial ``ContinuousBatcher`` and
    the parallel ``repro.serving.sched.TierScheduler``. Requests shed by
    an overload policy appear with ``answer None`` / ``stopped_at -2`` /
    zero cost."""
    from repro.serving.pipeline import ServeResult, _merge_answers

    reqs = sorted(requests, key=lambda r: r.rid)
    undone = [r for r in reqs if not r.done]
    if undone:
        raise RuntimeError(f"{len(undone)} requests still in flight")
    n = len(reqs)
    cost = np.asarray([r.cost for r in reqs], np.float64)
    stopped = np.asarray([r.stopped_at for r in reqs], np.int32)
    vals = np.empty(n, dtype=object)          # keeps array answers intact
    for i, r in enumerate(reqs):
        vals[i] = r.answer
    answers = _merge_answers(n, [(np.arange(n), vals)])
    toks = (np.stack([r.tokens for r in reqs]) if n
            else np.zeros((0, 1), np.int32))
    lat = dict(latency)
    lat["total"] = total_s
    return ServeResult(
        answers=answers, cost=cost, stopped_at=stopped,
        tier_counts=list(tier_counts),
        tier_names=[s.name for s in pipeline.tiers],
        cache_hits=cache_hits, cache_misses=cache_misses,
        prompt_tokens_saved=pipeline._prompt_saved(tier_counts),
        baseline_cost=pipeline._baseline_cost(toks) if n else 0.0,
        latency=lat, ingress=ingress, strategy=strategy)


@dataclasses.dataclass
class RequestState:
    """One in-flight request and its telemetry."""

    rid: int                        # submission index == result row
    tokens: np.ndarray              # (L,) token row
    arrival: float = 0.0            # seconds since stream start
    tier_pos: int = -1              # cascade position waited on; -1 = none
    answer: object = None
    cost: float = 0.0
    stopped_at: int = -1            # cascade position; -1 = cache hit
    score: float = float("nan")     # accept-time reliability score
    deadline: float | None = None   # absolute SLO deadline (stream clock)
    shed: bool = False              # dropped by the overload policy
    degraded: bool = False          # overload-degraded (reduced entry bar)
    entry: int = 0                  # cascade entry position (router)
    pred_accept: float | None = None  # router's accept prob at the entry
    probs: np.ndarray | None = None   # (m,) per-tier accept probabilities
                                      # (router) — speculation candidates
    t_admitted: float | None = None
    t_done: float | None = None
    t_enqueued: float = 0.0         # entered the current tier's wait queue
    n_chunks: int = 0               # tier chunks this request rode in
    emb: np.ndarray | None = None   # cache-stage embedding (misses only)
    future: asyncio.Future | None = None
    # failover fallback (repro.serving.resilience, populated only when
    # the scheduler runs resilient): the best-scoring answer an earlier
    # tier produced but the scorer rejected — served as a degraded
    # answer when every remaining tier is down
    fb_answer: object = None
    fb_score: float = float("-inf")
    fb_tier: int = -1
    # shadow audit (repro.serving.guarantee): a clone re-running a
    # served query on the reference tier. Shadow rows never resolve a
    # future, never count in tier_counts/fold_stream_result, and their
    # cost lands on the controller's shadow meter
    shadow: bool = False
    orig_answer: object = None      # the served answer being audited
    orig_stop: int = -1             # position the served answer came from

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def latency(self) -> float | None:
        """End-to-end: arrival -> answer."""
        return None if self.t_done is None else self.t_done - self.arrival

    @property
    def queue_wait(self) -> float | None:
        """Arrival -> first admission (cache lookup)."""
        return (None if self.t_admitted is None
                else self.t_admitted - self.arrival)


class IngressQueue:
    """Arrival-ordered request queue feeding the continuous batcher.

    Requests submitted with an ``arrival`` offset (seconds since stream
    start) become visible to ``due`` once the batcher's clock passes it;
    ties pop in submission order. ``close()`` tells the batcher no
    further submissions are coming, so it can drain and stop.
    """

    def __init__(self):
        self._heap: list[tuple[float, int, RequestState]] = []
        self._n = 0
        self._width: int | None = None
        self.closed = False

    def submit(self, tokens, arrival: float = 0.0, *,
               with_future: bool = False,
               deadline: float | None = None) -> RequestState:
        """``deadline`` is an absolute SLO deadline on the stream clock
        (seconds); the scheduler's ``SLOConfig.deadline_s`` supplies a
        per-request default when None."""
        if self.closed:
            raise RuntimeError("queue is closed")
        tokens = np.asarray(tokens)
        # one stream = one token width, like serve's (n, L) matrix —
        # chunks np.stack rows, so a mismatch would crash deep in the
        # batcher; right-pad shorter queries with the pipeline pad token
        if self._width is None:
            self._width = tokens.shape[-1]
        elif tokens.shape[-1] != self._width:
            raise ValueError(
                f"token width {tokens.shape[-1]} != stream width "
                f"{self._width}; right-pad queries to a common width")
        r = RequestState(rid=self._n, tokens=tokens,
                         arrival=float(arrival), deadline=deadline)
        if with_future:
            r.future = asyncio.get_running_loop().create_future()
        heapq.heappush(self._heap, (r.arrival, r.rid, r))
        self._n += 1
        return r

    def submit_burst(self, tokens: np.ndarray,
                     arrivals: Sequence[float] | None = None,
                     **kw) -> list[RequestState]:
        """tokens (b, L); arrivals (b,) offsets (default: all at t=0)."""
        if arrivals is None:
            arrivals = np.zeros(len(tokens))
        if len(arrivals) != len(tokens):
            raise ValueError(f"{len(tokens)} token rows but "
                             f"{len(arrivals)} arrival times")
        return [self.submit(t, a, **kw) for t, a in zip(tokens, arrivals)]

    def close(self):
        self.closed = True

    def due(self, now: float) -> list[RequestState]:
        """Pop every request whose arrival time has passed."""
        out = []
        while self._heap and self._heap[0][0] <= now:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def next_arrival(self) -> float | None:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


class ContinuousBatcher:
    """Continuous-batching admission loop over a ``ServingPipeline``.

    Drives the pipeline's three stages per-admission / per-chunk instead
    of per-closed-batch; see the module docstring. One batcher serves
    one stream and is then consumed (``result()``); build a fresh one
    per trace. Per-request state (tokens + telemetry) is kept for the
    final ``result()`` fold, so an indefinitely-open ``serve_async``
    stream should be rotated onto a fresh batcher periodically rather
    than run unbounded.
    """

    #: cap on idle sleeps so a producer submitting "later" is never
    #: missed for long (seconds)
    IDLE_POLL = 0.02

    def __init__(self, pipeline, max_chunk: int | None = None,
                 holdback: float = 0.02):
        self.pipeline = pipeline
        self.max_chunk = int(pipeline.batch_size if max_chunk is None
                             else max_chunk)
        self.holdback = float(holdback)
        if self.max_chunk < 1:
            raise ValueError("max_chunk must be >= 1")
        m = len(pipeline.tiers)
        self._tiers = pipeline._cascade_tiers()
        self._waiting: list[collections.deque] = [collections.deque()
                                                  for _ in range(m)]
        self._requests: list[RequestState] = []   # all, by rid order seen
        self.tier_counts = [0] * m                # requests entering tier j
        self.chunks_per_tier = [0] * m
        self._fill: list[float] = []              # chunk occupancy fractions
        self.cache_hits = 0
        self.cache_misses = 0
        self.latency = {"embed": 0.0, "cache": 0.0, "cascade": 0.0,
                        "insert": 0.0}

    _pad_rows = staticmethod(pad_pow2_rows)   # compat alias

    # -- admission: per-burst cache lookup ---------------------------------
    def admit(self, reqs: Sequence[RequestState], now: float):
        """Stage-1 a burst of new arrivals: embed + cache lookup; hits
        finish immediately, misses enter tier 0's wait queue."""
        if not reqs:
            return
        hit_mask, cached, emb, embed_s, cache_s = stage1_lookup(
            self.pipeline, reqs)
        self.latency["embed"] += embed_s
        self.latency["cache"] += cache_s
        self.cache_hits += int(hit_mask.sum())
        self.cache_misses += int((~hit_mask).sum())
        for i, r in enumerate(reqs):
            r.t_admitted = now
            self._requests.append(r)
            if hit_mask[i]:
                r.answer = cached[i]
                r.stopped_at = -1
                self._finish(r, now)
            else:
                if emb is not None:
                    r.emb = emb[i]
                self._enqueue(r, 0, now)

    def _enqueue(self, r: RequestState, j: int, now: float):
        r.tier_pos = j
        r.t_enqueued = now
        self.tier_counts[j] += 1
        self._waiting[j].append(r)

    def _finish(self, r: RequestState, now: float):
        r.t_done = now
        if r.future is not None and not r.future.done():
            r.future.set_result(r)

    # -- dispatch policy ---------------------------------------------------
    def has_work(self) -> bool:
        return any(self._waiting)

    def _pick_tier(self, now: float, *, drain: bool) -> int | None:
        """Which tier gets the next chunk — or None to hold back and let
        partial chunks fill (occupancy is throughput: a half-empty chunk
        costs the same padded-bucket compute as a full one)."""
        cand = [j for j, q in enumerate(self._waiting) if q]
        if not cand:
            return None
        overdue = [j for j in cand
                   if now - self._waiting[j][0].t_enqueued >= self.holdback]
        if overdue:                       # aged heads win, oldest first
            return min(overdue, key=lambda j: self._waiting[j][0].rid)
        full = [j for j in cand if len(self._waiting[j]) >= self.max_chunk]
        if full:                          # then the fullest queue
            return max(full, key=lambda j: len(self._waiting[j]))
        if drain:                         # nothing else will ever arrive
            return max(cand, key=lambda j: (len(self._waiting[j]),
                                            -self._waiting[j][0].rid))
        return None

    def _hold_expiry(self, now: float) -> float:
        """Seconds until the oldest waiting head ages past ``holdback``."""
        heads = [q[0].t_enqueued for q in self._waiting if q]
        if not heads:
            return self.IDLE_POLL
        return max(min(heads) + self.holdback - now, 0.0)

    def step(self, j: int, clock) -> list[RequestState]:
        """Pack and run ONE chunk on tier ``j``; returns the requests
        finished by this chunk."""
        q = self._waiting[j]
        batch = [q.popleft() for _ in range(min(self.max_chunk, len(q)))]
        toks, b = pad_pow2_rows(np.stack([r.tokens for r in batch]))
        pipe = self.pipeline
        last = j == len(self._tiers) - 1
        t0 = time.perf_counter()
        ans, cost, scores, accept = tier_step(
            self._tiers[j], toks, j, scorer=pipe._pos_scorer,
            threshold=None if last else pipe.thresholds[j], last=last)
        ans, cost, scores, accept = ans[:b], cost[:b], scores[:b], accept[:b]
        self.latency["cascade"] += time.perf_counter() - t0
        self.chunks_per_tier[j] += 1
        self._fill.append(len(batch) / self.max_chunk)
        now = clock()
        finished = []
        for i, r in enumerate(batch):
            r.n_chunks += 1
            r.cost += float(cost[i])
            if accept[i]:
                r.answer = ans[i]
                r.score = float(scores[i])
                r.stopped_at = j
                self._finish(r, now)
                finished.append(r)
            else:
                self._enqueue(r, j + 1, now)
        if pipe.cache is not None and finished:
            t0 = time.perf_counter()
            pipe._cache_insert(np.stack([r.emb for r in finished]),
                               np.asarray([r.answer for r in finished]),
                               np.asarray([r.score for r in finished]))
            for r in finished:              # the embedding served its
                r.emb = None                # purpose; don't retain it
            self.latency["insert"] += time.perf_counter() - t0
        return finished

    # -- drivers -----------------------------------------------------------
    def _ticks(self, queue: IngressQueue, clock) -> Iterator[float]:
        """The scheduling loop as a generator: runs admission + chunk
        steps inline and yields the seconds to sleep whenever idle; the
        sync/async drivers differ only in how they sleep. Terminates
        when the queue is closed and everything in flight has drained.
        """
        while True:
            self.admit(queue.due(clock()), clock())
            drain = queue.closed and len(queue) == 0
            j = self._pick_tier(clock(), drain=drain)
            if j is not None:
                self.step(j, clock)
                # zero-pause yield between chunks: the sync driver skips
                # it, the async driver hands the event loop to producers
                # so an open stream can keep submitting mid-backlog
                yield 0.0
                continue
            if self.has_work():            # holding back for chunk fill:
                now = clock()              # wake on arrival or age expiry
                pause = self._hold_expiry(now)
                nxt = queue.next_arrival()
                if nxt is not None:
                    pause = min(pause, max(nxt - now, 0.0))
                yield min(pause, self.IDLE_POLL)
                continue
            nxt = queue.next_arrival()
            if nxt is not None:
                yield min(max(nxt - clock(), 0.0), self.IDLE_POLL)
            elif queue.closed:
                return
            else:
                yield self.IDLE_POLL       # open stream, nothing due yet

    def run_trace(self, tokens: np.ndarray,
                  arrivals: Sequence[float] | None = None, *,
                  clock=None):
        """Synchronous trace replay: requests (rows of ``tokens``)
        become visible at their ``arrivals`` offsets on a wall clock,
        and the loop sleeps through genuinely idle gaps. An injected
        monotonic ``clock`` replaces the wall clock (tests; it must
        eventually pass every arrival offset or the trace never
        drains). Returns the folded ``ServeResult``."""
        t_start = time.perf_counter()

        if clock is None:
            def clock() -> float:
                return time.perf_counter() - t_start

        queue = IngressQueue()
        queue.submit_burst(tokens, arrivals)
        queue.close()
        for pause in self._ticks(queue, clock):
            if pause > 0:
                time.sleep(pause)
        return self.result(clock())

    async def serve_async(self, queue: IngressQueue, clock=None):
        """Asyncio driver over an (optionally still-open) queue:
        producers may keep submitting — with ``with_future=True`` each
        request's future resolves the moment it finishes — until
        ``queue.close()`` lets the loop drain and return the folded
        ``ServeResult``."""
        t_start = time.perf_counter()
        if clock is None:
            def clock() -> float:
                return time.perf_counter() - t_start
        for pause in self._ticks(queue, clock):
            # always yield control so producers can run, even at pause=0
            await asyncio.sleep(pause)
        return self.result(clock())

    # -- folding into ServeResult ------------------------------------------
    def stats(self) -> dict:
        """Ingress telemetry over every request seen so far."""
        done = [r for r in self._requests if r.done]
        lat = np.asarray([r.latency for r in done], np.float64)
        wait = np.asarray([r.queue_wait for r in done], np.float64)
        return {
            "request_latency": lat,
            "queue_wait": wait,
            "chunks_per_tier": list(self.chunks_per_tier),
            "chunk_occupancy": float(np.mean(self._fill)) if self._fill
            else 0.0,
            "n_chunks": int(sum(self.chunks_per_tier)),
        }

    def result(self, total_s: float):
        """Fold the finished stream into a ``ServeResult`` bit-compatible
        with ``ServingPipeline.serve`` (answers/cost/stopped_at indexed
        by submission order)."""
        return fold_stream_result(
            self.pipeline, self._requests, tier_counts=self.tier_counts,
            cache_hits=self.cache_hits, cache_misses=self.cache_misses,
            latency=self.latency, total_s=total_s, ingress=self.stats())
