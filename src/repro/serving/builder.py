"""Shared pipeline builder: everything between "a task name" and "a ready
``ServingPipeline``", used by both ``repro.launch.serve`` and
``examples/cascade_serving.py`` (which are now thin CLI wrappers).

Build steps:
  1. train the tier models (neural marketplace) on the synthetic task;
  2. collect offline marketplace data and train the scoring function
     g(q, a) on it;
  3. greedy prompt selection per tier (§3.1): pick the few-shot examples
     worth their tokens under each tier's measured accuracy profile;
  4. reprice the offline data with the adapted per-tier prompts and
     learn (L, tau) with the router optimizer under the budget;
  5. assemble the ``ServingPipeline``: completion cache keyed by
     scorer-encoder embeddings, adapted prompts, learned cascade.

The prompt-selection accuracy model is the calibrated diminishing-
returns curve (per-example gains anchored at the tier's measured
validation accuracy, as in ``examples/prompt_adaptation.py``); the token
accounting is exact.
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp
import numpy as np

from repro.core import neural_market as NM
from repro.core import scorer as SC
from repro.core.approx import CompletionCache, embed_queries
from repro.core.joint import joint_prompt_cascade
from repro.core.prompt import PromptSpec, select_prompt
from repro.core.router import RouterConfig, learn_cascade
from repro.core.simulate import MarketData
from repro.data import synthetic
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.strategy import (BudgetGovernor, ContextualRouter,
                                    ServingStrategy, accept_labels,
                                    train_entry_router)

#: synthetic task -> the paper dataset whose prompt shape ``core.joint``
#: models (prompt sizes, per-example token counts, Table-1 pricing)
_JOINT_DATASET = {"headlines": "HEADLINES", "overruling": "OVERRULING",
                  "qa": "COQA"}


@dataclasses.dataclass
class BuildConfig:
    task: str = "headlines"
    tiers: tuple = ("GPT-J", "ChatGPT", "GPT-4")
    train_queries: int = 400
    train_steps_cap: int = 200
    scorer_steps: int = 250
    budget_frac: float = 0.3        # budget as fraction of top-tier cost
    seed: int = 0
    router: RouterConfig | None = None
    # strategy toggles
    enable_cache: bool = True
    enable_prompt_adaptation: bool = True
    cache_capacity: int = 1024
    cache_threshold: float = 0.995
    cache_policy: str = "fifo"          # "fifo" ring | "lru" | "lfu"
    cache_min_score: float | None = None  # score-confidence insert floor
    cache_ttl: float | None = None      # entry time-to-live (seconds)
    # per-tier device placement (sharding.placement): pin each cascade
    # tier's model to its own local jax.Device, sized by the offline
    # replay's per-tier traffic share — on a multi-device host the tier
    # workers then decode on disjoint devices. Results are bit-identical
    # to the shared-device pipeline (tests/test_placement.py).
    place_tiers: bool = False
    # ... or per-tier mesh slices (sharding.tier_mesh): each tier's
    # model is sharded over a contiguous sub-mesh sized by the same
    # traffic signal — the multi-host rung of place_tiers (which it
    # supersedes; setting both is an error). mesh_shape=(R, C) lays the
    # local devices out as R rows ("data"/FSDP axis units) x C columns
    # ("model" tensor axis); None = (n_devices, 1), data-parallel only,
    # which keeps results bit-identical to the unsharded pipeline.
    shard_tiers: bool = False
    mesh_shape: tuple | None = None
    # pending-set compaction mode for the batch cascade path:
    # "host" numpy | "device" jitted gather+prefix-sum | "pallas" kernel
    compact: str = "host"
    # speculative cascade execution (repro.serving.sched): idle tier
    # workers pre-invoke predicted-reject rows on the stream scheduler.
    # Opt-in; bit-identical answers/costs by construction — only moves
    # wall-clock. Dials (depth, probability bar, idle budget) live on
    # the SLOConfig passed to the stream entry points.
    speculate: bool = False
    # fault tolerance (repro.serving.resilience). ``faults`` injects a
    # deterministic seeded fault schedule into the assembled tiers (one
    # FaultSpec broadcast to every tier, or a list indexed by the
    # *marketplace* tier order — the learned cascade keeps a subsequence
    # of the marketplace, so the builder maps the list onto whichever
    # tiers were selected and drops the rest; None = the tiers are not
    # even wrapped). ``retry``/``breaker`` opt the serving paths into
    # retry + circuit-breaker failover; all three default off and off
    # is bit-identical to not having the subsystem at all.
    faults: object | None = None        # FaultSpec | list | None
    retry: object | None = None         # RetryPolicy | None
    breaker: object | None = None       # BreakerConfig | None
    # joint prompt x cascade search (core.joint) instead of greedy
    # per-tier prompt selection: one shared prompt size chosen jointly
    # with the cascade under the budget
    joint_search: bool = False
    joint_prompt_sizes: tuple | None = None   # None = 0..n_shot
    # contextual entry routing + online budget governance
    # (repro.serving.strategy): train a per-query entry-tier router on
    # the offline artifacts; optionally govern spend to budget_rate
    contextual: bool = False
    entry_bar: float = 0.5          # predicted-accept bar to enter a tier
    degrade_relief: float = 0.5     # bar relief factor under overload
    router_hidden: int = 64
    router_steps: int = 300
    budget_rate: float | None = None  # target USD/query (None = no governor)
    governor_window: int = 64         # queries per governor update
    # window-assignment routing (repro.serving.assign): an AssignConfig
    # trains the two-head window meta-model on the same offline
    # artifacts and wires a WindowAssigner into the strategy as
    # mode="assign" — the third routing mode, beside fixed thresholds
    # and greedy contextual entry. None = structurally absent.
    assign: object | None = None        # assign.AssignConfig | None
    # accuracy-guaranteed frugality (repro.serving.guarantee): a
    # GuaranteeConfig(delta=, alpha=, sample_frac=) shadow-samples live
    # traffic against the reference (top) tier, holds anytime-valid
    # sequential confidence intervals on the gap-to-reference, and caps
    # the governor's threshold shift so P(gap > delta) <= alpha — the
    # spend controller's second dual constraint. Shadow invocations are
    # charged to a separate meter. None = structurally absent
    # (bit-identical serving).
    guarantee: object | None = None     # guarantee.GuaranteeConfig | None
    # unadapted few-shot prompt shape (paper's 8-shot HEADLINES scale)
    n_shot: int = 8
    tokens_per_example: int = 110
    base_tokens: int = 140
    verbose: bool = True


def _select_tier_prompt(cfg: BuildConfig, tier_idx: int,
                        val_acc: float) -> tuple[PromptSpec, list]:
    """Greedy prompt selection for one tier (Fig. 2a).

    Accuracy model: measured validation accuracy at the full prompt,
    diminishing per-example gains (seeded per tier) — the greedy selector
    finds the knee where examples stop paying for their tokens.
    """
    rng = np.random.default_rng(cfg.seed + 101 * tier_idx)
    gains = np.sort(rng.uniform(0.004, 0.02, size=cfg.n_shot))[::-1]
    base = val_acc - float(gains.sum())

    def evaluate(ids):
        return base + sum(float(gains[i]) for i in ids)

    return select_prompt(list(range(cfg.n_shot)), evaluate,
                         tokens_per_example=cfg.tokens_per_example,
                         base_tokens=cfg.base_tokens, min_gain=0.008)


def _reprice(data: MarketData, apis, prompts, full_tokens: int) -> MarketData:
    """Offline costs as the pipeline will actually bill them: query
    tokens + the (adapted or full) per-tier prompt prefix."""
    cost = np.zeros(np.asarray(data.cost).shape, np.float32)
    n_in = np.asarray(data.n_in)
    for k, api in enumerate(apis):
        prefix = prompts[k].n_tokens if prompts[k] is not None else full_tokens
        cost[:, k] = np.asarray(api.price.query_cost(n_in + prefix,
                                                     data.n_out))
    return MarketData(data.names, data.correct, jnp.asarray(cost),
                      data.n_in, data.n_out, data.difficulty)


def _select_tier_faults(faults, n_market: int, selected):
    """Map a marketplace-indexed per-tier fault list onto the tiers the
    learned cascade actually kept (``selected`` = marketplace indices,
    in cascade order). Broadcast specs and ``None`` pass through."""
    if not isinstance(faults, (list, tuple)):
        return faults
    if len(faults) != n_market:
        raise ValueError(
            f"{len(faults)} fault specs for a {n_market}-tier "
            "marketplace (per-tier fault lists are indexed by the "
            "marketplace order, not the learned cascade)")
    return [faults[i] for i in selected]


def build_pipeline(cfg: BuildConfig) -> tuple[ServingPipeline, dict]:
    """Returns (pipeline, report). ``report`` carries the build artifacts
    (apis, market data, scorer params, cascade, metrics) for drivers that
    want to print or evaluate them."""
    say = print if cfg.verbose else (lambda *a, **k: None)

    # 1. tier models
    say("== training tier models ==")
    tier_specs = NM.tier_subset(cfg.tiers, steps_cap=cfg.train_steps_cap)
    apis = NM.train_marketplace(cfg.task, seed=cfg.seed, verbose=cfg.verbose,
                                tiers=tier_specs)

    # 2. offline data + scorer
    say("== collecting offline marketplace data ==")
    train = synthetic.sample(cfg.task, cfg.train_queries, seed=cfg.seed + 11)
    data, answers = NM.collect_market_data(apis, train.tokens, train.labels)
    accs = np.asarray(data.accuracy())
    say("tier accuracy:", {n: round(float(a), 3)
                           for n, a in zip(data.names, accs)})

    say("== training the scoring function g(q, a) ==")
    k = len(apis)
    q = np.repeat(train.tokens, k, axis=0)
    y = np.asarray(data.correct).reshape(-1)
    sp = SC.train_scorer(q, answers.reshape(-1), y, steps=cfg.scorer_steps,
                         seed=cfg.seed)
    s_train = np.stack([SC.score(sp, train.tokens, answers[:, j])
                        for j in range(k)], axis=1)
    say(f"scorer AUC: {SC.auc(s_train.reshape(-1), y):.3f}")

    # 3. prompt adaptation: greedy per-tier selection, or the joint
    #    prompt x cascade search (one shared prompt size chosen jointly
    #    with the cascade, core.joint) behind cfg.joint_search
    full_tokens = cfg.base_tokens + cfg.n_shot * cfg.tokens_per_example
    prompts: list[PromptSpec | None] = [None] * k
    router = cfg.router or RouterConfig(top_lists=10, sample=256)
    joint_report = None
    if cfg.joint_search:
        say("== joint prompt x cascade search ==")
        full_priced = _reprice(data, apis, prompts, full_tokens)
        joint_budget = float(full_priced.cost[:, -1].mean()) * cfg.budget_frac
        sizes = (cfg.joint_prompt_sizes if cfg.joint_prompt_sizes is not None
                 else range(cfg.n_shot + 1))
        best, rows = joint_prompt_cascade(
            full_priced, jnp.asarray(s_train), _JOINT_DATASET[cfg.task],
            joint_budget, cfg=router, prompt_sizes=sizes, seed=cfg.seed)
        n_ex = int(best["n_examples"])
        prompts = [PromptSpec(tuple(range(n_ex)), cfg.tokens_per_example,
                              cfg.base_tokens) for _ in range(k)]
        joint_report = {"n_examples": n_ex, "rows": rows,
                        "budget": joint_budget}
        say(f"  joint winner: {n_ex}/{cfg.n_shot} examples "
            f"(acc {best['acc']:.3f} at ${best['avg_cost']:.6f}/query)")
    elif cfg.enable_prompt_adaptation:
        say("== greedy prompt selection per tier ==")
        for j in range(k):
            spec, _ = _select_tier_prompt(cfg, j, float(accs[j]))
            prompts[j] = spec
            say(f"  {data.names[j]}: kept {len(spec.example_ids)}/"
                f"{cfg.n_shot} examples ({spec.n_tokens} vs {full_tokens} "
                f"prompt tokens)")

    # 4. learn the cascade on the repriced (served-as-billed) costs
    say("== learning the cascade ==")
    priced = _reprice(data, apis, prompts, full_tokens)
    budget = float(priced.cost[:, -1].mean()) * cfg.budget_frac
    cas, metrics = learn_cascade(priced, jnp.asarray(s_train), budget, router)
    say(f"cascade: {cas.describe(data.names)} "
        f"(train acc {metrics['acc']:.3f}, ${metrics['avg_cost']:.6f}/query)")

    # 5. contextual strategy: entry-tier router trained on the same
    #    offline artifacts the cascade was learned from, plus an online
    #    budget governor when a target spend rate is set
    strategy = None
    entry_router = governor = assigner = None
    ent = None
    emb_train = None
    if cfg.contextual or cfg.assign is not None:
        emb_train = embed_queries(sp, train.tokens, cfg=SC.SCORER_CFG)
    if cfg.contextual:
        say("== training the contextual entry router ==")
        y = accept_labels(s_train, np.asarray(data.correct),
                          cas.apis, cas.thresholds)
        rp = train_entry_router(emb_train, y, hidden=cfg.router_hidden,
                                steps=cfg.router_steps, seed=cfg.seed)
        entry_router = ContextualRouter(rp, len(cas.apis))
        ent = entry_router.entry_tiers(emb_train, cfg.entry_bar)
        say(f"  entry-tier distribution (train): "
            f"{np.bincount(ent, minlength=len(cas.apis)).tolist()}")
    if cfg.assign is not None:
        from repro.serving.assign import (WindowAssigner,
                                          correctness_labels,
                                          train_window_meta)
        say("== training the window meta-model ==")
        acc_y = accept_labels(s_train, np.asarray(data.correct),
                              cas.apis, cas.thresholds)
        cor_y = correctness_labels(data.correct, cas.apis)
        meta = train_window_meta(
            emb_train, acc_y, cor_y, hidden=cfg.assign.hidden,
            steps=cfg.assign.steps, batch=cfg.assign.batch,
            lr=cfg.assign.lr, seed=cfg.assign.seed + cfg.seed)
        assigner = WindowAssigner(meta=meta, cfg=cfg.assign)
        say(f"  window meta: {len(cas.apis)} tiers, "
            f"window_size={cfg.assign.window_size}")
    guarantee_ctrl = None
    if cfg.guarantee is not None:
        from repro.serving.guarantee import (GuaranteeController,
                                             RouterRetrainer)
        retrainer = None
        if cfg.guarantee.retrain and entry_router is not None:
            retrainer = RouterRetrainer(entry_router)
        guarantee_ctrl = GuaranteeController(cfg.guarantee,
                                             retrainer=retrainer)
        say(f"== accuracy guarantee: gap <= {cfg.guarantee.delta} at "
            f"alpha {cfg.guarantee.alpha} "
            f"({cfg.guarantee.sample_frac:.0%} shadow"
            f"{', online router retraining' if retrainer else ''}) ==")
    if cfg.budget_rate is not None:
        governor = BudgetGovernor(cfg.budget_rate, cas.thresholds,
                                  base_bar=cfg.entry_bar,
                                  base_min_score=cfg.cache_min_score
                                  if cfg.enable_cache else None,
                                  base_threshold=cfg.cache_threshold
                                  if cfg.enable_cache else None,
                                  window=cfg.governor_window,
                                  guarantee=guarantee_ctrl)
    if (entry_router is not None or governor is not None
            or assigner is not None or guarantee_ctrl is not None):
        strategy = ServingStrategy(router=entry_router, governor=governor,
                                   entry_bar=cfg.entry_bar,
                                   degrade_relief=cfg.degrade_relief,
                                   mode=("assign" if assigner is not None
                                         else "entry"),
                                   assigner=assigner,
                                   guarantee=guarantee_ctrl)

    # 6. per-tier device placement: the offline replay's per-tier
    #    pending counts are the traffic-share signal (the online
    #    analogue is ServeResult.tier_counts); each tier's params move
    #    to their assigned device, so its chunks decode there. With a
    #    contextual router the replay honours the learned entry tiers —
    #    all-enter-at-0 pending fractions would size the wrong tiers.
    placement = mesh_plan = None
    if cfg.place_tiers and cfg.shard_tiers:
        raise ValueError("place_tiers pins tiers to single devices, "
                         "shard_tiers slices a mesh over them — pick one")
    if cfg.place_tiers or cfg.shard_tiers:
        from repro.core.cascade import execute_cascade, replay_tiers
        if ent is not None:
            replay = execute_cascade(
                replay_tiers(priced, cas.apis), cas.thresholds,
                lambda idx, _a, j: s_train[idx, cas.apis[j]],
                np.arange(data.n), batch_size=max(1, data.n), entry=ent)
            reach = [float(c) for c in replay["tier_counts"]]
        else:
            stop = list(metrics["stop_fracs"])
            reach = [1.0 - sum(stop[:j]) for j in range(len(cas.apis))]
    if cfg.place_tiers:
        from repro.sharding.placement import place_params, plan_placement
        placement = plan_placement(len(cas.apis), tier_counts=reach)
        for j, i in enumerate(cas.apis):
            apis[i].params = place_params(apis[i].params,
                                          placement.for_tier(j))
        say(f"tier placement: "
            f"{placement.describe([data.names[i] for i in cas.apis])}")
    elif cfg.shard_tiers:
        from repro.sharding.tier_mesh import plan_tier_meshes, shard_params
        mesh_plan = plan_tier_meshes(len(cas.apis),
                                     mesh_shape=cfg.mesh_shape,
                                     tier_counts=reach)
        for j, i in enumerate(cas.apis):
            apis[i].params = shard_params(apis[i].params,
                                          mesh_plan.for_tier(j))
        say(f"tier mesh slices: "
            f"{mesh_plan.describe([data.names[i] for i in cas.apis])}")

    # 7. assemble the pipeline
    cache = embed = None
    if cfg.enable_cache:
        cache = CompletionCache(capacity=cfg.cache_capacity,
                                threshold=cfg.cache_threshold,
                                policy=cfg.cache_policy,
                                min_score=cfg.cache_min_score,
                                ttl=cfg.cache_ttl)
    if (cfg.enable_cache or entry_router is not None
            or assigner is not None):
        embed = functools.partial(embed_queries, sp, cfg=SC.SCORER_CFG)
    tiers = [TierSpec(apis[i].name, apis[i].answer, apis[i].price,
                      prompt=prompts[i],
                      device=placement.for_tier(j) if placement else None,
                      mesh=mesh_plan.for_tier(j) if mesh_plan else None)
             for j, i in enumerate(cas.apis)]
    # savings baseline = the marketplace's most expensive tier, NOT the
    # cascade's last tier (a tight budget can drop the top tier entirely)
    top = int(np.argmax(np.asarray(priced.cost).mean(0)))
    faults = _select_tier_faults(cfg.faults, len(apis), cas.apis)
    pipeline = ServingPipeline(
        tiers=tiers, thresholds=cas.thresholds,
        scorer=lambda toks, ans: SC.score(sp, toks, ans),
        cache=cache, embed=embed, full_prompt_tokens=full_tokens,
        pad_token=synthetic.PAD, baseline_price=apis[top].price,
        strategy=strategy, compact=cfg.compact, speculate=cfg.speculate,
        faults=faults, retry=cfg.retry, breaker=cfg.breaker)
    report = {"apis": apis, "data": data, "priced": priced,
              "answers": answers, "scorer": sp, "scores": s_train,
              "cascade": cas, "metrics": metrics, "budget": budget,
              "prompts": prompts, "full_prompt_tokens": full_tokens,
              "strategy": strategy, "joint": joint_report,
              "guarantee": guarantee_ctrl,
              "placement": placement, "mesh_plan": mesh_plan}
    return pipeline, report
