"""Shared pipeline builder: everything between "a task name" and "a ready
``ServingPipeline``", used by both ``repro.launch.serve`` and
``examples/cascade_serving.py`` (which are now thin CLI wrappers).

Build steps:
  1. train the tier models (neural marketplace) on the synthetic task;
  2. collect offline marketplace data and train the scoring function
     g(q, a) on it;
  3. greedy prompt selection per tier (§3.1): pick the few-shot examples
     worth their tokens under each tier's measured accuracy profile;
  4. reprice the offline data with the adapted per-tier prompts and
     learn (L, tau) with the router optimizer under the budget;
  5. assemble the ``ServingPipeline``: completion cache keyed by
     scorer-encoder embeddings, adapted prompts, learned cascade.

The prompt-selection accuracy model is the calibrated diminishing-
returns curve (per-example gains anchored at the tier's measured
validation accuracy, as in ``examples/prompt_adaptation.py``); the token
accounting is exact.
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp
import numpy as np

from repro.core import neural_market as NM
from repro.core import scorer as SC
from repro.core.approx import CompletionCache, embed_queries
from repro.core.prompt import PromptSpec, select_prompt
from repro.core.router import RouterConfig, learn_cascade
from repro.core.simulate import MarketData
from repro.data import synthetic
from repro.serving.pipeline import ServingPipeline, TierSpec


@dataclasses.dataclass
class BuildConfig:
    task: str = "headlines"
    tiers: tuple = ("GPT-J", "ChatGPT", "GPT-4")
    train_queries: int = 400
    train_steps_cap: int = 200
    scorer_steps: int = 250
    budget_frac: float = 0.3        # budget as fraction of top-tier cost
    seed: int = 0
    router: RouterConfig | None = None
    # strategy toggles
    enable_cache: bool = True
    enable_prompt_adaptation: bool = True
    cache_capacity: int = 1024
    cache_threshold: float = 0.995
    # unadapted few-shot prompt shape (paper's 8-shot HEADLINES scale)
    n_shot: int = 8
    tokens_per_example: int = 110
    base_tokens: int = 140
    verbose: bool = True


def _select_tier_prompt(cfg: BuildConfig, tier_idx: int,
                        val_acc: float) -> tuple[PromptSpec, list]:
    """Greedy prompt selection for one tier (Fig. 2a).

    Accuracy model: measured validation accuracy at the full prompt,
    diminishing per-example gains (seeded per tier) — the greedy selector
    finds the knee where examples stop paying for their tokens.
    """
    rng = np.random.default_rng(cfg.seed + 101 * tier_idx)
    gains = np.sort(rng.uniform(0.004, 0.02, size=cfg.n_shot))[::-1]
    base = val_acc - float(gains.sum())

    def evaluate(ids):
        return base + sum(float(gains[i]) for i in ids)

    return select_prompt(list(range(cfg.n_shot)), evaluate,
                         tokens_per_example=cfg.tokens_per_example,
                         base_tokens=cfg.base_tokens, min_gain=0.008)


def _reprice(data: MarketData, apis, prompts, full_tokens: int) -> MarketData:
    """Offline costs as the pipeline will actually bill them: query
    tokens + the (adapted or full) per-tier prompt prefix."""
    cost = np.zeros(np.asarray(data.cost).shape, np.float32)
    n_in = np.asarray(data.n_in)
    for k, api in enumerate(apis):
        prefix = prompts[k].n_tokens if prompts[k] is not None else full_tokens
        cost[:, k] = np.asarray(api.price.query_cost(n_in + prefix,
                                                     data.n_out))
    return MarketData(data.names, data.correct, jnp.asarray(cost),
                      data.n_in, data.n_out, data.difficulty)


def build_pipeline(cfg: BuildConfig) -> tuple[ServingPipeline, dict]:
    """Returns (pipeline, report). ``report`` carries the build artifacts
    (apis, market data, scorer params, cascade, metrics) for drivers that
    want to print or evaluate them."""
    say = print if cfg.verbose else (lambda *a, **k: None)

    # 1. tier models
    say("== training tier models ==")
    tier_specs = NM.tier_subset(cfg.tiers, steps_cap=cfg.train_steps_cap)
    apis = NM.train_marketplace(cfg.task, seed=cfg.seed, verbose=cfg.verbose,
                                tiers=tier_specs)

    # 2. offline data + scorer
    say("== collecting offline marketplace data ==")
    train = synthetic.sample(cfg.task, cfg.train_queries, seed=cfg.seed + 11)
    data, answers = NM.collect_market_data(apis, train.tokens, train.labels)
    accs = np.asarray(data.accuracy())
    say("tier accuracy:", {n: round(float(a), 3)
                           for n, a in zip(data.names, accs)})

    say("== training the scoring function g(q, a) ==")
    k = len(apis)
    q = np.repeat(train.tokens, k, axis=0)
    y = np.asarray(data.correct).reshape(-1)
    sp = SC.train_scorer(q, answers.reshape(-1), y, steps=cfg.scorer_steps,
                         seed=cfg.seed)
    s_train = np.stack([SC.score(sp, train.tokens, answers[:, j])
                        for j in range(k)], axis=1)
    say(f"scorer AUC: {SC.auc(s_train.reshape(-1), y):.3f}")

    # 3. prompt adaptation per tier
    full_tokens = cfg.base_tokens + cfg.n_shot * cfg.tokens_per_example
    prompts: list[PromptSpec | None] = [None] * k
    if cfg.enable_prompt_adaptation:
        say("== greedy prompt selection per tier ==")
        for j in range(k):
            spec, _ = _select_tier_prompt(cfg, j, float(accs[j]))
            prompts[j] = spec
            say(f"  {data.names[j]}: kept {len(spec.example_ids)}/"
                f"{cfg.n_shot} examples ({spec.n_tokens} vs {full_tokens} "
                f"prompt tokens)")

    # 4. learn the cascade on the repriced (served-as-billed) costs
    say("== learning the cascade ==")
    priced = _reprice(data, apis, prompts, full_tokens)
    budget = float(priced.cost[:, -1].mean()) * cfg.budget_frac
    router = cfg.router or RouterConfig(top_lists=10, sample=256)
    cas, metrics = learn_cascade(priced, jnp.asarray(s_train), budget, router)
    say(f"cascade: {cas.describe(data.names)} "
        f"(train acc {metrics['acc']:.3f}, ${metrics['avg_cost']:.6f}/query)")

    # 5. assemble the pipeline
    cache = embed = None
    if cfg.enable_cache:
        cache = CompletionCache(capacity=cfg.cache_capacity,
                                threshold=cfg.cache_threshold)
        embed = functools.partial(embed_queries, sp, cfg=SC.SCORER_CFG)
    tiers = [TierSpec(apis[i].name, apis[i].answer, apis[i].price,
                      prompt=prompts[i]) for i in cas.apis]
    # savings baseline = the marketplace's most expensive tier, NOT the
    # cascade's last tier (a tight budget can drop the top tier entirely)
    top = int(np.argmax(np.asarray(priced.cost).mean(0)))
    pipeline = ServingPipeline(
        tiers=tiers, thresholds=cas.thresholds,
        scorer=lambda toks, ans: SC.score(sp, toks, ans),
        cache=cache, embed=embed, full_prompt_tokens=full_tokens,
        pad_token=synthetic.PAD, baseline_price=apis[top].price)
    report = {"apis": apis, "data": data, "priced": priced,
              "answers": answers, "scorer": sp, "scores": s_train,
              "cascade": cas, "metrics": metrics, "budget": budget,
              "prompts": prompts, "full_prompt_tokens": full_tokens}
    return pipeline, report
