"""``repro.serving`` — the FrugalGPT serving subsystem.

The paper's three cost-reduction strategies composed on one batched
request path:

  * **completion cache** (§3.2) — ``repro.core.approx.CompletionCache``,
    keyed by scorer-encoder embeddings;
  * **prompt adaptation** (§3.1) — per-tier ``PromptSpec`` billed with
    the exact 3-term ``ApiCost`` model;
  * **LLM cascade** (§3.3) — tier-by-tier compaction through the repo's
    single cascade executor (``repro.core.cascade.execute_cascade``).

Modules
-------
``engine``    ``GenerationEngine`` (bucketed prefill compilation — batch,
              prompt and cache lengths round up to power-of-two buckets
              so compiled variants stay O(log range)), a shared
              ``EnginePool``, ``Tier``/``generation_tier`` adapters, and
              the ``CascadeServer`` facade.
``pipeline``  ``ServingPipeline`` (the three-stage request path; batch
              ``serve`` plus continuous-batching ``serve_stream`` /
              ``aserve``) and the ``ServeResult`` telemetry record:
              per-tier compaction counts, cache hit rate, per-stage
              latency, prompt tokens saved, cost vs. the top-tier
              baseline, and (stream path) per-request latency.
``ingress``   async ingress with continuous batching: ``IngressQueue``
              (arrival-ordered admission, optional per-request asyncio
              futures) and ``ContinuousBatcher`` (packs waiting requests
              of a tier into its next chunk while earlier chunks decode,
              through the shared ``core.cascade.tier_step``).
``sched``     SLO-aware parallel tier scheduling: ``TierScheduler`` (one
              worker thread per tier — chunks decode concurrently),
              ``SLOConfig`` (deadlines, adaptive holdback, bounded
              queues, reject/degrade overload policies) and per-tier
              EWMA service-time estimators. The default executor behind
              ``serve_stream``/``aserve``.
``resilience`` fault-tolerant serving: seeded deterministic fault
              injection (``FaultSpec``/``FaultyTier``), per-tier
              ``RetryPolicy`` (bounded attempts, deterministic backoff,
              deadline-aware), per-tier circuit breakers
              (``BreakerConfig``/``TierHealth``), and the failover
              semantics threaded through the cascade executor and the
              parallel scheduler (escalate past a sick tier; fall back
              to the best earlier answer on last-tier failure).
``strategy``  contextual routing + online budget governance: a
              ``ContextualRouter`` (jax MLP over the scorer-encoder
              embeddings) predicts each query's cascade entry tier, a
              ``BudgetGovernor`` holds realized $/query to a target by
              shifting the thresholds/entry bar online, and cost-aware
              overload degradation routes degraded arrivals to the
              cheapest tier clearing a reduced predicted bar. Composed
              as a ``ServingStrategy`` on ``pipeline.strategy``.
``guarantee`` accuracy-guaranteed frugality (online SMART calibration):
              a seeded shadow sample of live traffic is re-run on the
              reference (top) tier, anytime-valid sequential confidence
              intervals track each threshold configuration's
              gap-to-reference, and a tighten ladder caps the budget
              governor's shift so ``P(gap > delta) <= alpha`` holds
              under drift the frozen offline grid would violate. Shadow
              labels also retrain the contextual router online.
``builder``   ``build_pipeline(BuildConfig)`` — train tiers, collect
              offline data, train the scorer, select prompts, learn the
              cascade, assemble the pipeline (with ``contextual=True`` /
              ``budget_rate=`` also the strategy layer).
              ``repro.launch.serve`` and ``examples/cascade_serving.py``
              are thin wrappers over it.

Usage
-----
    from repro.serving import BuildConfig, build_pipeline
    from repro.data import synthetic

    pipe, report = build_pipeline(BuildConfig(task="headlines"))
    batch = synthetic.sample("headlines", 256, seed=7)
    res = pipe.serve(batch.tokens)       # ServeResult
    print(res.summary())                 # hit rate, compaction, $ saved
    res = pipe.serve(batch.tokens)       # repeats now hit the cache

Serve a custom marketplace by constructing ``ServingPipeline`` directly
with ``TierSpec`` entries (any ``answer`` callable: a marketplace
classifier, a ``generation_tier`` over a pooled ``GenerationEngine``, or
a remote API client).
"""
from repro.serving.builder import BuildConfig, build_pipeline  # noqa: F401
from repro.serving.ingress import (  # noqa: F401
    ContinuousBatcher,
    IngressQueue,
    RequestState,
    poisson_arrivals,
)
from repro.serving.resilience import (  # noqa: F401
    BreakerConfig,
    CircuitBreaker,
    FaultSpec,
    FaultyTier,
    RetryPolicy,
    TierFault,
    TierHealth,
    wrap_tiers,
)
from repro.serving.sched import (  # noqa: F401
    SLOConfig,
    TierScheduler,
)
from repro.serving.strategy import (  # noqa: F401
    BudgetGovernor,
    ContextualRouter,
    ServingStrategy,
)
from repro.serving.guarantee import (  # noqa: F401
    GuaranteeConfig,
    GuaranteeController,
    RouterRetrainer,
)
from repro.serving.engine import (  # noqa: F401
    CascadeServer,
    EnginePool,
    GenerationEngine,
    Tier,
    generation_tier,
)
from repro.serving.pipeline import (  # noqa: F401
    ServeResult,
    ServingPipeline,
    TierSpec,
)
