"""Contextual entry-tier routing: per-query cascade entry prediction.

FrugalGPT's cascade enters every query at tier 0 and climbs until a
score clears the tier's threshold. That is already adaptive *per query*
— but only after paying for every tier below the stopping one. The
contextual router closes that gap (Zhang et al., budget-constrained
contextual cascade policy learning; Šakota et al., "fly-swat or
cannon"): a small jax MLP over the scorer-encoder embeddings predicts,
per query, the probability that each cascade position's answer would be
*accepted* (score >= tau at non-final positions; correct at the final
one). A query then enters at the cheapest position whose predicted
accept probability clears the entry bar — easy queries still start at
tier 0, hard queries skip the cheap tiers that were dead weight for
them, and the skipped calls are pure cost savings.

Training data is free: the builder already collects offline
``MarketData`` plus per-(query, api) reliability scores to learn
``(L, tau)``; the same matrices labelled against the learned thresholds
supervise the router (``accept_labels``). The embedding is the same
scorer-encoder embedding the completion cache keys on
(``core.approx.embed_queries``) — no extra model.

The entry bar is a runtime dial: the online budget governor
(``strategy.governor``) nudges it together with the cascade thresholds
to keep the realized spend rate on target.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optim import OptConfig, adamw_update, init_opt_state


def _mlp_forward(params, emb):
    """(n, d) embeddings -> (n, m) per-position accept logits."""
    h = jax.nn.gelu(emb @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


@functools.cache
def _jitted_forward():
    """One jitted forward shared by every router instance — shapes are
    part of the jit cache key, so routers of different widths coexist."""
    return jax.jit(_mlp_forward)


def init_router_params(key, d_in: int, n_tiers: int, hidden: int = 64):
    k1, k2 = jax.random.split(key)
    scale = 1.0 / np.sqrt(d_in)
    return {
        "w1": scale * jax.random.normal(k1, (d_in, hidden)),
        "b1": jnp.zeros((hidden,)),
        "w2": 0.02 * jax.random.normal(k2, (hidden, n_tiers)),
        "b2": jnp.zeros((n_tiers,)),
    }


def accept_labels(scores: np.ndarray, correct: np.ndarray,
                  apis, thresholds) -> np.ndarray:
    """Supervision for the entry router from offline build artifacts.

    scores (n, K): reliability scores g(q, a_k) on the marketplace;
    correct (n, K): recorded correctness; apis/thresholds: the learned
    cascade. Returns (n, m) 0/1 — column j says "tier j's answer would
    be accepted": score >= tau_j at non-final positions, correctness at
    the final position (which accepts unconditionally, so its label is
    whether *entering there* would answer well).
    """
    scores = np.asarray(scores)
    correct = np.asarray(correct)
    m = len(apis)
    y = np.zeros((scores.shape[0], m), np.float32)
    for j, a in enumerate(apis):
        if j < m - 1:
            y[:, j] = (scores[:, a] >= thresholds[j]).astype(np.float32)
        else:
            y[:, j] = correct[:, a]
    return y


def train_entry_router(emb: np.ndarray, labels: np.ndarray, *,
                       hidden: int = 64, steps: int = 300, batch: int = 256,
                       lr: float = 3e-3, seed: int = 0) -> dict:
    """Train the per-position accept predictor with BCE; returns params.

    emb (n, d) scorer-encoder embeddings; labels (n, m) from
    ``accept_labels``.
    """
    emb = jnp.asarray(emb, jnp.float32)
    labels = jnp.asarray(labels, jnp.float32)
    n, d = emb.shape
    params = init_router_params(jax.random.PRNGKey(seed), d,
                                labels.shape[1], hidden)
    opt = OptConfig(lr=lr, warmup=10, total_steps=steps, weight_decay=1e-4)
    state = init_opt_state(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step_fn(params, state, x, y):
        def loss_fn(p):
            logit = _mlp_forward(p, x)
            return jnp.mean(jnp.maximum(logit, 0) - logit * y
                            + jnp.log1p(jnp.exp(-jnp.abs(logit))))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = adamw_update(opt, params, grads, state)
        return params, state, loss

    for _ in range(steps):
        idx = rng.choice(n, size=min(batch, n), replace=False)
        params, state, _ = step_fn(params, state, emb[idx], labels[idx])
    return params


@dataclasses.dataclass
class ContextualRouter:
    """Trained entry-tier predictor over scorer-encoder embeddings.

    ``predict`` returns per-position accept probabilities; ``entry_tiers``
    applies the entry rule: the cheapest (lowest) cascade position whose
    predicted accept probability clears ``bar`` — the final position
    catches everything (it accepts unconditionally at serve time).
    """

    params: dict
    n_tiers: int

    def predict(self, emb: np.ndarray) -> np.ndarray:
        """emb (n, d) -> accept probabilities (n, m) float64."""
        emb = np.atleast_2d(np.asarray(emb, np.float32))
        logits = _jitted_forward()(self.params, jnp.asarray(emb))
        return np.asarray(jax.nn.sigmoid(logits), np.float64)

    def entry_tiers(self, emb: np.ndarray, bar: float,
                    probs: np.ndarray | None = None) -> np.ndarray:
        """(n,) int32 entry positions; pass ``probs`` to reuse a
        ``predict`` result instead of re-running the forward."""
        p = self.predict(emb) if probs is None else np.atleast_2d(probs)
        clears = p >= bar
        clears[:, -1] = True                   # final position catches all
        return np.asarray(clears.argmax(1), np.int32)
