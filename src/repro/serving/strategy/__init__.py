"""``repro.serving.strategy`` — contextual routing + online budget
governance: the decision layer between ingress and the cascade.

FrugalGPT learns one static ``(L, tau)`` offline; every query then
enters the cascade at tier 0 under thresholds frozen at build time. This
package makes both decisions *per query* and *per window*:

``router``    ``ContextualRouter`` — a small jax MLP over the
              scorer-encoder embeddings predicting, per query, each
              cascade position's accept probability; queries enter at
              the cheapest position clearing the entry bar (hard
              queries skip dead-weight cheap tiers entirely).
``governor``  ``BudgetGovernor`` — an online dual controller tracking
              realized $/query against a target spend rate, shifting
              the cascade thresholds and the router's entry bar every
              window so long-run cost stays on budget under traffic
              drift.
``degrade``   cost-aware overload degradation — degraded arrivals go to
              the cheapest tier whose *predicted* accept probability
              clears a reduced bar, replacing the unconditional
              pin-to-tier-0.

``ServingStrategy`` composes the three and is what a
``ServingPipeline`` carries (``pipeline.strategy``); with it unset the
serving paths are bit-identical to the fixed cascade. Built by
``serving.builder.build_pipeline(BuildConfig(contextual=True, ...))``.

A third routing mode lives beside fixed-threshold and contextual entry:
``mode="assign"`` swaps greedy per-query routing for *window
assignment* (``repro.serving.assign``) — arrivals are collected into
windows, scored by a meta-model, and dispatched by a budgeted
assignment solver. ``ServingStrategy`` only carries the mode switch and
the ``WindowAssigner``; the window mechanics live in the serving paths.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.strategy.degrade import degrade_entry  # noqa: F401
from repro.serving.strategy.governor import BudgetGovernor  # noqa: F401
from repro.serving.strategy.router import (  # noqa: F401
    ContextualRouter,
    accept_labels,
    train_entry_router,
)


@dataclasses.dataclass
class ServingStrategy:
    """Router + governor + degradation policy for one pipeline.

    Carries lifetime telemetry (entry-tier histogram, spend rate,
    predicted-vs-realized accept counters) across everything served
    through the owning pipeline. Mutation (``observe_request`` /
    ``observe_batch``) must be serialized by the caller — the parallel
    scheduler does it under its own lock, the batch path is
    single-threaded.
    """

    router: ContextualRouter | None = None
    governor: BudgetGovernor | None = None
    entry_bar: float = 0.5              # static bar when no governor
    degrade_relief: float = 0.5
    # routing mode: "entry" (greedy contextual, the default) or
    # "assign" (window assignment, repro.serving.assign) — "assign"
    # needs an assigner; with mode "entry" the assigner is ignored and
    # the strategy behaves exactly as before it existed
    mode: str = "entry"
    assigner: object | None = None      # assign.WindowAssigner
    # accuracy guarantee (repro.serving.guarantee.GuaranteeController):
    # shadow-samples served queries against the reference tier and caps
    # the governor's shift; None = no guarantee layer (bit-identical)
    guarantee: object | None = None

    def __post_init__(self):
        if self.mode not in ("entry", "assign"):
            raise ValueError(f"unknown strategy mode {self.mode!r}; "
                             "expected 'entry' or 'assign'")
        if self.mode == "assign" and self.assigner is None:
            raise ValueError("mode='assign' needs an assigner "
                             "(assign.WindowAssigner; see "
                             "BuildConfig(assign=...))")
        if (self.router is None and self.governor is None
                and self.guarantee is None and self.mode != "assign"):
            raise ValueError("a ServingStrategy needs a router, governor "
                             "and/or guarantee; with none it is a no-op — "
                             "leave pipeline.strategy unset instead")
        if (self.governor is not None and self.guarantee is not None
                and self.governor.guarantee is not self.guarantee):
            raise ValueError("strategy.guarantee and governor.guarantee "
                             "must be the same controller — build both "
                             "via BuildConfig(guarantee=...)")
        self._entry_hist: dict[int, int] = {}
        self._cost_sum = 0.0
        self._n_served = 0
        self._pred_sum = 0.0
        self._accept_sum = 0
        self._accept_n = 0

    # -- decisions ---------------------------------------------------------
    def current_bar(self) -> float:
        return (self.governor.entry_bar() if self.governor is not None
                else self.entry_bar)

    def thresholds(self, base) -> tuple:
        return (self.governor.thresholds() if self.governor is not None
                else tuple(base))

    def route(self, emb: np.ndarray):
        """(entry (n,) int32, probs (n, m) | None) for a batch of
        embeddings; without a router everything enters at tier 0."""
        n = len(emb)
        if self.router is None:
            return np.zeros(n, np.int32), None
        probs = self.router.predict(emb)
        return self.router.entry_tiers(emb, self.current_bar(),
                                       probs=probs), probs

    def degrade_entry(self, probs_row, n_tiers: int) -> int:
        """Entry tier for one overload-degraded arrival."""
        if self.router is None:
            return degrade_entry(None, 0.0)
        return degrade_entry(probs_row, self.current_bar(),
                             self.degrade_relief, n_tiers)

    # -- observation (caller-serialized) -----------------------------------
    def observe_request(self, cost: float, entry: int | None = None,
                        pred: float | None = None,
                        accepted: bool | None = None):
        """One served (non-shed) request: ``cost`` feeds the spend rate
        and governor; ``entry`` the histogram; ``pred``/``accepted``
        the predicted-vs-realized accept-rate telemetry (pass them only
        for normally-routed requests — degraded requests force-accept,
        and cache hits never entered the cascade)."""
        self._cost_sum += float(cost)
        self._n_served += 1
        if self.governor is not None:
            self.governor.observe(float(cost))
        if entry is not None:
            e = int(entry)
            self._entry_hist[e] = self._entry_hist.get(e, 0) + 1
        if pred is not None and accepted is not None:
            self._pred_sum += float(pred)
            self._accept_sum += int(bool(accepted))
            self._accept_n += 1

    def observe_batch(self, costs, entries=None, stopped_at=None,
                      probs=None):
        """Vectorized ``observe_request`` for the closed-batch path:
        ``stopped_at == entries`` is the realized accept. With
        ``entries=None`` only the costs are observed (cache hits, or a
        governor-only strategy)."""
        costs = np.asarray(costs, np.float64)
        if entries is None:
            for c in costs:
                self.observe_request(float(c))
            return
        entries = np.asarray(entries)
        stopped_at = np.asarray(stopped_at)
        for i in range(len(costs)):
            pred = (float(probs[i, entries[i]]) if probs is not None
                    else None)
            self.observe_request(
                costs[i], entry=int(entries[i]), pred=pred,
                accepted=(bool(stopped_at[i] == entries[i])
                          if pred is not None else None))

    # -- telemetry ---------------------------------------------------------
    def snapshot(self, n_tiers: int) -> dict:
        hist = [self._entry_hist.get(j, 0) for j in range(n_tiers)]
        return {
            "mode": self.mode,
            "assign": (self.assigner.snapshot()
                       if self.assigner is not None else None),
            "entry_hist": hist,
            "n_routed": int(sum(hist)),
            "spend_rate": (self._cost_sum / self._n_served
                           if self._n_served else 0.0),
            "entry_bar": self.current_bar(),
            "predicted_accept_rate": (self._pred_sum / self._accept_n
                                      if self._accept_n else None),
            "realized_accept_rate": (self._accept_sum / self._accept_n
                                     if self._accept_n else None),
            "governor": (self.governor.snapshot()
                         if self.governor is not None else None),
            "guarantee": (self.guarantee.snapshot()
                          if self.guarantee is not None else None),
        }
