"""Online budget governor: dual-controller threshold adaptation.

The builder learns ``(L, tau)`` offline under a *training-distribution*
budget. Live traffic drifts — a harder query mix escalates more, and the
fixed cascade quietly overspends (or underspends accuracy it could
afford). The governor closes the loop: it tracks the realized $/query on
the live stream and solves the budgeted-accuracy trade-off's dual
problem online — a Lagrange-style multiplier ``lam`` integrates the
window-level budget error, and a bounded monotone map turns it into one
scalar *shift* applied to every control surface:

  * cascade thresholds: ``tau_j - shift`` — a positive shift (spending
    over target) lowers the accept bars, keeping more traffic on cheap
    tiers; a negative shift raises them, converting spare budget into
    accuracy;
  * the contextual router's entry bar: ``bar - shift`` — the same dial
    applied to where queries *enter* the cascade;
  * the completion cache's ``min_score`` confidence floor:
    ``floor - shift`` — overspending loosens the floor so more answers
    become reusable (cache hits are free), spare budget tightens it so
    only high-confidence answers are ever replayed;
  * the completion cache's *similarity threshold*: overspending lowers
    it toward the slack's share of the shift (more near-duplicates hit
    the free cache), spare budget raises it back toward exactness —
    scaled by ``1 - base`` so a 0.99-tight base moves by basis points,
    not the raw shift (``cache_threshold``);
  * the scheduler's chunk cap and holdback window (``max_chunk``,
    ``holdback_s``, multiplier dials ``base x (1 + shift)``):
    overspending grows chunks and lets them fill longer — fuller pow2
    buckets amortize better, trading latency for $ — while spare budget
    shrinks them, spending $ on lower holdback latency.

Both updates happen once per ``window`` observed queries, so the
controller reacts within a few windows of a drift and cannot thrash on
single-query noise. ``shift`` saturates at ``max_shift`` (tanh), so a
persistent infeasible target degrades gracefully instead of slamming
every threshold to 0/1.

The governor is deliberately dumb about *why* spend moved — traffic mix,
tier pricing, cache hit-rate collapse all look the same through the
realized rate, which is exactly what makes the control robust.

**Second dual constraint — the accuracy floor.** With a
``repro.serving.guarantee.GuaranteeController`` attached
(``guarantee=``), the dual problem gains a guarantee-side multiplier:
the controller's sequential test turns shadow comparisons against the
reference tier into a *cap* on the shift, and every accuracy-relevant
surface (thresholds, entry bar, cache floor, cache similarity) uses
``effective_shift = min(shift, cap)``. The cost side may want to
loosen (positive shift) but the guarantee can veto down to
``-max_shift`` (force tightening) whenever the gap-to-reference is not
certified ``<= delta``. The latency dials (``max_chunk``,
``holdback_s``) keep the raw cost shift — chunking trades $, not
answer quality.

Concurrency: mutate (``observe``) under one caller-side serialization
domain — the parallel scheduler calls it under its own lock, the batch
path is single-threaded. Reads (``thresholds``/``entry_bar``) return
freshly-built tuples/floats and may race an update harmlessly.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np


@dataclasses.dataclass
class BudgetGovernor:
    """Tracks realized $/query against ``budget_rate`` and shifts the
    cascade thresholds + router entry bar to hold it."""

    budget_rate: float                  # target USD per served query
    base_thresholds: tuple              # the learned (offline) taus
    base_bar: float = 0.5               # the router's entry bar
    base_min_score: float | None = None  # completion-cache score floor
    base_threshold: float | None = None  # completion-cache similarity
                                         # threshold (None = not owned)
    window: int = 64                    # queries per controller update
    eta: float = 0.5                    # dual step size (per window)
    max_shift: float = 0.35             # saturation of the threshold shift
    lam_max: float = 4.0                # dual variable clip
    trace_len: int = 256                # most recent windows kept in trace
    guarantee: object | None = None     # GuaranteeController (accuracy
                                        # floor — caps the shift)

    def __post_init__(self):
        if self.budget_rate <= 0:
            raise ValueError(f"budget_rate must be > 0, got "
                             f"{self.budget_rate}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if not 0.0 < self.max_shift <= 1.0:
            raise ValueError("max_shift must be in (0, 1]")
        self.base_thresholds = tuple(float(t) for t in self.base_thresholds)
        self.lam = 0.0
        self.shift = 0.0
        self._win_cost = 0.0
        self._win_n = 0
        self._total_cost = 0.0
        self._total_n = 0
        self.dropped_obs = 0
        # one snapshot per window update; bounded — the governor
        # outlives individual batches/streams, so an unbounded trace
        # (and its per-snapshot copy) would grow with service lifetime
        self.trace: collections.deque = collections.deque(
            maxlen=self.trace_len)

    # -- observation -------------------------------------------------------
    def observe(self, cost: float, n: int = 1):
        """Record ``n`` served queries costing ``cost`` USD in total;
        runs a controller update whenever a window fills.

        Invalid observations are dropped, not folded: a NaN or negative
        cost (the failed-tier path produces NaN scores one hop away)
        would poison ``lam`` and propagate through ``tanh`` into every
        governed threshold, and ``n <= 0`` would corrupt the window
        accounting. Drops are counted in ``dropped_obs``."""
        cost = float(cost)
        n = int(n)
        if n <= 0 or not np.isfinite(cost) or cost < 0.0:
            self.dropped_obs += 1
            return
        self._win_cost += cost
        self._win_n += n
        self._total_cost += cost
        self._total_n += n
        while self._win_n >= self.window:
            self._update()

    def observe_many(self, costs) -> None:
        costs = np.asarray(costs, np.float64)
        ok = np.isfinite(costs) & (costs >= 0.0)
        self.dropped_obs += int(len(costs) - ok.sum())
        costs = costs[ok]
        if len(costs):
            self.observe(float(costs.sum()), len(costs))

    def _update(self):
        """Consume ONE window's worth of observations (a batched observe
        can span several windows — each gets its own dual step, at the
        pool's average rate)."""
        realized = self._win_cost / self._win_n
        err = (realized - self.budget_rate) / self.budget_rate
        self.lam = float(np.clip(self.lam + self.eta * err,
                                 -self.lam_max, self.lam_max))
        self.shift = float(self.max_shift * np.tanh(self.lam))
        self.trace.append({
            "n_seen": self._total_n,
            "window_rate": realized,
            "lam": self.lam,
            "shift": self.shift,
            "thresholds": self.thresholds(),
        })
        self._win_cost -= realized * self.window
        self._win_n -= self.window
        if self._win_n <= 0:
            self._win_cost = 0.0
            self._win_n = 0

    # -- control surfaces --------------------------------------------------
    def effective_shift(self) -> float:
        """Cost shift after the guarantee veto: ``min(shift, cap)``.

        Without a guarantee controller this IS ``shift`` (bit-identical
        behaviour); with one, the accuracy floor clamps cost-driven
        loosening and can force tightening (negative cap)."""
        if self.guarantee is None:
            return self.shift
        return min(self.shift, self.guarantee.shift_cap(self.max_shift))

    def thresholds(self) -> tuple:
        """Current cascade accept thresholds (len = m - 1)."""
        s = self.effective_shift()
        return tuple(float(np.clip(t - s, 0.0, 1.0))
                     for t in self.base_thresholds)

    def entry_bar(self) -> float:
        """Current contextual-router entry bar."""
        return float(np.clip(self.base_bar - self.effective_shift(),
                             0.0, 1.0))

    def min_score(self) -> float | None:
        """Current completion-cache confidence floor (None when the
        governor was not given one to own). Overspend (positive shift)
        *loosens* the floor — more answers become cacheable, diverting
        traffic to free hits; spare budget tightens it."""
        if self.base_min_score is None:
            return None
        return float(np.clip(self.base_min_score - self.effective_shift(),
                             0.0, 1.0))

    def cache_threshold(self) -> float | None:
        """Current completion-cache similarity threshold (None when not
        owned). Overspend lowers it — near-duplicates start hitting the
        free cache — spare budget raises it toward exactness. The move
        is scaled by the slack ``1 - base``: similarity thresholds live
        within basis points of 1.0, where the raw threshold shift would
        be a sledgehammer."""
        if self.base_threshold is None:
            return None
        s = self.effective_shift()
        return float(np.clip(
            self.base_threshold - s * (1.0 - self.base_threshold),
            0.0, 1.0))

    def max_chunk(self, base: int) -> int:
        """Scheduler chunk cap under the dial: ``base x (1 + shift)``,
        never below 1. Overspend grows chunks (fuller pow2 buckets,
        better batch amortization per $), spare budget shrinks them
        (lower holdback latency). Like ``thresholds(base)``, the base
        lives with the caller; the governor only owns the scale."""
        return max(1, int(round(base * (1.0 + self.shift))))

    def holdback_s(self, base: float) -> float:
        """Scheduler holdback window under the same multiplier dial:
        overspend lets partial chunks wait longer for fill, spare budget
        ships them sooner."""
        return max(0.0, float(base * (1.0 + self.shift)))

    def window_budget(self, n: int) -> float:
        """$ an ``n``-query assignment window may commit
        (``repro.serving.assign``): the target rate times the window,
        tightened by the live spend pressure — a stream running hot gets
        leaner windows until the dual controller re-centers. Spare
        budget is NOT handed out here (no ``1 + |shift|`` loosening):
        the assignment solver already spends up to its budget, so the
        squeeze only needs to act one way."""
        return float(self.budget_rate * n * (1.0 - max(0.0, self.shift)))

    # -- telemetry ---------------------------------------------------------
    def realized_rate(self) -> float:
        """Lifetime $/query over everything observed."""
        return self._total_cost / self._total_n if self._total_n else 0.0

    def snapshot(self) -> dict:
        return {
            "budget_rate": self.budget_rate,
            "realized_rate": self.realized_rate(),
            "n_observed": self._total_n,
            "dropped_obs": self.dropped_obs,
            "lam": self.lam,
            "shift": self.shift,
            "effective_shift": self.effective_shift(),
            "thresholds": self.thresholds(),
            "entry_bar": self.entry_bar(),
            "min_score": self.min_score(),
            "cache_threshold": self.cache_threshold(),
            "chunk_scale": 1.0 + self.shift,
            "holdback_scale": 1.0 + self.shift,
            "trace": list(self.trace),
        }
