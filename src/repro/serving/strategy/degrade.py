"""Cost-aware overload degradation.

The scheduler's original ``degrade`` overload policy pinned every
overflow arrival to tier 0 unconditionally — availability preserved, but
a hard query gets the cheapest tier's (likely wrong) answer even when a
mid-priced tier would have served it acceptably. With a contextual
router available, overload can instead route each arrival to the
*cheapest tier whose predicted accept probability clears a reduced bar*
(the normal entry bar scaled by ``relief`` < 1): easy queries still land
on tier 0, hard queries land on the cheapest tier the router believes in
at the relaxed standard, and only the router's final-position fallback
sends anything to the top tier under load.

The degraded request's answer is still accepted regardless of its
realized score — overload trades accuracy, not availability — and, as
before, a forced answer is never inserted into the completion cache.

Without a router (``probs is None``), this degrades — appropriately — to
the legacy pin-to-tier-0 behaviour.
"""
from __future__ import annotations

import numpy as np


def degrade_entry(probs, bar: float, relief: float = 0.5,
                  n_tiers: int = 1) -> int:
    """Entry tier for ONE overload-degraded arrival.

    probs: (m,) predicted accept probabilities from the contextual
    router, or None (no router -> legacy tier 0). ``bar`` is the current
    entry bar (governor-adjusted); ``relief`` in (0, 1] scales it down —
    under overload a tier only needs to clear ``bar * relief``.
    """
    if probs is None:
        return 0
    if not 0.0 < relief <= 1.0:
        raise ValueError(f"relief must be in (0, 1], got {relief}")
    p = np.asarray(probs, np.float64).ravel()
    if len(p) != n_tiers:
        raise ValueError(f"got {len(p)} tier probabilities for "
                         f"{n_tiers} tiers")
    clears = p >= bar * relief
    clears[-1] = True                    # final position catches everything
    return int(np.argmax(clears))
