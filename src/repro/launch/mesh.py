"""Production mesh construction (DESIGN.md §5).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod: 16x16 = 256 chips; multi-pod: 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes of a mesh ('pod' included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
