"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute term    = HLO_FLOPs_per_chip / peak_FLOPs
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_bytes_per_chip / link_bw

HLO terms use the scan-corrected per-device numbers from dryrun.py (the
SPMD module is per-chip). MODEL_FLOPS = 6*N_active*D (train) or
2*N_active*D (inference) per token; the ratio MODEL_FLOPS/HLO_FLOPs
exposes remat/dispatch/redundancy waste.
"""
from __future__ import annotations

import json

from repro.configs.base import INPUT_SHAPES, active_param_count
from repro.configs.registry import get_arch

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole step (all chips)."""
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch              # one token per sequence
    return 2.0 * n_active * tokens


def _advice(dom: str, arch: str, shape: str) -> str:
    cfg = get_arch(arch)
    if dom == "collective":
        if cfg.moe is not None:
            return ("shard_map sort-based MoE dispatch with explicit "
                    "all-to-all; bf16 FSDP gathers")
        return "overlap all-gathers with compute; reduce-scatter grads"
    if dom == "memory":
        if INPUT_SHAPES[shape].kind == "decode":
            return ("KV-cache is re-read per token: quantize cache to int8 "
                    "or shrink with MLA/ring buffers")
        return "fuse attention (flash kernel) to avoid score materialization"
    return "increase arithmetic intensity: larger per-chip batch or seq tile"


def analyze(results_path: str, multi_pod: bool | None = False):
    with open(results_path) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if r.get("status") != "ok":
            continue
        if multi_pod is not None and r.get("multi_pod") != multi_pod:
            continue
        n = r["n_devices"]
        fl = r.get("flops_per_device_corrected", r["flops_per_device"])
        by = r.get("bytes_per_device_corrected", r["bytes_per_device"])
        coll = r["collectives"]["total"]
        t_c = fl / PEAK_FLOPS
        t_m = by / HBM_BW
        t_x = coll / LINK_BW
        dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
        mf = model_flops(r["arch"], r["shape"])
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "n_devices": n,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops_global": fl * n,
            "useful_ratio": mf / (fl * n) if fl else 0.0,
            "advice": _advice(dom, r["arch"], r["shape"]),
        })
    return rows


def to_markdown(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | what would move it |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['advice']} |")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    rows = analyze(sys.argv[1] if len(sys.argv) > 1 else
                   "dryrun_results.json")
    print(to_markdown(rows))
