"""HLO text analysis: collective-bytes accounting for the roofline.

cost_analysis() does not report collective traffic, so we parse the
compiled (post-SPMD) HLO and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op. Two subtleties:

  * shapes in the partitioned module are per-shard -> global bytes =
    per-shard bytes x n_devices;
  * a jax.lax.scan lowers to a `while` whose body appears ONCE in the
    module — collectives inside it must be multiplied by the loop trip
    count. We parse the computation graph structurally: per-computation
    collective bytes, then walk call/while edges, multiplying while
    bodies by the trip count recovered from the loop condition constant.

(The same body-once caveat applies to cost_analysis FLOPs/bytes; dryrun
corrects those by lowering a zero-period "base" variant and scaling the
difference — see dryrun.py.)
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+(?:\.\d+)?\s*=\s*(\([^=]*?\)|[\w\[\],{}\/ ]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|while|"
    r"call|conditional)"
    r"(-start)?\(")
_ATTR_RE = re.compile(r"(body|condition|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_module(hlo_text: str):
    """Split into computations; per computation record collectives and
    call/while edges."""
    comps = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line.strip()) if line.rstrip().endswith("{") else None
        if mc and ("->" in line):
            cur = mc.group(1)
            comps[cur] = {"colls": defaultdict(int), "counts": defaultdict(int),
                          "calls": [], "whiles": [], "consts": []}
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        for m in _CONST_RE.finditer(line):
            comps[cur]["consts"].append(int(m.group(1)))
        mo = _OP_RE.match(line)
        if not mo:
            continue
        shape_str, op, is_start = mo.group(1), mo.group(2), mo.group(3)
        if op in COLLECTIVE_OPS:
            comps[cur]["colls"][op] += _shape_bytes(shape_str)
            comps[cur]["counts"][op] += 1
        elif op == "while":
            attrs = dict(_ATTR_RE.findall(line))
            comps[cur]["whiles"].append((attrs.get("body"),
                                         attrs.get("condition")))
        elif op in ("call", "conditional"):
            for _, target in _ATTR_RE.findall(line):
                comps[cur]["calls"].append(target)
    return comps, entry


def _trip_count(comps, cond_name) -> int:
    """Heuristic: the largest constant in the loop condition computation."""
    c = comps.get(cond_name)
    if not c or not c["consts"]:
        return 1
    return max(1, max(c["consts"]))


def _accumulate(comps, name, memo):
    if name not in comps:
        return {}, {}
    if name in memo:
        return memo[name]
    c = comps[name]
    by = defaultdict(int, c["colls"])
    cnt = defaultdict(int, c["counts"])
    for callee in c["calls"]:
        sub_b, sub_c = _accumulate(comps, callee, memo)
        for k, v in sub_b.items():
            by[k] += v
        for k, v in sub_c.items():
            cnt[k] += v
    for body, cond in c["whiles"]:
        trips = _trip_count(comps, cond)
        sub_b, sub_c = _accumulate(comps, body, memo)
        for k, v in sub_b.items():
            by[k] += v * trips
        for k, v in sub_c.items():
            cnt[k] += v * trips
    memo[name] = (dict(by), dict(cnt))
    return memo[name]


def collective_bytes(hlo_text: str) -> dict:
    """Trip-count-aware per-shard collective bytes from compiled HLO."""
    comps, entry = parse_module(hlo_text)
    if entry is None:
        return {"bytes": {}, "counts": {}, "total": 0}
    by, cnt = _accumulate(comps, entry, {})
    return {"bytes": by, "counts": cnt, "total": sum(by.values())}
