"""Input specs + step functions for the multi-pod dry-run.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input — shardable, never allocated. Decode
shapes lower ``serve_step`` (one token against a seq_len KV cache);
train_4k lowers ``train_step`` (loss + grad + AdamW); prefill lowers the
cache-building forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.models import transformer as T
from repro.training.optim import OptConfig, adamw_update, init_opt_state

OPT = OptConfig(lr=3e-4, warmup=100, total_steps=10_000)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for the data batch of one step."""
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if shape.kind == "decode":
        out["tokens"] = _sds((b, 1), jnp.int32)
        return out
    if cfg.embed_inputs:
        out["tokens"] = _sds((b, s), jnp.int32)
        if cfg.vision_tokens:
            out["vision_embeds"] = _sds((b, cfg.vision_tokens, cfg.d_model),
                                        cfg.dtype)
            out["mrope_pos"] = _sds((3, b, s), jnp.int32)
    else:
        out["embeds"] = _sds((b, s, cfg.d_model), cfg.dtype)
    if shape.kind == "train":
        out["labels"] = _sds((b, s), jnp.int32)
    return out


def params_specs(cfg: ModelConfig, dtype=None) -> dict:
    """Shape-only param tree (via eval_shape; nothing allocated)."""
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.dtype(dtype)), shapes)
    return shapes


def opt_specs(params_shapes) -> dict:
    return jax.eval_shape(init_opt_state, params_shapes)


def cache_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Everything the step function consumes, as ShapeDtypeStructs."""
    shape = INPUT_SHAPES[shape_name]
    out = {"batch": batch_specs(cfg, shape)}
    if shape.kind == "train":
        p = params_specs(cfg, "float32")
        out["params"] = p
        out["opt_state"] = opt_specs(p)
    else:
        out["params"] = params_specs(cfg, cfg.dtype)
        if shape.kind == "decode":
            out["cache"] = cache_specs(cfg, shape)
            out["pos"] = _sds((), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, remat: bool = True):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = T.forward_train(p, batch, cfg, remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        params, opt_state, om = adamw_update(OPT, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return T.prefill(params, batch, cfg)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        return T.decode_step(params, cache, tokens, pos, cfg)
    return serve_step


def make_step(cfg: ModelConfig, shape_name: str):
    kind = INPUT_SHAPES[shape_name].kind
    if kind == "train":
        return make_train_step(cfg)
    if kind == "prefill":
        return make_prefill_step(cfg)
    return make_serve_step(cfg)
