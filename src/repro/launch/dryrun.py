import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x input-shape) on the
# production mesh; record memory_analysis / cost_analysis / collective
# schedule for the roofline (EXPERIMENTS.md §Dry-run, §Roofline).
# NOTE: XLA_FLAGS must be set before any other import (jax locks device
# count on first init), hence the two lines above everything else.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape decode_32k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes --out results.json

import argparse
import contextlib
import dataclasses


def _nullcontext():
    return contextlib.nullcontext()
import json
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHS, get_arch
from repro.launch import specs as S
from repro.launch.hlo import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.sharding import policy
from repro.sharding import rules as R


def _jit_step(cfg, shape_name, mesh):
    shape = INPUT_SHAPES[shape_name]
    spec = S.input_specs(cfg, shape_name)
    step = S.make_step(cfg, shape_name)
    b_sh = R.batch_shardings(spec["batch"], mesh)

    if shape.kind == "train":
        p_sh = R.params_shardings(spec["params"], mesh, fsdp=True)
        o_sh = {"mu": R.params_shardings(spec["opt_state"]["mu"], mesh,
                                         fsdp=True),
                "nu": R.params_shardings(spec["opt_state"]["nu"], mesh,
                                         fsdp=True),
                "step": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())}
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        metrics_shapes = jax.eval_shape(step, spec["params"],
                                        spec["opt_state"], spec["batch"])[2]
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh,
                                        jax.tree.map(lambda _: rep,
                                                     metrics_shapes)),
                         donate_argnums=(0, 1))
        args = (spec["params"], spec["opt_state"], spec["batch"])
    elif shape.kind == "prefill":
        p_sh = R.params_shardings(spec["params"], mesh)
        out_shapes = jax.eval_shape(step, spec["params"], spec["batch"])
        lg_sh = R.logits_sharding(mesh, cfg, shape.global_batch)
        c_sh = (R.cache_shardings(out_shapes[1], mesh, cfg)
                if out_shapes[1] is not None else None)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(lg_sh, c_sh))
        args = (spec["params"], spec["batch"])
    else:  # decode
        p_sh = R.params_shardings(spec["params"], mesh)
        c_sh = R.cache_shardings(spec["cache"], mesh, cfg)
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        lg_sh = R.logits_sharding(mesh, cfg, shape.global_batch)
        # donate the KV cache: the decode step updates it in place on
        # real hardware instead of copying seq_len bytes per token
        jitted = jax.jit(step,
                         in_shardings=(p_sh, c_sh, b_sh["tokens"], rep),
                         out_shardings=(lg_sh, c_sh),
                         donate_argnums=(1,))
        args = (spec["params"], spec["cache"], spec["batch"]["tokens"],
                spec["pos"])
    return jitted, args


def _unrolled_variant(cfg, k: int):
    """Variant with k periods UNROLLED into the prefix (no scan). Used to
    measure the true in-context marginal cost of one period: XLA's
    cost_analysis counts a scan (while) body once regardless of trip
    count, and a naive (full - empty) subtraction picks up unrelated
    compile-context differences (measured 22x on mamba2 - see
    EXPERIMENTS.md #Perf B2), so we extrapolate from two unrolled
    compiles instead."""
    k = min(k, cfg.n_periods)
    return dataclasses.replace(
        cfg, n_layers=len(cfg.prefix) + k * len(cfg.period) + len(cfg.suffix),
        prefix=cfg.prefix + cfg.period * k, period=(), n_periods=0,
        name=f"{cfg.name}-u{k}")


def _compile_and_measure(cfg, shape_name, mesh):
    jitted, args = _jit_step(cfg, shape_name, mesh)
    t0 = time.time()
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
        },
    }


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, correct_scan: bool = True,
               constrain_activations: bool = True) -> dict:
    cfg = get_arch(arch)
    ok, why = cfg.supports_shape(shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    pol = policy.policy(mesh) if constrain_activations else _nullcontext()
    try:
        with mesh, pol:
            main = _compile_and_measure(cfg, shape_name, mesh)
            u2 = u4 = None
            if correct_scan and cfg.n_periods > 1:
                u2 = _compile_and_measure(_unrolled_variant(cfg, 2),
                                          shape_name, mesh)
                if cfg.n_periods > 2:
                    u4 = _compile_and_measure(_unrolled_variant(cfg, 4),
                                              shape_name, mesh)
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "status": "failed",
                "multi_pod": multi_pod, "error": f"{type(e).__name__}: {e}"}

    n_dev = mesh.size
    res = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "multi_pod": multi_pod, "n_devices": n_dev,
        "n_periods": cfg.n_periods, **main,
    }
    # cost_analysis counts a scan (while) body ONCE -> correct FLOPs/bytes
    # by extrapolating the per-period marginal measured on UNROLLED
    # variants (u2, u4). The HLO collective parser is trip-count aware
    # and needs no correction.
    if u2 is not None:
        n = cfg.n_periods
        k2 = min(2, n)
        k4 = min(4, n)
        for key in ("flops_per_device", "bytes_per_device"):
            if u4 is not None and k4 > k2:
                body = max(0.0, (u4[key] - u2[key]) / (k4 - k2))
                res[key + "_corrected"] = u2[key] + body * (n - k2)
            else:
                res[key + "_corrected"] = u2[key]
        res["u2_flops_per_device"] = u2["flops_per_device"]
        if u4 is not None:
            res["u4_flops_per_device"] = u4["flops_per_device"]
    else:
        res["flops_per_device_corrected"] = main["flops_per_device"]
        res["bytes_per_device_corrected"] = main["bytes_per_device"]
    if verbose:
        ms = res["memory"]
        print(f"[{arch} x {shape_name} x {'512' if multi_pod else '256'}] "
              f"OK lower={main['lower_s']:.0f}s compile={main['compile_s']:.0f}s "
              f"flops/dev={res['flops_per_device_corrected']:.3e} "
              f"args={ms['argument_size_in_bytes']/2**30:.2f}GiB "
              f"temp={ms['temp_size_in_bytes']/2**30:.2f}GiB "
              f"coll={res['collectives']['total']/2**20:.1f}MiB/shard")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-constrain", action="store_true",
                    help="disable activation sharding constraints (A/B)")
    args = ap.parse_args()

    runs = []
    if args.all:
        for a in ARCHS:
            for s in INPUT_SHAPES:
                runs.append((a, s))
    else:
        runs.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for a, s in runs:
        for mp in meshes:
            res = dryrun_one(a, s, multi_pod=mp,
                             constrain_activations=not args.no_constrain)
            results.append(res)
            if res["status"] == "skipped":
                print(f"[{a} x {s}] SKIP: {res['reason']}")
            elif res["status"] == "failed":
                print(f"[{a} x {s} x {'512' if mp else '256'}] "
                      f"FAILED: {res['error']}")
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
