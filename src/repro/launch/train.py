"""Training launcher.

CPU/demo:    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
                 --reduced --steps 20
Production:  the same entry point with --mesh pod|multipod builds the
             pjit train step exactly as the dry-run does (requires TPU
             devices; on this container use repro.launch.dryrun instead).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.training.optim import OptConfig
from repro.training.train_loop import train_lm
from repro.training import checkpoint


def synthetic_lm_data(cfg, batch: int, seq: int, seed: int = 0):
    """Token stream with learnable n-gram structure (repeat + offset)."""
    rng = np.random.default_rng(seed)

    def data_fn(step):
        base = rng.integers(3, cfg.vocab, size=(batch, seq), dtype=np.int32)
        evens = base[:, 2::2]
        base[:, 2::2] = (base[:, 1:1 + 2 * evens.shape[1]:2] + 1) % cfg.vocab
        labels = np.roll(base, -1, axis=1)
        return {"tokens": base, "labels": labels}

    return data_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.embed_inputs:
        raise SystemExit(f"{cfg.name} is encoder-only; use the classifier "
                         f"trainer (repro.training.train_loop)")
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab} on {jax.device_count()} device(s)")
    params, hist = train_lm(
        cfg, data_fn=synthetic_lm_data(cfg, args.batch, args.seq),
        steps=args.steps,
        opt=OptConfig(lr=args.lr, warmup=max(1, args.steps // 10),
                      total_steps=args.steps),
        log_every=max(1, args.steps // 10))
    print(f"final loss {hist[-1]['loss']:.3f} "
          f"(first {hist[0]['loss']:.3f})")
    if args.ckpt:
        checkpoint.save(args.ckpt, params, meta={"arch": cfg.name,
                                                 "steps": args.steps})
        print(f"checkpoint -> {args.ckpt}.npz")


if __name__ == "__main__":
    main()
