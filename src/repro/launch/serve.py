"""Serving launcher: FrugalGPT cascade over generation-capable tiers.

Demo (CPU):
  PYTHONPATH=src python -m repro.launch.serve --requests 200

Builds a 3-tier marketplace of reduced-config models (cheap -> expensive),
trains the scorer, learns (L, tau) with the router optimizer, then serves
a batched request stream tier-by-tier with compaction. This is the
serving entry point a real deployment would point at the production mesh
(tiers sharded with pjit per DESIGN.md §5).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import neural_market as NM
from repro.core import scorer as SC
from repro.core.router import RouterConfig, learn_cascade
from repro.data import synthetic
from repro.serving.engine import CascadeServer, Tier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="headlines",
                    choices=list(synthetic.N_CLASSES))
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--budget-frac", type=float, default=0.3,
                    help="budget as a fraction of top-tier cost")
    ap.add_argument("--tiers", default="GPT-J,ChatGPT,GPT-4")
    ap.add_argument("--train-steps", type=int, default=150)
    args = ap.parse_args()

    keep = args.tiers.split(",")
    NM.TIERS = {k: v for k, v in NM.TIERS.items() if k in keep}
    for k in NM.TIERS:
        NM.TIERS[k]["steps"] = min(NM.TIERS[k]["steps"], args.train_steps)

    print("== tiers ==")
    apis = NM.train_marketplace(args.task, seed=0, verbose=True)
    train = synthetic.sample(args.task, 400, seed=11)
    data, answers = NM.collect_market_data(apis, train.tokens, train.labels)
    print("tier accuracy:",
          {n: round(float(a), 3)
           for n, a in zip(data.names, np.asarray(data.accuracy()))})

    k = len(apis)
    sp = SC.train_scorer(np.repeat(train.tokens, k, axis=0),
                         answers.reshape(-1),
                         np.asarray(data.correct).reshape(-1), steps=200)
    s_train = np.stack([SC.score(sp, train.tokens, answers[:, j])
                        for j in range(k)], axis=1)
    budget = float(data.cost[:, -1].mean()) * args.budget_frac
    cas, m = learn_cascade(data, jnp.asarray(s_train), budget,
                           RouterConfig(top_lists=10, sample=256))
    print(f"cascade: {cas.describe(data.names)} "
          f"(train acc {m['acc']:.3f}, ${m['avg_cost']:.6f}/query)")

    test = synthetic.sample(args.task, args.requests, seed=77)
    tiers = [Tier(apis[i].name, apis[i].answer, apis[i].query_cost)
             for i in cas.apis]
    server = CascadeServer(tiers, cas.thresholds,
                           lambda t, ans: SC.score(sp, t, ans))
    res = server.serve(test.tokens)
    acc = float((res["answers"] == test.labels).mean())
    top = apis[-1].query_cost(test.tokens).mean()
    print(f"served {args.requests} requests in {res['latency_s']:.1f}s "
          f"(tiers {res['tier_counts']}): acc {acc:.3f}, "
          f"${res['cost'].mean():.6f}/query "
          f"({100 * (1 - res['cost'].mean() / top):.0f}% below top-tier-only)")


if __name__ == "__main__":
    main()
