"""Serving launcher: the unified FrugalGPT pipeline (cache + prompt
adaptation + cascade) over a batched request stream.

Demo (CPU):
  PYTHONPATH=src python -m repro.launch.serve --requests 200
  PYTHONPATH=src python -m repro.launch.serve --requests 200 \\
      --stream --rate 500        # parallel tier scheduler, Poisson trace
  PYTHONPATH=src python -m repro.launch.serve --requests 200 --stream \\
      --deadline-ms 100 --queue-cap 64 --overload degrade   # SLO mode
  PYTHONPATH=src python -m repro.launch.serve --requests 200 \\
      --contextual --budget-rate 3e-5     # entry routing + spend governor
  PYTHONPATH=src python -m repro.launch.serve --requests 200 \\
      --assign --window-budget 1e-3       # budgeted window assignment
  PYTHONPATH=src python -m repro.launch.serve --requests 400 \\
      --contextual --budget-rate 3e-5 --guarantee --acc-gap 0.05 \\
      --shadow-frac 0.1    # accuracy floor: P(gap > delta) <= alpha
  PYTHONPATH=src python -m repro.launch.serve --requests 200 --stream \\
      --devices 4 --on-device-compact     # per-tier device placement
  PYTHONPATH=src python -m repro.launch.serve --requests 200 --stream \\
      --mesh 8,1                          # per-tier mesh slices (sharded)

Thin CLI over ``repro.serving.build_pipeline`` — this is the entry point
a real deployment would point at the production mesh (tiers sharded with
pjit per DESIGN.md §5; ``--mesh`` is that path on a forced-CPU grid).
"""
from __future__ import annotations

import argparse
import os
import sys

# --devices N / --mesh R,C force an N- (R*C-) device host platform (CPU
# dev boxes have one device; tier placement/sharding needs several). XLA
# locks the device count at first use, so the flag must land in the
# environment BEFORE anything imports jax — pre-parse it here, ahead of
# the repro imports below. Both `--flag V` and `--flag=V` spellings
# count; if the user already exported their own XLA_FLAGS we leave it
# alone and main() warns when the resulting device count falls short.


def _preparse(argv, flag: str) -> str | None:
    for i, a in enumerate(argv):
        if a == flag and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith(flag + "="):
            return a.split("=", 1)[1]
    return None


def _parse_mesh(spec: str | None) -> tuple[int, int] | None:
    if spec is None:
        return None
    parts = spec.split(",")
    if len(parts) != 2 or not all(p.strip().isdigit() for p in parts):
        return None
    return int(parts[0]), int(parts[1])


def _parse_faults(spec: str, n_tiers: int):
    """--faults grammar: one FaultSpec broadcast to every tier, or
    pipe-separated ``J:SPEC`` entries targeting tier J (mixing the two
    forms is an error). The per-entry grammar is ``FaultSpec.parse``'s.
    A window value like ``outage=0.1:0.5`` also contains a colon, so a
    tier prefix only counts when the head is a bare integer."""
    from repro.serving.resilience import FaultSpec
    entries = [e.strip() for e in spec.split("|") if e.strip()]
    per_tier: list = [None] * n_tiers
    broadcast = None
    for e in entries:
        head, sep, rest = e.partition(":")
        if sep and "=" not in head and head.strip().isdigit():
            j = int(head)
            if not 0 <= j < n_tiers:
                raise ValueError(f"tier {j} out of range for "
                                 f"{n_tiers} tiers")
            per_tier[j] = FaultSpec.parse(rest)
        else:
            if broadcast is not None:
                raise ValueError("multiple broadcast entries; use "
                                 "'J:SPEC' to target tiers")
            broadcast = FaultSpec.parse(e)
    if broadcast is not None and any(s is not None for s in per_tier):
        raise ValueError("mix of broadcast and per-tier 'J:SPEC' "
                         "entries; pick one form")
    return broadcast if broadcast is not None else per_tier


_n = _preparse(sys.argv, "--devices")
_mesh = _parse_mesh(_preparse(sys.argv, "--mesh"))
if _mesh is not None and (_n is None or not _n.isdigit()
                          or int(_n) < _mesh[0] * _mesh[1]):
    _n = str(_mesh[0] * _mesh[1])
if (_n is not None and _n.isdigit() and int(_n) > 1
        and "XLA_FLAGS" not in os.environ):
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={_n}"

from repro.core.router import RouterConfig            # noqa: E402
from repro.data import synthetic                      # noqa: E402
from repro.serving import BuildConfig, build_pipeline  # noqa: E402
from repro.serving.ingress import poisson_arrivals    # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="headlines",
                    choices=list(synthetic.N_CLASSES))
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--budget-frac", type=float, default=0.3,
                    help="budget as a fraction of top-tier cost")
    ap.add_argument("--tiers", default="GPT-J,ChatGPT,GPT-4")
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--no-prompt-adaptation", action="store_true")
    ap.add_argument("--stream", action="store_true",
                    help="replay a Poisson arrival trace through the "
                         "streaming path instead of one closed batch")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="stream mode: mean arrival rate (requests/s)")
    ap.add_argument("--max-chunk", type=int, default=32,
                    help="stream mode: max requests per tier chunk")
    ap.add_argument("--serial", action="store_true",
                    help="stream mode: serial continuous batcher instead "
                         "of the parallel SLO-aware tier scheduler")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="stream mode: per-request latency SLO; partial "
                         "chunks ship when the head-of-line request "
                         "would miss it")
    ap.add_argument("--holdback-ms", type=float, default=20.0,
                    help="stream mode: max wait for chunk fill")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="stream mode: bounded per-tier wait queue "
                         "(enables backpressure/shedding)")
    ap.add_argument("--overload", default="reject",
                    choices=["reject", "degrade"],
                    help="stream mode: policy once the queue cap is hit — "
                         "shed arrivals, or answer them from the cheapest "
                         "tier whose predicted score clears a reduced bar "
                         "(tier 0 without --contextual)")
    ap.add_argument("--contextual", action="store_true",
                    help="train a contextual entry-tier router: each "
                         "query enters the cascade at the cheapest tier "
                         "whose predicted accept probability clears the "
                         "entry bar")
    ap.add_argument("--entry-bar", type=float, default=0.5,
                    help="contextual mode: predicted-accept probability "
                         "needed to enter a tier")
    ap.add_argument("--assign", action="store_true",
                    help="window-assignment routing (third mode, beside "
                         "the fixed cascade and --contextual): score "
                         "each arrival window's (query, tier) grid with "
                         "a trained meta-model and solve every entry "
                         "tier jointly, on device, under a per-window "
                         "$ budget and per-tier capacity caps")
    ap.add_argument("--window-size", type=int, default=32,
                    help="assign mode: queries assigned together per "
                         "window")
    ap.add_argument("--window-budget", type=float, default=None,
                    help="assign mode: $ per full window (pro-rated to "
                         "actual fill); default derives the budget from "
                         "--budget-rate's governor, or unbounded with "
                         "neither")
    ap.add_argument("--capacity-frac", type=float, default=None,
                    help="assign mode: cap each tier at this fraction "
                         "of a window (derated by live tier utilization "
                         "on the stream scheduler)")
    ap.add_argument("--budget-rate", type=float, default=None,
                    help="target spend rate (USD/query): an online "
                         "governor shifts the cascade thresholds and "
                         "entry bar to hold it")
    ap.add_argument("--governor-window", type=int, default=64,
                    help="queries per governor controller update")
    ap.add_argument("--guarantee", action="store_true",
                    help="accuracy-guaranteed frugality (online SMART "
                         "calibration): shadow-sample served queries "
                         "against the reference (top) tier, hold "
                         "anytime-valid sequential confidence intervals "
                         "on the gap-to-reference, and tighten the "
                         "cascade thresholds so P(gap > delta) <= alpha "
                         "— the guarantee side can veto the budget "
                         "governor's cost-driven loosening. Shadow "
                         "invocations are charged to a separate meter")
    ap.add_argument("--acc-gap", type=float, default=0.05,
                    help="guarantee: tolerable accuracy gap delta vs "
                         "the reference tier (disagreement rate)")
    ap.add_argument("--acc-alpha", type=float, default=0.05,
                    help="guarantee: failure probability alpha of the "
                         "sequential guarantee")
    ap.add_argument("--shadow-frac", type=float, default=0.1,
                    help="guarantee: fraction of served queries "
                         "shadow-routed to the reference tier")
    ap.add_argument("--devices", type=int, default=None,
                    help="pin each cascade tier's model to its own "
                         "device, sized by offline traffic share "
                         "(forces an N-device CPU host when the "
                         "platform has fewer; results are bit-identical "
                         "to the shared device)")
    ap.add_argument("--mesh", default=None,
                    help="R,C: shard each cascade tier over its own "
                         "contiguous slice of an RxC device grid (rows "
                         "= data/FSDP axis, cols = tensor axis), sized "
                         "by offline traffic share; forces an R*C-"
                         "device CPU host when the platform has fewer. "
                         "C=1 slices are bit-identical to the unsharded "
                         "pipeline. Mutually exclusive with --devices")
    ap.add_argument("--speculate", action="store_true",
                    help="stream mode: speculative cascade execution — "
                         "idle tier workers pre-invoke predicted-reject "
                         "rows still decoding upstream; answers and "
                         "charged cost are bit-identical, only wall-"
                         "clock moves (best with --contextual for the "
                         "router's probabilities and --devices/--mesh "
                         "so tiers overlap on real hardware)")
    ap.add_argument("--spec-depth", type=int, default=1,
                    help="speculation: how many tiers ahead of a row's "
                         "current position may pre-invoke it")
    ap.add_argument("--spec-bar", type=float, default=0.5,
                    help="speculation: router accept-probability floor — "
                         "every intermediate tier must be predicted to "
                         "reject (prob below this) for a row to qualify")
    ap.add_argument("--spec-idle-frac", type=float, default=0.5,
                    help="speculation: cap on wasted device-seconds as a "
                         "fraction of elapsed stream time")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="inject a deterministic seeded fault schedule "
                         "into the tiers. SPEC is comma-separated "
                         "key=value pairs (error=RATE, timeout=RATE, "
                         "spike=RATE@SECS, rlim=START:END, "
                         "outage=START:END, max=N, seed=N) broadcast to "
                         "every tier, or pipe-separated 'J:SPEC' entries "
                         "targeting tier J in --tiers order (the learned "
                         "cascade may keep a subset; specs for dropped "
                         "tiers are dropped with it), e.g. "
                         "'1:error=0.2|2:outage=0.1:0.5'. Without "
                         "--retry/--breaker an injected fault is fatal "
                         "(the no-resilience baseline)")
    ap.add_argument("--retry", type=int, default=None, metavar="N",
                    help="retry TierFault invokes up to N attempts per "
                         "tier call (exponential backoff, deterministic "
                         "jitter, deadline-aware)")
    ap.add_argument("--retry-backoff-ms", type=float, default=20.0,
                    help="base backoff before the first retry")
    ap.add_argument("--breaker", action="store_true",
                    help="per-tier circuit breakers: a tier whose "
                         "recent invokes keep failing trips open and "
                         "pending rows fail over past it until a "
                         "half-open probe succeeds")
    ap.add_argument("--breaker-cooldown-ms", type=float, default=500.0,
                    help="seconds(ms) an open breaker waits before its "
                         "half-open probe")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="batch mode: run the offline executor's "
                         "resilience path on a virtual clock — fault "
                         "windows, retry backoff and latency spikes "
                         "advance virtual time instead of wall-"
                         "sleeping, with identical accounting")
    ap.add_argument("--on-device-compact", nargs="?", const="device",
                    choices=["device", "pallas"], default=None,
                    help="keep the cascade's pending-set compaction on "
                         "device (jitted gather+prefix-sum, or the "
                         "Pallas kernel variant); bit-identical to the "
                         "host path")
    args = ap.parse_args()
    if args.devices is not None and args.devices < 1:
        ap.error("--devices must be >= 1")
    mesh_shape = None
    if args.mesh is not None:
        mesh_shape = _parse_mesh(args.mesh)
        if mesh_shape is None or min(mesh_shape) < 1:
            ap.error("--mesh expects R,C with positive integers")
        if args.devices is not None:
            ap.error("--devices pins tiers to single devices, --mesh "
                     "shards them over slices; pick one")
    need = (args.devices if args.devices is not None
            else mesh_shape[0] * mesh_shape[1] if mesh_shape else None)
    if need is not None and need > 1:
        import jax
        avail = len(jax.local_devices())
        if avail < need:
            # a pre-existing XLA_FLAGS wins over the pre-parse above
            print(f"warning: {need} devices requested but only "
                  f"{avail} available (XLA_FLAGS already set?); tiers "
                  f"will share devices")
            if mesh_shape:
                mesh_shape = (avail, 1)
    if args.serial and (args.deadline_ms is not None
                        or args.queue_cap is not None
                        or args.overload != "reject"):
        ap.error("--deadline-ms/--queue-cap/--overload need the "
                 "parallel scheduler; drop --serial")
    if args.serial and (args.contextual or args.budget_rate is not None):
        ap.error("--contextual/--budget-rate run on the parallel "
                 "scheduler; drop --serial")
    if args.assign and args.serial:
        ap.error("--assign runs on the batch path or the parallel "
                 "scheduler; drop --serial")
    if args.assign and args.contextual:
        ap.error("--assign and --contextual are different routing "
                 "modes; pick one")
    if not args.assign and (args.window_budget is not None
                            or args.capacity_frac is not None):
        ap.error("--window-budget/--capacity-frac are assign-mode "
                 "dials; add --assign")
    if args.assign and args.window_size < 1:
        ap.error("--window-size must be >= 1")
    if args.guarantee and args.serial:
        ap.error("--guarantee runs on the batch path or the parallel "
                 "scheduler; drop --serial")
    if not args.guarantee and (args.acc_gap != 0.05
                               or args.acc_alpha != 0.05
                               or args.shadow_frac != 0.1):
        ap.error("--acc-gap/--acc-alpha/--shadow-frac are guarantee "
                 "dials; add --guarantee")
    if args.virtual_clock and args.stream:
        ap.error("--virtual-clock drives the offline batch executor; "
                 "drop --stream (the stream scheduler owns its clock)")
    if args.overload != "reject" and args.queue_cap is None:
        ap.error("--overload degrade only acts on a bounded queue; "
                 "set --queue-cap")
    if args.speculate and (not args.stream or args.serial):
        ap.error("--speculate needs the parallel stream scheduler's idle "
                 "tier workers; add --stream and drop --serial")
    if args.serial and (args.retry is not None or args.breaker
                        or args.faults is not None):
        ap.error("--faults/--retry/--breaker run on the batch executor "
                 "or the parallel stream scheduler; drop --serial")
    if args.retry is not None and args.retry < 1:
        ap.error("--retry must be >= 1 (total attempts)")
    n_tiers = len(args.tiers.split(","))
    faults = retry_pol = breaker_cfg = None
    if args.faults is not None:
        try:
            faults = _parse_faults(args.faults, n_tiers)
        except ValueError as e:
            ap.error(f"--faults: {e}")
    if args.retry is not None:
        from repro.serving.resilience import RetryPolicy
        retry_pol = RetryPolicy(max_attempts=args.retry,
                                backoff_s=args.retry_backoff_ms / 1e3)
    if args.breaker:
        from repro.serving.resilience import BreakerConfig
        breaker_cfg = BreakerConfig(
            cooldown_s=args.breaker_cooldown_ms / 1e3)
    assign_cfg = None
    if args.assign:
        from repro.serving.assign import AssignConfig
        assign_cfg = AssignConfig(window_size=args.window_size,
                                  window_budget=args.window_budget,
                                  capacity_frac=args.capacity_frac)
    guarantee_cfg = None
    if args.guarantee:
        from repro.serving.guarantee import GuaranteeConfig
        try:
            guarantee_cfg = GuaranteeConfig(delta=args.acc_gap,
                                            alpha=args.acc_alpha,
                                            sample_frac=args.shadow_frac)
        except ValueError as e:
            ap.error(f"--guarantee: {e}")

    pipe, _ = build_pipeline(BuildConfig(
        task=args.task, tiers=tuple(args.tiers.split(",")),
        train_steps_cap=args.train_steps, budget_frac=args.budget_frac,
        enable_cache=not args.no_cache,
        enable_prompt_adaptation=not args.no_prompt_adaptation,
        contextual=args.contextual, entry_bar=args.entry_bar,
        budget_rate=args.budget_rate, assign=assign_cfg,
        guarantee=guarantee_cfg,
        governor_window=args.governor_window,
        place_tiers=args.devices is not None,
        shard_tiers=mesh_shape is not None, mesh_shape=mesh_shape,
        compact=args.on_device_compact or "host",
        speculate=args.speculate,
        faults=faults, retry=retry_pol, breaker=breaker_cfg,
        router=RouterConfig(top_lists=10, sample=256)))

    test = synthetic.sample(args.task, args.requests, seed=77)
    if args.stream:
        arrivals = poisson_arrivals(args.requests, args.rate, seed=77)
        mode = ("serial continuous batcher" if args.serial
                else "parallel SLO scheduler")
        print(f"== streaming {args.requests} requests over "
              f"{arrivals[-1]:.2f}s (Poisson, {args.rate:.0f}/s; "
              f"{mode}) ==")
        if args.serial:
            res = pipe.serve_stream(test.tokens, arrivals,
                                    max_chunk=args.max_chunk,
                                    holdback=args.holdback_ms / 1e3,
                                    parallel=False)
        else:
            from repro.serving.sched import SLOConfig
            slo = SLOConfig(
                deadline_s=(None if args.deadline_ms is None
                            else args.deadline_ms / 1e3),
                max_holdback_s=args.holdback_ms / 1e3,
                queue_cap=args.queue_cap, overload=args.overload,
                speculate=args.speculate, spec_depth=args.spec_depth,
                spec_bar=args.spec_bar,
                spec_idle_frac=args.spec_idle_frac,
                retry=retry_pol, breaker=breaker_cfg)
            res = pipe.serve_stream(test.tokens, arrivals,
                                    max_chunk=args.max_chunk, slo=slo)
    elif args.virtual_clock:
        from repro.serving.resilience import VirtualClock
        vc = VirtualClock()
        res = pipe.serve(test.tokens, clock=vc, sleep=vc.sleep)
    else:
        res = pipe.serve(test.tokens)
    served = res.stopped_at != -2
    n_served = int(served.sum())
    acc = (float((res.answers[served] == test.labels[served]).mean())
           if n_served else float("nan"))
    avg_cost = float(res.cost[served].mean()) if n_served else 0.0
    print(res.summary())
    print(f"accuracy {acc:.3f} over {n_served} served; "
          f"avg cost ${avg_cost:.6f}/served query "
          f"({100 * res.savings_frac:.0f}% below top-tier-only)")


if __name__ == "__main__":
    main()
