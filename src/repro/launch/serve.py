"""Serving launcher: the unified FrugalGPT pipeline (cache + prompt
adaptation + cascade) over a batched request stream.

Demo (CPU):
  PYTHONPATH=src python -m repro.launch.serve --requests 200
  PYTHONPATH=src python -m repro.launch.serve --requests 200 \\
      --stream --rate 500        # continuous batching over a Poisson trace

Thin CLI over ``repro.serving.build_pipeline`` — this is the entry point
a real deployment would point at the production mesh (tiers sharded with
pjit per DESIGN.md §5).
"""
from __future__ import annotations

import argparse

from repro.core.router import RouterConfig
from repro.data import synthetic
from repro.serving import BuildConfig, build_pipeline
from repro.serving.ingress import poisson_arrivals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="headlines",
                    choices=list(synthetic.N_CLASSES))
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--budget-frac", type=float, default=0.3,
                    help="budget as a fraction of top-tier cost")
    ap.add_argument("--tiers", default="GPT-J,ChatGPT,GPT-4")
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--no-prompt-adaptation", action="store_true")
    ap.add_argument("--stream", action="store_true",
                    help="replay a Poisson arrival trace through the "
                         "continuous batcher instead of one closed batch")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="stream mode: mean arrival rate (requests/s)")
    ap.add_argument("--max-chunk", type=int, default=32,
                    help="stream mode: max requests per tier chunk")
    args = ap.parse_args()

    pipe, _ = build_pipeline(BuildConfig(
        task=args.task, tiers=tuple(args.tiers.split(",")),
        train_steps_cap=args.train_steps, budget_frac=args.budget_frac,
        enable_cache=not args.no_cache,
        enable_prompt_adaptation=not args.no_prompt_adaptation,
        router=RouterConfig(top_lists=10, sample=256)))

    test = synthetic.sample(args.task, args.requests, seed=77)
    if args.stream:
        arrivals = poisson_arrivals(args.requests, args.rate, seed=77)
        print(f"== streaming {args.requests} requests over "
              f"{arrivals[-1]:.2f}s (Poisson, {args.rate:.0f}/s) ==")
        res = pipe.serve_stream(test.tokens, arrivals,
                                max_chunk=args.max_chunk)
    else:
        res = pipe.serve(test.tokens)
    acc = float((res.answers == test.labels).mean())
    print(res.summary())
    print(f"accuracy {acc:.3f}; avg cost ${res.cost.mean():.6f}/query "
          f"({100 * res.savings_frac:.0f}% below top-tier-only)")


if __name__ == "__main__":
    main()
