import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Hillclimb measurement driver: re-measures the three chosen pairs and
# appends a labeled row per pair to hillclimb_log.json (EXPERIMENTS §Perf).
#
# Usage: PYTHONPATH=src python -m repro.launch.hillclimb <label> [--no-constrain]

import argparse
import json

from repro.launch.dryrun import dryrun_one
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops

PAIRS = [
    ("deepseek-v3-671b", "train_4k"),
    ("mamba2-1.3b", "train_4k"),
    ("mistral-nemo-12b", "decode_32k"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("label")
    ap.add_argument("--no-constrain", action="store_true")
    ap.add_argument("--pairs", default=None,
                    help="comma-separated arch:shape filter")
    args = ap.parse_args()
    pairs = PAIRS
    if args.pairs:
        pairs = [tuple(p.split(":")) for p in args.pairs.split(",")]

    log_path = "hillclimb_log.json"
    log = []
    if os.path.exists(log_path):
        log = json.load(open(log_path))
    for arch, shape in pairs:
        r = dryrun_one(arch, shape, verbose=False,
                       constrain_activations=not args.no_constrain)
        if r["status"] != "ok":
            print(f"{arch} x {shape}: {r}")
            continue
        fl = r["flops_per_device_corrected"]
        by = r["bytes_per_device_corrected"]
        co = r["collectives"]["total"]
        row = {
            "label": args.label, "arch": arch, "shape": shape,
            "compute_s": fl / PEAK_FLOPS, "memory_s": by / HBM_BW,
            "collective_s": co / LINK_BW,
            "useful_ratio": model_flops(arch, shape) / (fl * r["n_devices"]),
            "flops_per_device": fl, "bytes_per_device": by,
            "collective_bytes": co,
            "collective_breakdown": r["collectives"]["bytes"],
            "temp_gib": r["memory"]["temp_size_in_bytes"] / 2**30,
        }
        log.append(row)
        print(f"[{args.label}] {arch} x {shape}: "
              f"C={row['compute_s']:.3g}s M={row['memory_s']:.3g}s "
              f"X={row['collective_s']:.3g}s useful={row['useful_ratio']:.3f} "
              f"temp={row['temp_gib']:.0f}GiB")
    with open(log_path, "w") as f:
        json.dump(log, f, indent=1)


if __name__ == "__main__":
    main()
