"""Accuracy-guaranteed frugality (ISSUE 10): the online SMART layer.

Covers the four contract layers bottom-up:

  * **bounds** — the anytime-valid confidence sequences keep their
    time-uniform coverage under H0 (Monte Carlo violation rate below
    ``alpha``) and still *detect*: a gap genuinely above ``delta``
    certifies (LCB crosses) within a practical sample count;
  * **controller** — the tighten ladder climbs only on certified
    violations, relaxes only on certified safety, holds under
    uncertainty; shadow sampling is a seeded deterministic coin; bad
    observations are refused, not folded;
  * **governor interaction** — the guarantee-side multiplier vetoes
    cost-driven loosening on every accuracy surface while the latency
    dials keep the raw cost shift; plus the ISSUE's NaN/negative-cost
    regression on ``BudgetGovernor.observe``;
  * **end-to-end** — both serve paths (closed batch + parallel
    scheduler) shadow-audit against the reference tier on a separate
    meter with served results bit-identical, and the online router
    retrainer consumes the realized-accept / shadow-agreement labels.
"""
import numpy as np
import pytest

from repro.core.cost import ApiCost
from repro.serving.guarantee import (GapStat, GuaranteeConfig,
                                     GuaranteeController, RouterRetrainer,
                                     bernstein_radius, hoeffding_radius)
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.sched import SLOConfig, TierScheduler
from repro.serving.strategy import (BudgetGovernor, ContextualRouter,
                                    ServingStrategy)
from repro.serving.strategy.router import train_entry_router

D = 8  # embedding width of the toy pipelines


# ---------------------------------------------------------------------------
# bounds: anytime-valid coverage + detection
# ---------------------------------------------------------------------------


def test_radii_shrink_and_clip():
    assert hoeffding_radius(0, 0.05) == 1.0
    assert bernstein_radius(1, 0.0, 0.05) == 1.0
    hs = [hoeffding_radius(n, 0.05) for n in (8, 64, 512, 4096)]
    assert all(a > b for a, b in zip(hs, hs[1:]))
    assert all(0.0 < h <= 1.0 for h in hs)
    # variance adaptivity: at small empirical variance the empirical-
    # Bernstein radius undercuts distribution-free Hoeffding
    p = 0.05
    assert bernstein_radius(4096, p * (1 - p), 0.05) \
        < hoeffding_radius(4096, 0.05)


def test_gapstat_welford_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.random(200)
    st = GapStat()
    for i, x in enumerate(xs):
        st.add(float(x), clock=i + 1)
    assert st.n == 200 and st.last_fed == 200
    assert st.mean == pytest.approx(xs.mean(), abs=1e-12)
    assert st.var == pytest.approx(xs.var(), abs=1e-12)
    st.reset()
    assert st.n == 0 and st.ucb(0.05) == 1.0 and st.lcb(0.05) == 0.0


def test_gapstat_rejects_invalid():
    st = GapStat()
    for bad in (-0.1, 1.1, float("nan")):
        with pytest.raises(ValueError, match="gap observation"):
            st.add(bad)
    with pytest.raises(ValueError, match="unknown bound"):
        st.add(0.5)
        st.radius(0.05, "wald")


@pytest.mark.parametrize("bound", ["bernstein", "hoeffding"])
def test_anytime_coverage_under_h0(bound):
    """Time-uniform coverage: over many independent gap streams with
    true mean p, the fraction of *streams* whose interval ever excludes
    p (at any of the continuously-monitored stopping times) stays below
    alpha. This is the property a fixed-n interval would fail — peeking
    every step inflates its violation rate far above alpha."""
    alpha, p, streams, horizon = 0.05, 0.3, 120, 400
    rng = np.random.default_rng(42)
    violated = 0
    for _ in range(streams):
        st = GapStat()
        bad = False
        for x in (rng.random(horizon) < p).astype(float):
            st.add(float(x))
            if st.ucb(alpha, bound) < p or st.lcb(alpha, bound) > p:
                bad = True
                break
        violated += bad
    assert violated / streams <= alpha


@pytest.mark.parametrize("bound", ["bernstein", "hoeffding"])
def test_detection_under_drift(bound):
    """Power: a true gap of 0.3 against delta=0.05 must certify (LCB
    crosses delta) within a practical number of shadow observations."""
    delta, alpha = 0.05, 0.05
    rng = np.random.default_rng(7)
    st = GapStat()
    crossed_at = None
    for t, x in enumerate((rng.random(2000) < 0.3).astype(float)):
        st.add(float(x))
        if st.lcb(alpha, bound) > delta:
            crossed_at = t + 1
            break
    assert crossed_at is not None and crossed_at < 500


# ---------------------------------------------------------------------------
# controller: ladder dynamics, sampling determinism, input hygiene
# ---------------------------------------------------------------------------


def test_config_validation():
    for kw in ({"delta": 0.0}, {"delta": 1.0}, {"alpha": 0.0},
               {"sample_frac": 0.0}, {"sample_frac": 1.5},
               {"window": 0}, {"levels": 1}, {"bound": "wald"}):
        with pytest.raises(ValueError):
            GuaranteeConfig(**kw)


def _drive(ctrl, p, n, rng):
    for x in (rng.random(n) < p).astype(float):
        ctrl.observe(float(x), 1e-5, invoked=True)


def test_h0_holds_level_zero():
    """True gap well under delta: the triad never has a certified
    violation, so the ladder never climbs and the cap never vetoes."""
    ctrl = GuaranteeController(GuaranteeConfig(delta=0.05, window=16))
    _drive(ctrl, 0.01, 2000, np.random.default_rng(0))
    assert ctrl.level == 0
    assert ctrl.shift_cap(0.35) == pytest.approx(0.35)
    assert ctrl.certified    # UCB under delta by now


def test_drift_tightens_then_calm_recovers():
    """A 0.4 disagreement burst climbs the ladder (gross violation:
    double steps); once the drift passes, per-level re-certification
    walks it back to level 0 and the cap releases."""
    ctrl = GuaranteeController(GuaranteeConfig(delta=0.05, window=16))
    rng = np.random.default_rng(0)
    _drive(ctrl, 0.4, 400, rng)
    assert ctrl.level >= 2
    assert ctrl.shift_cap(0.35) < 0.35   # veto engaged
    _drive(ctrl, 0.005, 6000, rng)
    assert ctrl.level == 0 and ctrl.certified
    assert ctrl.shift_cap(0.35) == pytest.approx(0.35)


def test_uncertain_holds_position():
    """Before min_samples the interval is vacuous ([0, 1] spans delta):
    neither certified branch fires and the level holds."""
    ctrl = GuaranteeController(GuaranteeConfig(
        delta=0.05, window=2, min_samples=64))
    _drive(ctrl, 1.0, 32, np.random.default_rng(0))  # gap 1.0 but n < 64
    assert ctrl.level == 0 and not ctrl.certified


def test_shift_cap_ladder_endpoints():
    cfg = GuaranteeConfig(levels=8)
    ctrl = GuaranteeController(cfg)
    assert ctrl.shift_cap(0.35) == pytest.approx(0.35)
    ctrl.level = cfg.levels - 1
    assert ctrl.shift_cap(0.35) == pytest.approx(-0.35)
    ctrl.level = 3
    assert -0.35 < ctrl.shift_cap(0.35) < 0.35


def test_stale_level_evidence_reset_on_reentry():
    """Evidence parked at a level for longer than ``stale_after`` global
    observations is from a dead regime: re-entering the level restarts
    its sequential test instead of trusting it."""
    cfg = GuaranteeConfig(stale_after=10, window=10 ** 6)
    ctrl = GuaranteeController(cfg)
    _drive(ctrl, 1.0, 5, np.random.default_rng(0))   # level 0 evidence
    assert ctrl._stats[0].n == 5
    ctrl.level = 1                                    # park elsewhere
    _drive(ctrl, 0.0, 20, np.random.default_rng(1))  # clock advances
    ctrl._enter(0)                                    # come back
    assert ctrl._stats[0].n == 0                      # reset, not trusted


def test_stat_cap_restarts_the_stream():
    """The rolling evidence horizon: a level's stream restarts after
    ``stat_cap`` observations so a long-passed regime cannot pin the
    anytime test forever."""
    cfg = GuaranteeConfig(window=16, stat_cap=64)
    ctrl = GuaranteeController(cfg)
    _drive(ctrl, 0.0, 200, np.random.default_rng(0))
    assert ctrl._stats[0].n <= 64


def test_should_sample_deterministic_and_calibrated():
    cfg = GuaranteeConfig(sample_frac=0.3, seed=11)
    a = GuaranteeController(cfg)
    b = GuaranteeController(cfg)
    pa = [a.should_sample() for _ in range(400)]
    pb = [b.should_sample() for _ in range(400)]
    assert pa == pb                                   # same seed, same subset
    c = GuaranteeController(GuaranteeConfig(sample_frac=0.3, seed=12))
    assert pa != [c.should_sample() for _ in range(400)]
    assert abs(np.mean(pa) - 0.3) < 0.08              # calibrated coin


def test_observe_refuses_invalid():
    ctrl = GuaranteeController(GuaranteeConfig())
    ctrl.observe(float("nan"), 1.0)
    ctrl.observe(1.5, 1.0)
    ctrl.observe(0.5, -1.0)
    ctrl.observe(0.5, float("inf"))
    assert ctrl.dropped_obs == 4 and ctrl.n_shadow == 0
    ctrl.observe(0.5, 1.0, invoked=True)
    assert ctrl.n_shadow == 1 and ctrl.n_invoked == 1
    ctrl.abort()
    assert ctrl.n_aborted == 1


def test_snapshot_and_trace():
    ctrl = GuaranteeController(GuaranteeConfig(window=8, sample_frac=0.5))
    _drive(ctrl, 0.2, 64, np.random.default_rng(0))
    snap = ctrl.snapshot()
    for key in ("delta", "alpha", "level", "n_shadow", "n_invoked",
                "shadow_cost", "gap_hat", "gap_ucb", "gap_lcb",
                "certified", "trace", "dropped_obs"):
        assert key in snap
    assert len(snap["trace"]) == 8                    # one per window
    tr = snap["trace"][-1]
    assert tr["gap_lcb"] <= tr["gap_hat"] <= tr["gap_ucb"]


# ---------------------------------------------------------------------------
# governor interaction: the second dual constraint
# ---------------------------------------------------------------------------


def _overspending_governor(guarantee=None):
    gov = BudgetGovernor(budget_rate=1.0, base_thresholds=(0.5, 0.6),
                         base_bar=0.5, base_min_score=0.5,
                         base_threshold=0.98, window=4,
                         guarantee=guarantee)
    for _ in range(32):                 # far under budget -> loosen
        gov.observe(0.01)
    return gov


def test_guarantee_veto_beats_cost_loosening():
    """The cost dual wants to loosen (underspend -> negative lam ->
    positive... no: underspend gives negative shift). Drive overspend
    instead? The veto direction that matters: cost side loosening
    (positive shift) clamped by a violated guarantee to -max_shift."""
    guar = GuaranteeController(GuaranteeConfig(window=8))
    gov = BudgetGovernor(budget_rate=0.01, base_thresholds=(0.5, 0.6),
                         base_bar=0.5, base_min_score=0.5,
                         base_threshold=0.98, window=4, guarantee=guar)
    for _ in range(64):                 # overspend -> loosen (shift > 0)
        gov.observe(1.0)
    assert gov.shift > 0.2
    base = BudgetGovernor(budget_rate=0.01, base_thresholds=(0.5, 0.6),
                          window=4)
    for _ in range(64):
        base.observe(1.0)
    assert gov.thresholds() == base.thresholds()      # level 0: no veto
    # certified violation: drive the controller up the ladder
    _drive(guar, 0.6, 400, np.random.default_rng(3))
    assert guar.level > 0
    assert gov.effective_shift() < gov.shift          # veto engaged
    # every accuracy surface tightens past the un-governed base...
    assert all(g >= b for g, b in zip(gov.thresholds(),
                                      base.thresholds()))
    assert gov.thresholds() != base.thresholds()
    assert gov.entry_bar() > base.entry_bar()
    # ...while the latency dials keep the raw cost shift (chunking
    # trades $, not answer quality)
    assert gov.max_chunk(16) == base.max_chunk(16)
    assert gov.holdback_s(0.1) == base.holdback_s(0.1)
    assert gov.snapshot()["effective_shift"] == gov.effective_shift()


def test_governor_without_guarantee_is_identity():
    gov = _overspending_governor(None)
    assert gov.effective_shift() == gov.shift


def test_governor_observe_rejects_nan_and_negative():
    """ISSUE 10 satellite: a NaN cost (one hop from the failed-tier
    path) or a non-positive count must be dropped, leaving every
    governed threshold finite."""
    gov = BudgetGovernor(budget_rate=0.01, base_thresholds=(0.5,),
                         base_min_score=0.5, base_threshold=0.98, window=2)
    gov.observe(float("nan"))
    gov.observe(-1.0)
    gov.observe(0.02, n=0)
    gov.observe(0.02, n=-3)
    gov.observe(float("inf"))
    assert gov.dropped_obs == 5
    gov.observe_many([0.01, float("nan"), -0.5, 0.02])
    assert gov.dropped_obs == 7
    for _ in range(8):
        gov.observe(0.02)
    assert np.isfinite(gov.lam) and np.isfinite(gov.shift)
    assert all(np.isfinite(t) for t in gov.thresholds())
    assert np.isfinite(gov.entry_bar())
    assert np.isfinite(gov.min_score())
    assert np.isfinite(gov.cache_threshold())


def test_strategy_governor_guarantee_must_share_controller():
    guar = GuaranteeController(GuaranteeConfig())
    gov = BudgetGovernor(budget_rate=1.0, base_thresholds=(0.5,),
                         guarantee=GuaranteeController(GuaranteeConfig()))
    with pytest.raises(ValueError, match="same controller"):
        ServingStrategy(governor=gov, guarantee=guar)


# ---------------------------------------------------------------------------
# end-to-end: both serve paths
# ---------------------------------------------------------------------------


def _feature_embed(tokens):
    return np.asarray(tokens[:, :D], np.float32)


def _two_tier_pipeline(guarantee=None, strategy=None, batch_size=8):
    """t0 answers 0, the reference t1 answers 1; the scorer accepts at
    t0 iff the leading feature is positive — so every t0-stopped row
    *disagrees* with the reference (known gap)."""
    prices = [ApiCost(10.0, 10.0, 0.0), ApiCost(100.0, 100.0, 0.0)]
    tiers = [TierSpec("t0", lambda t: np.zeros(len(t), np.int32), prices[0]),
             TierSpec("t1", lambda t: np.ones(len(t), np.int32), prices[1])]
    if strategy is None and guarantee is not None:
        strategy = ServingStrategy(guarantee=guarantee)
    return ServingPipeline(
        tiers=tiers, thresholds=[0.5],
        scorer=lambda t, a: np.where(t[:, 0] > 0, 0.9, 0.1),
        embed=_feature_embed, full_prompt_tokens=100, pad_token=-1,
        batch_size=batch_size, strategy=strategy)


def _feature_tokens(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, D)).astype(np.float32)


def test_batch_shadow_audit_end_to_end():
    guar = GuaranteeController(GuaranteeConfig(sample_frac=1.0, window=8,
                                               retrain=False))
    pipe = _two_tier_pipeline(guar)
    toks = _feature_tokens(32, seed=0)
    res = pipe.serve(toks)
    plain = _two_tier_pipeline().serve(toks)
    # measurement, not service: served results bit-identical
    assert np.array_equal(res.answers, plain.answers)
    assert (res.cost == plain.cost).all()
    n0 = int((res.stopped_at == 0).sum())
    # every miss sampled: t0-stoppers invoke the reference (and all
    # disagree by construction), top-tier rows are free observations
    assert guar.n_shadow == 32 and guar.n_invoked == n0
    assert guar.gap_hat == pytest.approx(n0 / 32)
    # the shadow meter charged exactly n0 reference invocations, and
    # none of it leaked into the per-request accounting
    assert guar.shadow_cost == pytest.approx(
        float(pipe._tier_cost(pipe.tiers[1], toks[:n0]).sum()))
    assert res.cost.sum() == plain.cost.sum()
    assert "guarantee" in res.strategy
    assert res.strategy["guarantee"]["n_invoked"] == n0
    assert "guarantee" in res.summary()


def test_batch_shadow_subset_is_seeded():
    toks = _feature_tokens(64, seed=1)
    runs = []
    for _ in range(2):
        guar = GuaranteeController(GuaranteeConfig(
            sample_frac=0.4, seed=5, retrain=False, window=10 ** 6))
        _two_tier_pipeline(guar).serve(toks)
        runs.append((guar.n_shadow, guar.n_invoked,
                     round(guar.shadow_cost, 12)))
    assert runs[0] == runs[1]          # fixed seed, identical subset


def test_scheduler_shadow_clones_end_to_end():
    toks = _feature_tokens(48, seed=2)
    guar = GuaranteeController(GuaranteeConfig(sample_frac=1.0, window=8,
                                               retrain=False))
    sched = TierScheduler(_two_tier_pipeline(guar), max_chunk=8)
    res = sched.run_trace(toks)
    plain = TierScheduler(_two_tier_pipeline(), max_chunk=8).run_trace(toks)
    assert np.array_equal(res.answers, plain.answers)
    assert (res.cost == plain.cost).all()
    assert np.array_equal(res.stopped_at, plain.stopped_at)
    n0 = int((res.stopped_at == 0).sum())
    # every request audited and every shadow clone drained before the
    # result was folded: invoked = t0-stoppers, free obs for the rest
    assert guar.n_shadow == 48 and guar.n_invoked == n0
    assert guar.n_aborted == 0
    assert guar.gap_hat == pytest.approx(n0 / 48)
    # shadow clones never pollute the service telemetry
    assert res.tier_counts == plain.tier_counts


def test_scheduler_shadow_aborts_on_full_queue():
    """Overload sheds the *audit*, never the service: a finish that
    draws the shadow coin while the reference tier's queue sits at
    ``queue_cap`` counts an abort instead of enqueueing a clone, and a
    clone that comes back failed aborts instead of observing."""
    from repro.serving.ingress import RequestState

    guar = GuaranteeController(GuaranteeConfig(sample_frac=1.0,
                                               retrain=False))
    pipe = _two_tier_pipeline(guar)
    sched = TierScheduler(pipe, max_chunk=4, slo=SLOConfig(queue_cap=2))
    top = len(pipe.tiers) - 1
    toks = _feature_tokens(1, seed=3)

    def finished(rid):
        r = RequestState(rid=rid, tokens=toks[0], arrival=0.0)
        r.answer, r.stopped_at, r.cost = np.int32(0), 0, 0.1
        sched._inflight += 1
        return r

    with sched._mu:
        sched._waiting[top].extend(
            RequestState(rid=-9 - k, tokens=toks[0], arrival=0.0,
                         shadow=True) for k in range(2))   # cap reached
        sched._finish_locked(finished(0), 0.0)
        assert guar.n_aborted == 1                 # audit shed at the cap
        assert len(sched._waiting[top]) == 2       # no clone squeezed in
        sched._waiting[top].clear()
        sched._finish_locked(finished(1), 0.0)
        assert guar.n_aborted == 1                 # room again: clone queued
        assert len(sched._waiting[top]) == 1
        clone = sched._waiting[top].pop()          # ...which then fails
        clone.answer = None
        sched._finish_locked(clone, 0.0)
        assert guar.n_aborted == 2 and guar.n_shadow == 0


def test_serial_batcher_still_rejects_strategies():
    guar = GuaranteeController(GuaranteeConfig(retrain=False))
    pipe = _two_tier_pipeline(guar)
    with pytest.raises(ValueError, match="parallel"):
        pipe.serve_stream(_feature_tokens(8), np.zeros(8), parallel=False)


# ---------------------------------------------------------------------------
# online router retraining
# ---------------------------------------------------------------------------


def _toy_router(n_tiers=2, seed=0, steps=60):
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(200, D)).astype(np.float32)
    labels = np.zeros((200, n_tiers), np.float32)
    labels[:, 0] = emb[:, 0] > 0
    labels[:, 1:] = 1.0
    params = train_entry_router(emb, labels, steps=steps, seed=seed)
    return ContextualRouter(params, n_tiers)


def test_retrainer_learns_from_labels():
    router = _toy_router(steps=1)        # nearly untrained
    rt = RouterRetrainer(router, lr=5e-2, capacity=128, interval=32,
                         min_fill=32)
    rng = np.random.default_rng(0)
    before = None
    for _ in range(12):
        emb = rng.normal(size=(32, D)).astype(np.float32)
        for e in emb:
            rt.observe(e, 0, bool(e[0] > 0))
        stepped = rt.maybe_step()
        assert stepped
        if before is None:
            before = rt.last_loss
    assert rt.steps == 12
    assert rt.last_loss < before         # masked BCE actually descends
    probe = np.zeros((2, D), np.float32)
    probe[0, 0], probe[1, 0] = 3.0, -3.0
    p = router.predict(probe)
    assert p[0, 0] > p[1, 0]             # learned the separable rule


def test_retrainer_refuses_bad_observations():
    rt = RouterRetrainer(_toy_router(steps=1))
    rt.observe(np.full(D, np.nan, np.float32), 0, True)
    rt.observe(np.zeros(D, np.float32), 7, True)      # position out of range
    rt.observe(np.zeros(D, np.float32), -1, True)
    assert rt.n_observed == 0
    with pytest.raises(ValueError):
        RouterRetrainer(_toy_router(steps=1), capacity=0)


def test_pipeline_feeds_retrainer_from_both_streams():
    """Routed entries yield realized-accept labels; shadow audits yield
    agreement labels at the stopping position."""
    router = _toy_router(steps=60)
    guar = GuaranteeController(
        GuaranteeConfig(sample_frac=1.0, window=10 ** 6),
        retrainer=RouterRetrainer(router, interval=10 ** 6))
    strat = ServingStrategy(router=router, guarantee=guar)
    pipe = _two_tier_pipeline(strategy=strat)
    toks = _feature_tokens(32, seed=4)
    res = pipe.serve(toks)
    rt = guar.retrainer
    n0 = int((res.stopped_at == 0).sum())
    entered0 = int(res.strategy["entry_hist"][0])
    # realized accepts at non-final entries + shadow labels at non-top
    # stopping positions (both streams skip the trivial final position)
    assert rt.n_observed == entered0 + n0
    assert res.strategy["guarantee"]["retrain"]["n_observed"] \
        == rt.n_observed
