"""Contextual routing + online budget governor (repro.serving.strategy):
router/governor/degrade units, cascade entry support, pipeline and
scheduler integration, estimator-driven predictive shedding, the
builder's strategy/joint/cache knobs, and core.router frontier /
cost_to_match coverage."""
import numpy as np
import pytest

from repro.core.approx import CompletionCache
from repro.core.cascade import CascadeTier, evaluate_offline, execute_cascade
from repro.core.cost import ApiCost
from repro.core.router import RouterConfig, cost_to_match, frontier
from repro.core.simulate import simulate_market, simulate_scores, split_market
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.sched import SLOConfig, TierScheduler, admit_decision
from repro.serving.sched.estimator import TierEstimator
from repro.serving.strategy import (BudgetGovernor, ContextualRouter,
                                    ServingStrategy, accept_labels,
                                    degrade_entry, train_entry_router)

D = 8          # toy embedding width


def _toy_router(n_tiers=2, seed=0, steps=250):
    """Router trained on separable features: emb[0] > 0 => tier 0
    accepts. Returns (router, sampler) where sampler(n) draws fresh
    feature rows."""
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(600, D)).astype(np.float32)
    labels = np.zeros((600, n_tiers), np.float32)
    labels[:, 0] = emb[:, 0] > 0
    for j in range(1, n_tiers):
        labels[:, j] = 1.0
    params = train_entry_router(emb, labels, steps=steps, seed=seed)
    return ContextualRouter(params, n_tiers)


# ---------------------------------------------------------------------------
# router units
# ---------------------------------------------------------------------------


def test_router_learns_separable_accept():
    router = _toy_router()
    rng = np.random.default_rng(1)
    emb = rng.normal(size=(400, D)).astype(np.float32)
    probs = router.predict(emb)
    assert probs.shape == (400, 2)
    acc = ((probs[:, 0] > 0.5) == (emb[:, 0] > 0)).mean()
    assert acc > 0.9, acc


def test_router_entry_rule_and_bar_monotonicity():
    router = _toy_router()
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(200, D)).astype(np.float32)
    lo = router.entry_tiers(emb, 0.1)
    hi = router.entry_tiers(emb, 0.9)
    # raising the bar can only push entries upward
    assert (hi >= lo).all()
    assert lo.dtype == np.int32
    # the final position catches everything, even at bar > any prob
    assert (router.entry_tiers(emb, 2.0) == 1).all()
    # probs reuse path matches the fresh forward
    probs = router.predict(emb)
    assert np.array_equal(router.entry_tiers(emb, 0.5),
                          router.entry_tiers(emb, 0.5, probs=probs))


def test_accept_labels_from_build_artifacts():
    scores = np.array([[0.9, 0.2, 0.5],
                       [0.1, 0.8, 0.5]])
    correct = np.array([[1.0, 0.0, 1.0],
                        [0.0, 1.0, 0.0]])
    # cascade over marketplace apis (2, 0) with tau_0 = 0.4
    y = accept_labels(scores, correct, apis=(2, 0), thresholds=(0.4,))
    # position 0: score of api 2 >= 0.4; position 1 (final): api 0 correct
    assert y.tolist() == [[1.0, 1.0], [1.0, 0.0]]


# ---------------------------------------------------------------------------
# governor units
# ---------------------------------------------------------------------------


def test_governor_validation():
    with pytest.raises(ValueError, match="budget_rate"):
        BudgetGovernor(0.0, (0.5,))
    with pytest.raises(ValueError, match="window"):
        BudgetGovernor(1.0, (0.5,), window=0)
    with pytest.raises(ValueError, match="max_shift"):
        BudgetGovernor(1.0, (0.5,), max_shift=0.0)


def test_governor_dual_updates_track_budget_error():
    gov = BudgetGovernor(1.0, (0.6, 0.4), base_bar=0.5, window=10)
    assert gov.thresholds() == (0.6, 0.4)      # starts at the base
    for _ in range(30):
        gov.observe(2.0)                       # 2x over budget
    assert gov.shift > 0
    thr = gov.thresholds()
    assert thr[0] < 0.6 and thr[1] < 0.4       # cheaper: lower accept bars
    assert gov.entry_bar() < 0.5               # and a lower entry bar
    assert len(gov.trace) == 3                 # one snapshot per window
    assert gov.trace[-1]["n_seen"] == 30
    for _ in range(120):
        gov.observe(0.1)                       # deep under budget
    assert gov.shift < 0
    assert gov.thresholds()[0] > 0.6           # spend spare budget on acc
    # saturation: shift never exceeds max_shift, thresholds stay in [0,1]
    assert abs(gov.shift) <= gov.max_shift + 1e-12
    assert all(0.0 <= t <= 1.0 for t in gov.thresholds())


def test_governor_window_batching_and_snapshot():
    gov = BudgetGovernor(1.0, (0.5,), window=8)
    gov.observe_many(np.full(20, 3.0))         # 2 full windows + remainder
    assert len(gov.trace) == 2
    snap = gov.snapshot()
    assert snap["n_observed"] == 20
    assert snap["realized_rate"] == pytest.approx(3.0)
    assert snap["budget_rate"] == 1.0
    assert len(snap["trace"]) == 2


def test_governor_converges_on_controllable_cost():
    """Closed loop against a synthetic dial: per-query cost rises with
    the threshold (more escalation). The governor must settle the
    realized rate within +/-10% of target."""
    gov = BudgetGovernor(1.5, (0.6,), window=20, eta=0.6)
    rng = np.random.default_rng(0)
    total, n = 0.0, 0
    for _ in range(60):                        # 60 windows
        tau = gov.thresholds()[0]
        costs = 0.5 + 3.0 * tau + 0.05 * rng.normal(size=20)
        gov.observe_many(costs)
        total += costs.sum()
        n += 20
    last = [w["window_rate"] for w in list(gov.trace)[-10:]]
    assert abs(np.mean(last) - 1.5) / 1.5 < 0.1


def test_governor_trace_is_bounded():
    gov = BudgetGovernor(1.0, (0.5,), window=1, trace_len=16)
    for _ in range(100):
        gov.observe(1.0)
    assert len(gov.trace) == 16            # bounded despite 100 windows
    assert gov.trace[-1]["n_seen"] == 100
    assert len(gov.snapshot()["trace"]) == 16


# ---------------------------------------------------------------------------
# cost-aware degradation
# ---------------------------------------------------------------------------


def test_degrade_entry_rule():
    # no router: legacy pin to tier 0
    assert degrade_entry(None, 0.5) == 0
    # cheapest tier clearing the reduced bar (0.5 * 0.5 = 0.25)
    assert degrade_entry(np.array([0.1, 0.3, 0.9]), 0.5, 0.5, 3) == 1
    assert degrade_entry(np.array([0.3, 0.1, 0.9]), 0.5, 0.5, 3) == 0
    # nothing clears: the final position catches it
    assert degrade_entry(np.array([0.1, 0.1, 0.2]), 0.9, 0.5, 3) == 2
    with pytest.raises(ValueError, match="relief"):
        degrade_entry(np.array([0.5]), 0.5, 0.0, 1)
    with pytest.raises(ValueError, match="probabilities"):
        degrade_entry(np.array([0.5, 0.5]), 0.5, 0.5, 3)


# ---------------------------------------------------------------------------
# cascade entry support
# ---------------------------------------------------------------------------


def _counting_tiers(m=3, costs=(1.0, 10.0, 100.0)):
    calls = [[] for _ in range(m)]

    def mk(j):
        def invoke(q):
            calls[j].append(len(q))
            return np.full(len(q), j, np.int32), np.full(len(q), costs[j])
        return invoke

    return [CascadeTier(f"t{j}", mk(j)) for j in range(m)], calls


def test_execute_cascade_entry_skips_tiers():
    tiers, calls = _counting_tiers()
    n = 6
    entry = np.array([0, 0, 1, 1, 2, 2])

    def scorer(q, a, j):
        return np.zeros(len(q))               # reject: everything escalates

    res = execute_cascade(tiers, [0.5, 0.5], scorer, np.arange(n),
                          entry=entry)
    # tier 0 sees only entry-0 rows; tier 1 adds the entry-1 rows; etc.
    assert res["tier_counts"] == [2, 4, 6]
    assert sum(calls[0]) == 2 and sum(calls[1]) == 4 and sum(calls[2]) == 6
    # cost never includes a skipped tier
    assert res["cost"].tolist() == [111.0, 111.0, 110.0, 110.0, 100.0, 100.0]
    assert (np.asarray(res["stopped_at"]) == 2).all()


def test_execute_cascade_entry_zero_matches_none():
    def scorer(q, a, j):
        return (np.asarray(q) % 2 == 0).astype(float)

    tiers, _ = _counting_tiers(2, (1.0, 10.0))
    a = execute_cascade(tiers, [0.5], scorer, np.arange(10))
    tiers2, _ = _counting_tiers(2, (1.0, 10.0))
    b = execute_cascade(tiers2, [0.5], scorer, np.arange(10),
                        entry=np.zeros(10, np.int64))
    assert np.array_equal(a["answers"], b["answers"])
    assert (a["cost"] == b["cost"]).all()
    assert a["tier_counts"] == b["tier_counts"]


def test_execute_cascade_entry_validation():
    tiers, _ = _counting_tiers(2, (1.0, 10.0))

    def scorer(q, a, j):
        return np.zeros(len(q))

    with pytest.raises(ValueError, match="entry must be"):
        execute_cascade(tiers, [0.5], scorer, np.arange(4),
                        entry=np.zeros(3))
    with pytest.raises(ValueError, match=r"\[0, 2\)"):
        execute_cascade(tiers, [0.5], scorer, np.arange(4),
                        entry=np.array([0, 1, 2, 0]))


# ---------------------------------------------------------------------------
# pipeline + scheduler integration
# ---------------------------------------------------------------------------


def _feature_embed(tokens):
    """Rows ARE the embedding: tokens (n, D) float-ish."""
    return np.asarray(tokens[:, :D], np.float32)


def _routed_pipeline(router=None, governor=None, thresholds=(0.5,),
                     batch_size=8, n_tiers=2, entry_bar=0.5,
                     degrade_relief=0.5):
    """2-3 tier pipeline whose scorer accepts iff the leading feature is
    positive — aligned with what _toy_router predicts."""
    prices = [ApiCost(10.0 * 10 ** j, 10.0 * 10 ** j, 0.0)
              for j in range(n_tiers)]
    tiers = [TierSpec(f"t{j}", (lambda t, j=j: np.full(len(t), j, np.int32)),
                      prices[j]) for j in range(n_tiers)]
    strategy = None
    if router is not None or governor is not None:
        strategy = ServingStrategy(router=router, governor=governor,
                                   entry_bar=entry_bar,
                                   degrade_relief=degrade_relief)
    return ServingPipeline(
        tiers=tiers, thresholds=list(thresholds),
        scorer=lambda t, a: np.where(t[:, 0] > 0, 0.9, 0.1),
        embed=_feature_embed, full_prompt_tokens=100, pad_token=-1,
        batch_size=batch_size, strategy=strategy)


def _feature_tokens(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, D)).astype(np.float32)


def test_pipeline_serve_routes_hard_queries_past_tier0():
    router = _toy_router()
    pipe = _routed_pipeline(router=router)
    toks = _feature_tokens(64, seed=3)
    res = pipe.serve(toks)
    hard = toks[:, 0] < -0.5                  # confidently hard rows
    easy = toks[:, 0] > 0.5
    # hard queries entered (and stopped) at tier 1 without paying tier 0
    assert (res.stopped_at[hard] == 1).all()
    t1_only = ApiCost(100.0, 100.0, 0.0)
    # easy queries stop at tier 0
    assert (res.stopped_at[easy] == 0).all()
    # telemetry
    assert res.strategy is not None
    assert sum(res.strategy["entry_hist"]) == 64
    assert res.strategy["entry_hist"][1] >= int(hard.sum())
    assert res.strategy["realized_accept_rate"] > 0.8
    assert 0.0 < res.strategy["predicted_accept_rate"] <= 1.0
    # entry-1 queries are billed tier 1 only (cost = one tier-1 call)
    n_q = (toks[hard] != pipe.pad_token).sum(-1)
    expected = np.asarray(t1_only.query_cost(n_q + 100, np.ones_like(n_q)),
                          np.float64)
    assert res.cost[hard] == pytest.approx(expected)


def test_pipeline_serve_governor_only_strategy():
    gov = BudgetGovernor(1e-9, (0.5,), window=8)   # impossible target
    pipe = _routed_pipeline(governor=gov)
    toks = _feature_tokens(64, seed=4)
    pipe.serve(toks)
    # overspend detected: thresholds pushed down from the base
    assert gov.shift > 0
    assert pipe.strategy.thresholds(pipe.thresholds)[0] < 0.5
    # and the governed threshold is what the next serve actually uses:
    # with tau pushed to ~0.15 the 0.1-score (hard) rows still escalate,
    # but nothing that scores 0.9 can ever leave tier 0
    res = pipe.serve(toks)
    assert res.strategy["governor"]["n_observed"] == 128
    assert len(res.strategy["governor"]["trace"]) >= 8


def test_governor_min_score_dial():
    # no base floor configured: the dial is off
    assert BudgetGovernor(1.0, (0.5,), window=8).min_score() is None
    gov = BudgetGovernor(1.0, (0.5,), base_min_score=0.6, window=8)
    assert gov.min_score() == pytest.approx(0.6)   # starts at the base
    for _ in range(16):
        gov.observe(3.0)                           # 3x over budget
    assert gov.shift > 0
    # overspend LOOSENS the floor: cache more answers, buy fewer calls
    assert gov.min_score() < 0.6
    assert gov.snapshot()["min_score"] == pytest.approx(gov.min_score())
    for _ in range(200):
        gov.observe(0.01)                          # deep under budget
    assert gov.shift < 0
    # spare budget TIGHTENS it: only cache what the scorer trusted most
    assert 0.6 < gov.min_score() <= 1.0


def test_pipeline_cache_floor_follows_governor():
    gov = BudgetGovernor(1e-9, (0.5,), base_min_score=0.9, window=8)
    pipe = _routed_pipeline(governor=gov)
    pipe.cache = CompletionCache(capacity=256, threshold=0.99,
                                 min_score=0.9)
    # the cache's dot-product similarity expects L2-normalized rows
    # (like the real embed_queries); raw gaussian features would all
    # "hit" at any threshold
    pipe.embed = lambda t: (_feature_embed(t)
                            / np.linalg.norm(_feature_embed(t), axis=1,
                                             keepdims=True))
    pipe.serve(_feature_tokens(64, seed=6))
    assert gov.shift > 0   # impossible target: permanently over budget
    # fresh queries (all misses) make the next insert read the dial;
    # re-serving the SAME queries would all hit, cost nothing, and let
    # the governor unwind the shift — the cache curing the overspend
    pipe.serve(_feature_tokens(64, seed=7))
    # the live cache floor is the governor's dial, not the static 0.9
    assert gov.shift > 0
    assert pipe.cache.min_score == pytest.approx(gov.min_score())
    assert pipe.cache.min_score < 0.9


def test_governor_chunk_and_holdback_dials():
    """The multiplier dials (base x (1 + shift)): overspend grows chunks
    and holdback (fuller pow2 buckets, better $ amortization), spare
    budget shrinks them (lower latency). The base lives with the caller,
    like thresholds(base)."""
    gov = BudgetGovernor(1.0, (0.5,), window=8)
    assert gov.max_chunk(32) == 32             # zero shift: identity
    assert gov.holdback_s(0.02) == pytest.approx(0.02)
    for _ in range(16):
        gov.observe(3.0)                       # 3x over budget
    assert gov.shift > 0
    assert gov.max_chunk(32) == int(round(32 * (1 + gov.shift))) > 32
    assert gov.holdback_s(0.02) == pytest.approx(0.02 * (1 + gov.shift))
    snap = gov.snapshot()
    assert snap["chunk_scale"] == pytest.approx(1 + gov.shift)
    assert snap["holdback_scale"] == pytest.approx(1 + gov.shift)
    for _ in range(200):
        gov.observe(0.01)                      # deep under budget
    assert gov.shift < 0
    assert gov.max_chunk(32) < 32
    assert gov.holdback_s(0.02) < 0.02
    assert gov.max_chunk(1) >= 1               # never starves the chunk
    assert gov.holdback_s(0.0) == 0.0


def test_governor_cache_threshold_dial():
    """The similarity-threshold dial is slack-scaled: the shift moves
    the threshold by shift x (1 - base), so a 0.99-tight base moves by
    basis points while a loose base moves proportionally more."""
    assert BudgetGovernor(1.0, (0.5,), window=8).cache_threshold() is None
    gov = BudgetGovernor(1.0, (0.5,), base_threshold=0.99, window=8)
    assert gov.cache_threshold() == pytest.approx(0.99)
    for _ in range(16):
        gov.observe(3.0)                       # over budget: loosen
    assert gov.shift > 0
    want = 0.99 - gov.shift * (1 - 0.99)
    assert gov.cache_threshold() == pytest.approx(want)
    assert 0.98 < gov.cache_threshold() < 0.99     # basis points, not raw
    assert gov.snapshot()["cache_threshold"] == \
        pytest.approx(gov.cache_threshold())
    for _ in range(200):
        gov.observe(0.01)                      # spare budget: tighten
    assert gov.shift < 0
    assert 0.99 < gov.cache_threshold() <= 1.0


def test_pipeline_cache_threshold_follows_governor():
    """Builder wiring, end to end at the pipeline layer: a governor that
    owns the similarity threshold drives the live CompletionCache
    threshold on every lookup — overspend admits near-duplicates as free
    hits."""
    gov = BudgetGovernor(1e-9, (0.5,), base_threshold=0.99, window=8)
    pipe = _routed_pipeline(governor=gov)
    pipe.cache = CompletionCache(capacity=256, threshold=0.99)
    pipe.embed = lambda t: (_feature_embed(t)
                            / np.linalg.norm(_feature_embed(t), axis=1,
                                             keepdims=True))
    pipe.serve(_feature_tokens(64, seed=8))
    assert gov.shift > 0   # impossible target: permanently over budget
    pipe.serve(_feature_tokens(64, seed=9))
    # the live similarity threshold is the governor's dial, not 0.99
    assert pipe.cache.threshold == pytest.approx(gov.cache_threshold())
    assert pipe.cache.threshold < 0.99


def test_scheduler_chunk_and_holdback_follow_governor():
    """The parallel scheduler reads its chunk cap and holdback window
    through the governor on every pop, so a mid-stream shift re-tunes
    batching without a rebuild."""
    gov = BudgetGovernor(1.0, (0.5,), window=8)
    pipe = _routed_pipeline(governor=gov)
    slo = SLOConfig(max_holdback_s=0.02)
    sched = TierScheduler(pipe, max_chunk=16, slo=slo)
    assert sched._effective_chunk() == 16
    assert sched._effective_holdback() == pytest.approx(0.02)
    for _ in range(16):
        gov.observe(3.0)                       # push the dial mid-stream
    assert sched._effective_chunk() == gov.max_chunk(16) > 16
    assert sched._effective_holdback() == \
        pytest.approx(gov.holdback_s(0.02))
    # without a governor the scheduler runs on its static knobs
    plain = TierScheduler(_routed_pipeline(), max_chunk=16, slo=slo)
    assert plain._effective_chunk() == 16
    assert plain._effective_holdback() is None   # None = SLO unchanged


def test_scheduler_matches_serve_with_router():
    router = _toy_router()
    toks = _feature_tokens(48, seed=5)
    a = _routed_pipeline(router=router).serve(toks)
    b = TierScheduler(_routed_pipeline(router=router),
                      max_chunk=8).run_trace(toks)
    assert np.array_equal(a.answers, b.answers)
    assert (a.cost == b.cost).all()
    assert np.array_equal(a.stopped_at, b.stopped_at)
    assert a.tier_counts == b.tier_counts
    assert a.strategy["entry_hist"] == b.strategy["entry_hist"]


def test_serial_batcher_rejects_strategy():
    pipe = _routed_pipeline(router=_toy_router())
    with pytest.raises(ValueError, match="parallel"):
        pipe.serve_stream(_feature_tokens(4), parallel=False)


def test_pipeline_requires_embed_with_router():
    with pytest.raises(ValueError, match="embed"):
        ServingPipeline(
            tiers=[], thresholds=[], scorer=None,
            strategy=ServingStrategy(router=_toy_router(steps=1)))


def test_strategy_requires_router_or_governor():
    with pytest.raises(ValueError, match="governor and/or guarantee"):
        ServingStrategy()


def test_scheduler_degrade_routes_by_predicted_score():
    """Overload-degraded arrivals enter the cheapest tier clearing the
    reduced bar instead of being pinned to tier 0: with every query
    confidently hard (tier-0 accept prob ~0), degraded traffic lands on
    tier 1+ and tier 0 sees none of it."""
    import time as _time

    router = _toy_router(n_tiers=3)

    def slow(v):
        def answer(t):
            _time.sleep(0.01)
            return np.full(len(t), v, np.int32)
        return answer

    tiers = [TierSpec(f"t{j}", slow(j), ApiCost(10.0 ** (j + 1),
                                                10.0 ** (j + 1), 0.0))
             for j in range(3)]
    pipe = ServingPipeline(
        tiers=tiers, thresholds=[0.5, 0.5],
        scorer=lambda t, a: np.where(t[:, 0] > 0, 0.9, 0.1),
        embed=_feature_embed, full_prompt_tokens=100, pad_token=-1,
        batch_size=4,
        strategy=ServingStrategy(router=router, degrade_relief=0.5))
    rng = np.random.default_rng(6)
    toks = rng.normal(size=(32, D)).astype(np.float32)
    toks[:, 0] = -2.0                          # every query is hard
    slo = SLOConfig(queue_cap=4, overload="degrade", max_holdback_s=0.0)
    sched = TierScheduler(pipe, max_chunk=4, slo=slo)
    res = sched.run_trace(toks)
    degraded = [r for r in sched._requests if r.degraded and not r.shed]
    assert degraded, "queue cap 4 against 32 instant arrivals must degrade"
    assert all(r.entry >= 1 for r in degraded)
    assert all(r.stopped_at == r.entry for r in degraded)  # forced accept
    assert res.tier_counts[0] == 0             # tier 0 never touched
    # the hard 2x bound holds on the degrade TARGET queues too
    assert all(p <= 2 * 4 for p in res.ingress["queue_peak"])


def test_predictive_shed_acts_before_queue_fills():
    """With predictive_shed, once the EWMA knows the tier is slow, an
    arrival whose predicted completion misses its deadline is shed even
    though the queue is nearly empty."""
    import time as _time

    def slow(t):
        _time.sleep(0.05)
        return np.zeros(len(t), np.int32)

    pipe = ServingPipeline(
        tiers=[TierSpec("slow", slow, ApiCost(10.0, 10.0, 0.0))],
        thresholds=[], scorer=None, full_prompt_tokens=10, pad_token=-1,
        batch_size=4)
    slo = SLOConfig(deadline_s=0.02, predictive_shed=True, queue_cap=64,
                    max_holdback_s=0.0)
    toks = np.arange(12 * 4, dtype=np.int32).reshape(12, 4)
    # wave 1 at t=0 trains the EWMA; wave 2 arrives when the scheduler
    # already knows a chunk takes ~50ms > the 20ms deadline budget
    arrivals = np.concatenate([np.zeros(4), np.full(8, 0.2)])
    sched = TierScheduler(pipe, max_chunk=4, slo=slo)
    res = sched.run_trace(toks, arrivals)
    shed = res.stopped_at == -2
    assert shed[4:].all(), "post-warmup arrivals must be predictively shed"
    assert not shed[:4].any(), "cold-start wave is admitted (EWMA empty)"
    assert res.ingress["queue_peak"][0] <= 4   # far below the 64 cap


def test_admit_decision_predictive_unit():
    est = TierEstimator()
    slo = SLOConfig(deadline_s=1.0, predictive_shed=True,
                    service_safety=1.0)
    # cold estimator: never predictively sheds
    assert admit_decision(0, slo, est=est, now=0.0, deadline=0.01) == "admit"
    est.observe_chunk(0.5, rows=1)
    est.observe_wait(0.3)
    # predicted finish now + 0.3 + 0.5 = 0.8 <= 1.0: admit
    assert admit_decision(0, slo, est=est, now=0.0, deadline=1.0) == "admit"
    # deadline 0.7 < 0.8: shed though the queue is empty
    assert admit_decision(0, slo, est=est, now=0.0, deadline=0.7) == "shed"
    # no deadline: predictive shedding cannot bite
    assert admit_decision(0, slo, est=est, now=0.0, deadline=None) == "admit"
    # under the degrade contract a predicted miss degrades (a cheaper
    # tier may still answer in time) within the hard 2x bound
    slo_d = SLOConfig(deadline_s=1.0, predictive_shed=True,
                      service_safety=1.0, queue_cap=4, overload="degrade")
    assert admit_decision(0, slo_d, est=est, now=0.0,
                          deadline=0.7) == "degrade"
    assert admit_decision(8, slo_d, est=est, now=0.0,
                          deadline=0.7) == "shed"


# ---------------------------------------------------------------------------
# builder: strategy + joint + cache knobs (one tiny end-to-end build)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_build():
    from repro.serving import BuildConfig, build_pipeline

    cfg = BuildConfig(
        task="overruling", tiers=("GPT-J", "GPT-4"), train_queries=120,
        train_steps_cap=40, scorer_steps=60, budget_frac=0.5,
        contextual=True, budget_rate=5e-5, governor_window=16,
        router_steps=100, joint_search=True, joint_prompt_sizes=(0, 3, 5),
        cache_policy="lfu", cache_min_score=0.4, cache_ttl=3600.0,
        place_tiers=True,      # contextual placement: entry-aware replay
        router=RouterConfig(m=2, top_lists=4, sample=96), verbose=False)
    return build_pipeline(cfg), cfg


def test_build_cache_knobs_reach_the_cache(tiny_build):
    (pipe, _), cfg = tiny_build
    assert pipe.cache is not None
    assert pipe.cache.policy == "lfu"
    assert pipe.cache.min_score == pytest.approx(0.4)
    assert pipe.cache.ttl == pytest.approx(3600.0)
    assert pipe.cache.capacity == cfg.cache_capacity


def test_build_contextual_placement_uses_entry_aware_shares(tiny_build):
    """With a contextual router, the placement sizing replays the
    cascade WITH the learned entry tiers (all-enter-at-0 pending
    fractions would size the wrong tiers)."""
    (pipe, report), cfg = tiny_build
    placement = report["placement"]
    assert placement is not None
    assert len(placement.devices) == len(pipe.tiers)
    assert placement.shares is not None
    # shares are the entry-aware replay's tier_counts, normalized
    assert sum(placement.shares) == pytest.approx(1.0)
    for spec, dev in zip(pipe.tiers, placement.devices):
        assert spec.device is dev


def test_build_joint_respects_budget_and_is_valid(tiny_build):
    (pipe, report), cfg = tiny_build
    joint = report["joint"]
    assert joint is not None
    assert 0 <= joint["n_examples"] <= cfg.n_shot
    # every joint row (and the final cascade) respects its budget up to
    # the optimizer's subsample slack (see test_joint.py)
    assert all(r["avg_cost"] <= joint["budget"] * 1.3
               for r in joint["rows"])
    assert report["metrics"]["avg_cost"] <= report["budget"] * 1.3
    # the chosen shared prompt reached the pipeline's tiers
    for spec in pipe.tiers:
        assert spec.prompt is not None
        assert len(spec.prompt.example_ids) == joint["n_examples"]


def test_build_contextual_strategy_serves(tiny_build):
    from repro.data import synthetic

    (pipe, report), cfg = tiny_build
    assert pipe.strategy is not None
    assert pipe.strategy.router is not None
    assert pipe.strategy.governor is not None
    assert pipe.strategy.governor.budget_rate == pytest.approx(5e-5)
    test = synthetic.sample("overruling", 48, seed=9)
    res = pipe.serve(test.tokens)
    assert res.strategy is not None
    assert sum(res.strategy["entry_hist"]) == 48
    assert res.n == 48 and (res.stopped_at >= -1).all()
    # stream path carries the same strategy
    res2 = pipe.serve_stream(test.tokens)
    assert res2.strategy is not None
    assert res2.n == 48


# ---------------------------------------------------------------------------
# core.router: frontier + cost_to_match (previously example-only paths)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def router_market():
    data = simulate_market("OVERRULING", n=1600, seed=21)
    scores = simulate_scores(data, seed=22)
    return split_market(data, scores, frac=0.5, seed=23)


def test_frontier_monotone_and_budget_feasible(router_market):
    d_tr, _, s_tr, _ = router_market
    cost = np.asarray(d_tr.cost)
    budgets = np.linspace(cost.min(1).mean() * 1.2, cost.mean(0).max(), 6)
    cfg = RouterConfig(top_lists=10, sample=256)
    pts = frontier(d_tr, s_tr, budgets, cfg)
    assert [p["budget"] for p in pts] == pytest.approx(list(budgets))
    # every point respects its budget up to the subsample slack
    assert all(p["avg_cost"] <= p["budget"] * 1.3 for p in pts)
    # accuracy is (weakly) monotone along the frontier, small grid noise
    accs = [p["acc"] for p in pts]
    for lo, hi in zip(accs, accs[1:]):
        assert hi >= lo - 0.02
    assert accs[-1] > accs[0]


def test_cost_to_match_consistent_with_evaluate_offline(router_market):
    d_tr, d_te, s_tr, s_te = router_market
    cfg = RouterConfig(top_lists=10, sample=256)
    # a mid-frontier operating point as the target
    target = float(np.asarray(d_tr.accuracy()).max()) - 0.01
    best = cost_to_match(d_tr, s_tr, d_te, s_te, target, cfg, n_steps=8)
    assert best is not None
    assert best["acc"] >= target
    # reported metrics ARE evaluate_offline of the returned cascade on
    # the test split
    m = evaluate_offline(best["cascade"], d_te, s_te)
    assert m["acc"] == pytest.approx(best["acc"])
    assert m["avg_cost"] == pytest.approx(best["avg_cost"])
    # the bisection returned the spend actually needed, not the cap
    hi = float(np.asarray(d_tr.cost).max(1).mean()) * 1.5
    assert best["budget"] < hi