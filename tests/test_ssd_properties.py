"""Property-based tests (hypothesis) for SSD and MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import LayerSpec, ModelConfig, MoECfg
from repro.models.moe import apply_moe, capacity, init_moe
from repro.models.ssm import ssd_chunked
from repro.kernels.ssd_scan.ref import ssd_ref


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), chunk=st.sampled_from([8, 16, 32]),
       s=st.sampled_from([32, 48, 64]))
def test_ssd_chunk_size_invariance(seed, chunk, s):
    """SSD output must not depend on the chunk size (incl. non-divisible
    lengths, which exercise the padding path)."""
    key = jax.random.PRNGKey(seed)
    b, h, p, n = 1, 2, 8, 4
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    a = -jnp.exp(0.2 * jax.random.normal(key, (h,)))
    bm = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, n))
    cm = jax.random.normal(jax.random.PRNGKey(seed + 2), (b, s, n))
    y1, _ = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    ref = ssd_ref(x, dt, a, bm, cm)
    err = float(jnp.abs(y1 - ref).max() / (jnp.abs(ref).max() + 1e-6))
    assert err < 1e-4, (chunk, s, err)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_ssd_prefill_state_continues_correctly(seed):
    """Running SSD on [0:s1] then continuing with init_state == running
    the full sequence."""
    key = jax.random.PRNGKey(seed)
    b, s, h, p, n = 1, 64, 2, 8, 4
    s1 = 32
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    a = -jnp.exp(0.2 * jax.random.normal(key, (h,)))
    bm = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, n))
    cm = jax.random.normal(jax.random.PRNGKey(seed + 2), (b, s, n))
    y_full, _ = ssd_chunked(x, dt, a, bm, cm, chunk=16)
    _, st1 = ssd_chunked(x[:, :s1], dt[:, :s1], a, bm[:, :s1], cm[:, :s1],
                         chunk=16)
    y2, _ = ssd_chunked(x[:, s1:], dt[:, s1:], a, bm[:, s1:], cm[:, s1:],
                        chunk=16, init_state=st1)
    err = float(jnp.abs(y_full[:, s1:] - y2).max()
                / (jnp.abs(y_full).max() + 1e-6))
    assert err < 1e-4, err


def _moe_cfg(n_experts=4, top_k=2, cf=1.25):
    return ModelConfig(
        name="p-moe", arch_type="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
        period=(LayerSpec("attn", "moe"),), n_periods=1,
        moe=MoECfg(n_experts=n_experts, top_k=top_k, d_expert=64,
                   capacity_factor=cf),
        dtype="float32")


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), cf=st.sampled_from([0.5, 1.0, 2.0, 8.0]))
def test_moe_output_finite_and_bounded(seed, cf):
    cfg = _moe_cfg(cf=cf)
    key = jax.random.PRNGKey(seed)
    params = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y, aux = apply_moe(params, x, cfg=cfg)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(aux))
    assert float(aux) >= 0.9  # E * sum f_e p_e >= 1 at balance, ~>=0.9 loose


def test_moe_high_capacity_equals_dropless():
    """cf large enough => no token drops => output invariant to cf."""
    key = jax.random.PRNGKey(0)
    cfg8 = _moe_cfg(cf=8.0)
    cfg16 = _moe_cfg(cf=16.0)
    params = init_moe(key, cfg8)
    x = jax.random.normal(key, (2, 32, 32))
    y8, _ = apply_moe(params, x, cfg=cfg8)
    y16, _ = apply_moe(params, x, cfg=cfg16)
    assert jnp.allclose(y8, y16, atol=1e-5)


def test_moe_capacity_formula():
    cfg = _moe_cfg(n_experts=4, top_k=2, cf=1.25)
    assert capacity(cfg, 64) == int(64 * 2 * 1.25 / 4)
    assert capacity(cfg, 1) == 1          # decode: at least one slot
