"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cascade_compact.ops import compact
from repro.kernels.cascade_compact.ref import compact_ref
from repro.kernels.decode_attention.ops import gqa_decode
from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.kernel import gmm
from repro.kernels.moe_gmm.ops import expert_mlp
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

KEY = jax.random.PRNGKey(7)


def _rand(shape, dtype=jnp.float32, key=KEY):
    return jax.random.normal(key, shape).astype(dtype)


@pytest.mark.parametrize("b,s,h,kvh,d", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA 2:1
    (1, 256, 8, 1, 32),     # MQA
    (2, 128, 4, 4, 128),    # MXU-width head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(b, s, h, kvh, d, dtype, causal, window):
    q = _rand((b, s, h, d), dtype)
    k = _rand((b, s, kvh, d), dtype, jax.random.PRNGKey(1))
    v = _rand((b, s, kvh, d), dtype, jax.random.PRNGKey(2))
    o = mha(q, k, v, causal=causal, window=window, interpret=True,
            bq=64, bk=64)
    g = h // kvh
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, 1).reshape(b * h, s, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, 1).reshape(b * h, s, d)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    r = attention_ref(qf, kf, vf, causal=causal, window=window)
    r = r.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert jnp.allclose(o.astype(jnp.float32), r.astype(jnp.float32),
                        atol=tol, rtol=tol), float(jnp.abs(o - r).max())


@pytest.mark.parametrize("b,s,h,kvh,d,length", [
    (2, 512, 4, 2, 64, 300),
    (1, 256, 8, 8, 32, 256),
    (2, 1024, 8, 2, 128, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, s, h, kvh, d, length, dtype):
    q = _rand((b, 1, h, d), dtype)
    k = _rand((b, s, kvh, d), dtype, jax.random.PRNGKey(1))
    v = _rand((b, s, kvh, d), dtype, jax.random.PRNGKey(2))
    o = gqa_decode(q, k, v, jnp.int32(length), bk=128, interpret=True)
    r = decode_ref(q.reshape(b, kvh, h // kvh, d), k, v, length)
    r = r.reshape(b, 1, h, d)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    assert jnp.allclose(o.astype(jnp.float32), r.astype(jnp.float32),
                        atol=tol, rtol=tol)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 32, 16, 32),
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, s, h, p, n, chunk, dtype):
    x = _rand((b, s, h, p), dtype)
    dt = jax.nn.softplus(_rand((b, s, h), key=jax.random.PRNGKey(1))
                         ).astype(dtype)
    a = -jnp.exp(0.3 * _rand((h,), key=jax.random.PRNGKey(2)))
    bm = _rand((b, s, n), dtype, jax.random.PRNGKey(3))
    cm = _rand((b, s, n), dtype, jax.random.PRNGKey(4))
    o = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    r = ssd_ref(x.astype(jnp.float32), dt.astype(jnp.float32), a,
                bm.astype(jnp.float32), cm.astype(jnp.float32))
    scale = float(jnp.abs(r).max()) + 1e-6
    err = float(jnp.abs(o.astype(jnp.float32) - r).max()) / scale
    assert err < (3e-2 if dtype == jnp.bfloat16 else 1e-5), err


@pytest.mark.parametrize("e,c,k,f", [
    (4, 256, 128, 256),
    (2, 128, 256, 128),
    (8, 128, 128, 384),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(e, c, k, f, dtype):
    x = _rand((e, c, k), dtype)
    w = _rand((e, k, f), dtype, jax.random.PRNGKey(1))
    o = gmm(x, w, interpret=True)
    r = gmm_ref(x, w)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    assert jnp.allclose(o.astype(jnp.float32), r.astype(jnp.float32),
                        atol=tol, rtol=tol)


def test_expert_mlp_against_einsum():
    e, c, d, f = 2, 128, 64, 128
    x = _rand((e, c, d))
    wg = _rand((e, d, f), key=jax.random.PRNGKey(1))
    wu = _rand((e, d, f), key=jax.random.PRNGKey(2))
    wd = _rand((e, f, d), key=jax.random.PRNGKey(3))
    o = expert_mlp(x, wg, wu, wd, interpret=True)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg)) * \
        jnp.einsum("ecd,edf->ecf", x, wu)
    r = jnp.einsum("ecf,efd->ecd", h, wd)
    assert jnp.allclose(o, r, atol=1e-3, rtol=1e-3)


# -- cascade pending-set compaction (gather + prefix-sum) -------------------
# accept-mask edge cases per the serving cascade: all-accept empties the
# pending set, none-accept keeps it whole, single rows and non-pow2
# batches must survive the fixed-shape padding. Both device backends are
# BIT-identical to the numpy oracle (the serving equivalence suite in
# tests/test_placement.py builds on this).


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("n", [1, 2, 7, 16, 33, 200])   # single row, non-pow2
def test_cascade_compact_sweep(n, backend):
    rng = np.random.default_rng(n)
    idx = rng.permutation(n).astype(np.int64) * 5       # non-trivial values
    for accept in (np.ones(n, bool),                    # all-accept
                   np.zeros(n, bool),                   # none-accept
                   rng.random(n) < 0.4):                # mixed
        keep = ~accept                                  # rejected rows stay
        ro, rc = compact_ref(idx, keep)
        o, c = compact(idx, keep, backend=backend)
        assert int(c) == rc
        assert np.array_equal(np.asarray(o), ro.astype(np.int32))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_cascade_compact_preserves_order_and_padding(backend):
    idx = np.array([40, 10, 30, 20, 50], np.int64)
    keep = np.array([True, False, True, True, False])
    o, c = compact(idx, keep, backend=backend, fill=-7)
    assert int(c) == 3
    assert np.asarray(o).tolist() == [40, 30, 20, -7, -7]   # original order


def test_cascade_compact_empty_and_validation():
    o, c = compact(np.zeros(0, np.int64), np.zeros(0, bool))
    assert int(c) == 0 and len(np.asarray(o)) == 0
    with pytest.raises(ValueError, match="backend"):
        compact(np.arange(4), np.ones(4, bool), backend="cuda")
    with pytest.raises(ValueError, match="1-D"):
        compact(np.arange(4), np.ones(3, bool))
    with pytest.raises(ValueError, match="1-D"):
        compact(np.arange(4).reshape(2, 2), np.ones((2, 2), bool))


@pytest.mark.parametrize("block", [8, 32])
def test_cascade_compact_pallas_multi_block(block):
    """The block-sequential kernel: survivors spanning many grid steps
    land at the right running offsets, later blocks overwrite earlier
    garbage tails, and non-multiple-of-block sizes pad cleanly."""
    rng = np.random.default_rng(3)
    n = 101                                  # not a multiple of any block
    idx = rng.permutation(n).astype(np.int64)
    for density in (0.0, 0.5, 1.0):
        keep = rng.random(n) < density if density not in (0.0, 1.0) \
            else np.full(n, bool(density))
        ro, rc = compact_ref(idx, keep)
        o, c = compact(idx, keep, backend="pallas", block=block)
        assert int(c) == rc
        assert np.array_equal(np.asarray(o), ro.astype(np.int32))
