"""Unified serving pipeline: completion-cache ring buffer, the single
cascade executor, router guard rails, and the 3-strategy pipeline
end-to-end on a 2-tier toy marketplace.

(Runs without hypothesis — keeps executor/cache coverage alive even when
the property-based modules skip.)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.approx import CompletionCache
from repro.core.cascade import (Cascade, CascadeTier, evaluate_offline,
                                execute_cascade, replay_tiers)
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.core.router import RouterConfig, _grid_eval, learn_cascade
from repro.core.simulate import MarketData, simulate_scores
from repro.serving.pipeline import ServeResult, ServingPipeline, TierSpec


def _unit(v):
    v = np.asarray(v, np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# completion cache: ring wraparound + accounting
# ---------------------------------------------------------------------------


def test_cache_ring_wraparound_evicts_oldest():
    cache = CompletionCache(capacity=4, threshold=0.99)
    # 6 orthogonal embeddings -> inserting all wraps the ring by 2
    emb = np.eye(6, 8, dtype=np.float32)
    cache.insert(emb[:4], np.arange(4, dtype=np.int32))
    assert cache._next == 0                     # exactly full, wrapped to 0
    cache.insert(emb[4:], np.arange(4, 6, dtype=np.int32))
    assert cache._next == 2
    # entries 0 and 1 were evicted (slots reused by 4 and 5)
    hit, ans = cache.lookup(emb)
    assert hit.tolist() == [False, False, True, True, True, True]
    assert ans[2:].tolist() == [2, 3, 4, 5]


def test_cache_hit_miss_accounting():
    cache = CompletionCache(capacity=8, threshold=0.99)
    emb = np.eye(3, 4, dtype=np.float32)
    hit, _ = cache.lookup(emb)                  # empty cache: all miss
    assert not hit.any() and cache.misses == 3 and cache.hits == 0
    cache.insert(emb, np.array([7, 8, 9], np.int32))
    hit, ans = cache.lookup(emb)
    assert hit.all() and ans.tolist() == [7, 8, 9]
    assert cache.hits == 3 and cache.misses == 3
    assert cache.hit_rate == pytest.approx(0.5)


def test_cache_insert_batch_larger_than_capacity():
    """A single insert bigger than the ring must keep the NEWEST entries
    (wraparound self-overwrite) and leave ``_next`` pointing at the
    oldest surviving slot."""
    cache = CompletionCache(capacity=4, threshold=0.99)
    emb = np.eye(9, 12, dtype=np.float32)
    cache.insert(emb, np.arange(9, dtype=np.int32))
    assert cache._next == 1                     # (0 + 9) % 4
    hit, ans = cache.lookup(emb)
    # only the newest capacity-many entries (5..8) survive
    assert hit.tolist() == [False] * 5 + [True] * 4
    assert ans[5:].tolist() == [5, 6, 7, 8]
    # the next insert overwrites the oldest survivor (entry 5), not a
    # newer one
    cache.insert(_unit(np.ones((1, 12))), np.array([99], np.int32))
    hit, _ = cache.lookup(emb)
    assert hit.tolist() == [False] * 6 + [True] * 3


def test_cache_lookup_miss_counting_before_any_insert():
    cache = CompletionCache(capacity=4, threshold=0.9)
    emb = np.eye(5, 8, dtype=np.float32)
    hit, ans = cache.lookup(emb)
    assert not hit.any()
    assert (ans == 0).all() and ans.dtype == np.int32
    assert cache.misses == 5 and cache.hits == 0
    assert cache.hit_rate == 0.0
    cache.lookup(emb[:2])                       # still empty: keep counting
    assert cache.misses == 7 and cache.hits == 0


def test_cache_near_duplicate_threshold():
    cache = CompletionCache(capacity=8, threshold=0.9)
    base = _unit(np.ones((1, 16)))
    near = _unit(np.ones((1, 16)) + 0.1 * np.eye(1, 16))     # sim ~ 1
    far = _unit(np.eye(1, 16))                               # sim = 0.25
    cache.insert(base, np.array([3], np.int32))
    hit, ans = cache.lookup(near)
    assert hit[0] and ans[0] == 3
    hit, _ = cache.lookup(far)
    assert not hit[0]


def test_cache_lru_eviction_order():
    """LRU evicts the least-recently-USED entry; a lookup hit refreshes
    its entry where the FIFO ring would still cycle it out."""
    cache = CompletionCache(capacity=4, threshold=0.99, policy="lru")
    emb = np.eye(6, 8, dtype=np.float32)
    cache.insert(emb[:4], np.arange(4, dtype=np.int32))
    hit, _ = cache.lookup(emb[0:1])             # touch entry 0: now MRU
    assert hit[0]
    cache.insert(emb[4:5], np.array([4], np.int32))
    # entry 1 (least recently used) was evicted — NOT entry 0
    hit, _ = cache.lookup(emb[1:2])             # miss: no refresh
    assert not hit[0]
    hit, ans = cache.lookup(emb[0:1])           # survived, refreshed again
    assert hit[0] and ans[0] == 0
    # next victim is entry 2 (oldest untouched); 0/3/4 survive
    cache.insert(emb[5:6], np.array([5], np.int32))
    hit, _ = cache.lookup(emb[2:3])
    assert not hit[0]
    for i, want in [(0, 0), (3, 3), (4, 4), (5, 5)]:
        hit, ans = cache.lookup(emb[i:i + 1])
        assert hit[0] and ans[0] == want


def test_cache_lru_fills_invalid_slots_first():
    cache = CompletionCache(capacity=4, threshold=0.99, policy="lru")
    emb = np.eye(4, 8, dtype=np.float32)
    cache.insert(emb[:2], np.arange(2, dtype=np.int32))
    cache.insert(emb[2:], np.arange(2, 4, dtype=np.int32))
    hit, ans = cache.lookup(emb)                # nothing evicted yet
    assert hit.all() and ans.tolist() == [0, 1, 2, 3]


def test_cache_lru_insert_larger_than_capacity_keeps_newest():
    cache = CompletionCache(capacity=4, threshold=0.99, policy="lru")
    emb = np.eye(9, 12, dtype=np.float32)
    cache.insert(emb, np.arange(9, dtype=np.int32))
    hit, ans = cache.lookup(emb)
    assert hit.tolist() == [False] * 5 + [True] * 4
    assert ans[5:].tolist() == [5, 6, 7, 8]


def test_cache_lfu_eviction_order():
    """LFU evicts the least-frequently-used entry — a steady hot set
    survives a flood of one-off queries that would age everything out
    of an LRU."""
    cache = CompletionCache(capacity=4, threshold=0.99, policy="lfu")
    emb = np.eye(6, 8, dtype=np.float32)
    cache.insert(emb[:4], np.arange(4, dtype=np.int32))
    for _ in range(2):                          # entries 0, 1 become hot
        hit, _ = cache.lookup(emb[0:2])
        assert hit.all()
    # entries 2 and 3 are tied at zero hits; 2 is least recently used
    cache.insert(emb[4:5], np.array([4], np.int32))
    hit, _ = cache.lookup(emb[2:3])
    assert not hit[0]                           # 2 evicted
    # next victim: entry 3 (still zero hits; 4 was hit by the probe? no
    # — a miss refreshes nothing, and 4 has zero hits but is younger)
    cache.insert(emb[5:6], np.array([5], np.int32))
    hit, _ = cache.lookup(emb[3:4])
    assert not hit[0]                           # 3 evicted, 4 survived
    for i, want in [(0, 0), (1, 1), (4, 4), (5, 5)]:
        hit, ans = cache.lookup(emb[i:i + 1])
        assert hit[0] and ans[0] == want


def test_cache_lfu_tie_breaks_least_recently_used():
    """All-zero hit counts: the tie breaks on recency, and an insert
    resets the slot's count so a recycled slot doesn't inherit the old
    entry's popularity."""
    cache = CompletionCache(capacity=3, threshold=0.99, policy="lfu")
    emb = np.eye(5, 8, dtype=np.float32)
    cache.insert(emb[:3], np.arange(3, dtype=np.int32))
    cache.insert(emb[3:4], np.array([3], np.int32))   # evicts 0 (oldest)
    hit, _ = cache.lookup(emb[0:1])
    assert not hit[0]
    cache.insert(emb[4:5], np.array([4], np.int32))   # evicts 1, not 3
    hit, _ = cache.lookup(emb[1:2])
    assert not hit[0]
    hit, ans = cache.lookup(emb[3:4])
    assert hit[0] and ans[0] == 3


def test_cache_ttl_expires_at_lookup():
    """An entry older than ``ttl`` is invalidated AT LOOKUP — never
    served stale — on an injected clock (no sleeping)."""
    t = {"now": 0.0}
    cache = CompletionCache(capacity=4, threshold=0.99, ttl=10.0,
                            time_fn=lambda: t["now"])
    emb = np.eye(2, 8, dtype=np.float32)
    cache.insert(emb[0:1], np.array([7], np.int32))
    t["now"] = 5.0
    cache.insert(emb[1:2], np.array([8], np.int32))
    hit, ans = cache.lookup(emb)                # both inside their ttl
    assert hit.all() and ans.tolist() == [7, 8]
    t["now"] = 12.0                             # entry 0 is 12s old now
    hit, ans = cache.lookup(emb)
    assert hit.tolist() == [False, True] and ans[1] == 8
    assert cache.expired == 1
    t["now"] = 20.0                             # entry 1 expires too
    hit, _ = cache.lookup(emb)
    assert not hit.any() and cache.expired == 2
    # an expired slot is reusable: fresh insert serves again
    cache.insert(emb[0:1], np.array([9], np.int32))
    hit, ans = cache.lookup(emb[0:1])
    assert hit[0] and ans[0] == 9


def test_cache_insert_evicts_expired_before_live():
    """insert() expires stale entries first: an expired slot is the
    victim even when its tick/frequency sorts above a live entry's —
    otherwise the cache silently sheds live entries while dead ones
    squat in their slots."""
    t = {"now": 0.0}
    cache = CompletionCache(capacity=2, threshold=0.99, policy="lru",
                            ttl=10.0, time_fn=lambda: t["now"])
    emb = np.eye(3, 8, dtype=np.float32)
    cache.insert(emb[0:1], np.array([0], np.int32))     # A at t=0
    t["now"] = 8.0
    cache.insert(emb[1:2], np.array([1], np.int32))     # B at t=8
    t["now"] = 9.0
    hit, _ = cache.lookup(emb[0:1])                     # refresh A's tick
    assert hit[0]
    t["now"] = 12.0                                     # A expired, B live
    cache.insert(emb[2:3], np.array([2], np.int32))     # must evict A
    hit, ans = cache.lookup(emb[1:3])
    assert hit.tolist() == [True, True]                 # B survived
    assert ans.tolist() == [1, 2]


def test_cache_ttl_refresh_on_reinsert_and_validation():
    """Re-inserting an answer restamps its birth; bad ttl fails loudly."""
    t = {"now": 0.0}
    cache = CompletionCache(capacity=4, threshold=0.99, policy="lru",
                            ttl=10.0, time_fn=lambda: t["now"])
    emb = np.eye(1, 8, dtype=np.float32)
    cache.insert(emb, np.array([1], np.int32))
    t["now"] = 8.0
    cache.insert(emb, np.array([1], np.int32))  # lru: refills a slot now
    t["now"] = 15.0                             # 7s after the re-insert
    hit, ans = cache.lookup(emb)
    assert hit[0] and ans[0] == 1
    with pytest.raises(ValueError, match="ttl"):
        CompletionCache(ttl=0.0)
    with pytest.raises(ValueError, match="ttl"):
        CompletionCache(ttl=-1.0)


def test_cache_score_confidence_floor():
    """Answers the scorer distrusted are never cached; NaN (unscored
    last-tier answers) counts as trusted."""
    cache = CompletionCache(capacity=8, threshold=0.99, min_score=0.5)
    emb = np.eye(3, 8, dtype=np.float32)
    cache.insert(emb, np.array([10, 11, 12], np.int32),
                 scores=np.array([0.9, 0.2, np.nan]))
    hit, ans = cache.lookup(emb)
    assert hit.tolist() == [True, False, True]
    assert ans[0] == 10 and ans[2] == 12
    assert cache.skipped_low_score == 1
    # without scores the floor cannot apply: entries are trusted
    cache.insert(emb[1:2], np.array([11], np.int32))
    hit, _ = cache.lookup(emb)
    assert hit.all()


def test_cache_rejects_unknown_policy():
    with pytest.raises(ValueError, match="eviction policy"):
        CompletionCache(policy="mru")


def test_pipeline_serve_respects_cache_floor():
    """End-to-end: with a floor above the scorer's accept scores, tier-0
    answers are not cached, so repeats go back through the tiers."""
    floor_cache = CompletionCache(capacity=32, threshold=0.99,
                                  min_score=0.95)
    pipe = _toy_pipeline()
    pipe.cache = floor_cache
    toks = np.arange(8 * 4, dtype=np.int32).reshape(8, 4)
    toks[:, 0] = np.arange(8)
    first = pipe.serve(toks)
    assert first.cache_misses == 8
    # tier-0 accepts score 0.9 < floor -> skipped; last-tier answers are
    # unscored (NaN) -> trusted and cached
    again = pipe.serve(toks)
    easy = toks[:, 0] % 2 == 0
    assert (again.stopped_at[easy] == 0).all()      # re-served by tiers
    assert (again.stopped_at[~easy] == -1).all()    # hit the cache
    assert floor_cache.skipped_low_score == 8       # 4 per pass


# ---------------------------------------------------------------------------
# the single cascade executor
# ---------------------------------------------------------------------------


def test_execute_cascade_batches_all_calls():
    """answer/cost and scorer calls are chunked to batch_size."""
    n, bs = 50, 16
    sizes = {"invoke": [], "score": []}

    def invoke(q):
        sizes["invoke"].append(len(q))
        return np.zeros(len(q), np.int32), np.ones(len(q))

    def scorer(q, a, j):
        sizes["score"].append(len(q))
        return np.zeros(len(q))            # reject all -> everything escalates

    tiers = [CascadeTier("a", invoke), CascadeTier("b", invoke)]
    res = execute_cascade(tiers, [0.5], scorer, np.arange(n), batch_size=bs)
    assert max(sizes["invoke"]) <= bs and max(sizes["score"]) <= bs
    assert sum(sizes["score"]) == n            # only tier 0 is scored
    assert res["tier_counts"] == [n, n]
    assert res["cost"].sum() == pytest.approx(2 * n)


def test_execute_cascade_threshold_count_mismatch():
    t = CascadeTier("a", lambda q: (np.zeros(len(q)), np.zeros(len(q))))
    with pytest.raises(ValueError, match="thresholds"):
        execute_cascade([t, t], [], lambda q, a, j: None, np.arange(3))


def test_replay_backend_matches_market_accuracy():
    rng = np.random.default_rng(0)
    n, k = 300, 3
    correct = (rng.uniform(size=(n, k)) < [0.6, 0.7, 0.9]).astype(np.float32)
    cost = np.array([1.0, 3.0, 10.0])[None] * np.ones((n, 1), np.float32)
    data = MarketData([f"t{i}" for i in range(k)], jnp.asarray(correct),
                      jnp.asarray(cost), jnp.ones(n, jnp.int32),
                      jnp.ones(n, jnp.int32), jnp.zeros(n))
    scores = simulate_scores(data, seed=1)
    m = evaluate_offline(Cascade((0, 2), (0.0,)), data, scores)
    # tau=0 accepts everything at tier 0
    assert m["acc"] == pytest.approx(float(correct[:, 0].mean()))
    assert m["avg_cost"] == pytest.approx(1.0)
    assert m["stop_fracs"] == [1.0, 0.0]
    tiers = replay_tiers(data, (0, 2))
    assert tiers[0].name == "t0" and tiers[1].name == "t2"


# ---------------------------------------------------------------------------
# router guard rail
# ---------------------------------------------------------------------------


def test_grid_eval_rejects_long_lists():
    rng = np.random.default_rng(2)
    n, k = 64, 5
    data = MarketData([f"t{i}" for i in range(k)],
                      jnp.asarray(rng.uniform(size=(n, k)) < 0.7, jnp.float32),
                      jnp.ones((n, k), jnp.float32), jnp.ones(n, jnp.int32),
                      jnp.ones(n, jnp.int32), jnp.zeros(n))
    scores = simulate_scores(data, seed=3)
    grid = jnp.linspace(0.0, 1.0, 4)
    with pytest.raises(ValueError, match="m=4"):
        _grid_eval((0, 1, 2, 3), data, scores, grid)
    with pytest.raises(ValueError, match="length 2 or 3"):
        _grid_eval((0,), data, scores, grid)
    # m in {2, 3} still works
    acc, cost = _grid_eval((0, 1), data, scores, grid)
    assert acc.shape == (4,)


def test_learn_cascade_m4_fails_loudly():
    rng = np.random.default_rng(4)
    n, k = 128, 5
    correct = (rng.uniform(size=(n, k)) <
               np.linspace(0.5, 0.9, k)).astype(np.float32)
    data = MarketData([f"t{i}" for i in range(k)], jnp.asarray(correct),
                      jnp.ones((n, k), jnp.float32), jnp.ones(n, jnp.int32),
                      jnp.ones(n, jnp.int32), jnp.zeros(n))
    scores = simulate_scores(data, seed=5)
    with pytest.raises(ValueError, match="cascade lists"):
        learn_cascade(data, scores, 10.0,
                      RouterConfig(m=4, top_lists=2, sample=64))


# ---------------------------------------------------------------------------
# the 3-strategy pipeline end-to-end on a 2-tier toy marketplace
# ---------------------------------------------------------------------------


def _toy_pipeline(with_cache=True, with_prompts=True):
    """2-tier toy marketplace: row-leading token parity decides difficulty.

    cheap tier answers 0, pricey answers 1; even-leading queries are
    'easy' (scorer accepts at tier 0), odd-leading escalate.
    """
    cheap = TierSpec("cheap", lambda t: np.zeros(len(t), np.int32),
                     ApiCost(10.0, 10.0, 0.0),
                     prompt=PromptSpec((0,), 100, 40) if with_prompts
                     else None)
    pricey = TierSpec("pricey", lambda t: np.ones(len(t), np.int32),
                      ApiCost(100.0, 100.0, 0.0),
                      prompt=PromptSpec((0, 1), 100, 40) if with_prompts
                      else None)

    def scorer(t, ans):
        return np.where(t[:, 0] % 2 == 0, 0.9, 0.1)

    def embed(tokens):
        # deterministic one-hot on the leading token: exact-repeat cache
        e = np.zeros((len(tokens), 64), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 64] = 1.0
        return e

    cache = CompletionCache(capacity=32, threshold=0.99) if with_cache else None
    return ServingPipeline(
        tiers=[cheap, pricey], thresholds=[0.5], scorer=scorer,
        cache=cache, embed=embed if with_cache else None,
        full_prompt_tokens=840, pad_token=-1, batch_size=8)


def test_pipeline_end_to_end_routing_cost_and_telemetry():
    pipe = _toy_pipeline()
    n = 24
    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)            # half even (easy) / half odd
    easy = toks[:, 0] % 2 == 0
    res = pipe.serve(toks)
    assert isinstance(res, ServeResult)
    # routing: easy stop at tier 0 with answer 0, hard escalate to tier 1
    assert (res.answers[easy] == 0).all() and (res.answers[~easy] == 1).all()
    assert (res.stopped_at[easy] == 0).all()
    assert (res.stopped_at[~easy] == 1).all()
    assert res.tier_counts == [n, n // 2]
    assert res.tier_names == ["cheap", "pricey"]
    # first pass: empty cache, all miss
    assert res.cache_hits == 0 and res.cache_misses == n
    assert res.cache_hit_rate == 0.0
    # prompt-adapted cost accounting: query tokens=4, cheap prefix 140,
    # pricey prefix 240, n_out=1
    cheap_cost = (4 + 140 + 1) * 10.0 / 1e7
    pricey_cost = (4 + 240 + 1) * 100.0 / 1e7
    assert res.cost[easy].mean() == pytest.approx(cheap_cost)
    assert res.cost[~easy].mean() == pytest.approx(cheap_cost + pricey_cost)
    # baseline: every query to the pricey tier with the FULL prompt
    assert res.baseline_cost == pytest.approx(n * (4 + 840 + 1) * 100.0 / 1e7)
    assert 0.0 < res.savings_frac < 1.0
    # prompt telemetry: tier0 saved 700/query on n, tier1 600 on n/2
    assert res.prompt_tokens_saved == n * 700 + (n // 2) * 600
    assert set(res.latency) == {"embed", "cache", "cascade", "insert",
                                "total"}


def test_pipeline_cache_absorbs_repeats():
    pipe = _toy_pipeline()
    toks = np.arange(16 * 4, dtype=np.int32).reshape(16, 4)
    toks[:, 0] = np.arange(16)
    first = pipe.serve(toks)
    again = pipe.serve(toks)
    # every repeat is a cache hit: zero cost, answers preserved, no tier
    # traffic
    assert again.cache_hits == 16 and again.cache_misses == 0
    assert again.cache_hit_rate == 1.0
    assert again.cost.sum() == 0.0
    assert (again.answers == first.answers).all()
    assert (again.stopped_at == -1).all()
    assert again.tier_counts == [0, 0]
    assert again.savings_frac == pytest.approx(1.0)


def test_pipeline_without_cache_or_prompts():
    pipe = _toy_pipeline(with_cache=False, with_prompts=False)
    toks = np.arange(8 * 4, dtype=np.int32).reshape(8, 4)
    toks[:, 0] = np.arange(8)
    res = pipe.serve(toks)
    assert res.cache_hits == 0 and res.cache_misses == 8
    assert res.prompt_tokens_saved == 0
    # unadapted: both tiers billed with the full 840-token prefix
    assert res.cost[0] == pytest.approx((4 + 840 + 1) * 10.0 / 1e7)


def test_pipeline_preserves_string_answers():
    """Regression: the pipeline forced answers through np.int32, which
    crashed on generation tiers returning strings; the executor's
    answer dtype must survive end-to-end."""
    tier = TierSpec("gen", lambda t: np.array([f"ans{x}" for x in t[:, 0]]),
                    ApiCost(1.0, 1.0, 0.0))
    pipe = ServingPipeline(tiers=[tier], thresholds=[], scorer=None,
                           full_prompt_tokens=10, pad_token=-1)
    toks = np.arange(4 * 4, dtype=np.int32).reshape(4, 4)
    toks[:, 0] = np.arange(4)
    res = pipe.serve(toks)
    assert res.answers.tolist() == ["ans0", "ans1", "ans2", "ans3"]
    assert res.answers.dtype.kind == "U"
    assert (res.cost > 0).all()


def test_pipeline_string_answers_skip_int_keyed_cache():
    """Non-integer answers must not be silently truncated into the
    int-keyed cache: insertion is skipped, lookups keep missing."""

    def embed(tokens):
        e = np.zeros((len(tokens), 16), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 16] = 1.0
        return e

    tier = TierSpec("gen", lambda t: np.array([f"s{x}" for x in t[:, 0]]),
                    ApiCost(1.0, 1.0, 0.0))
    cache = CompletionCache(capacity=8, threshold=0.99)
    pipe = ServingPipeline(tiers=[tier], thresholds=[], scorer=None,
                           cache=cache, embed=embed,
                           full_prompt_tokens=10, pad_token=-1)
    toks = np.arange(3 * 4, dtype=np.int32).reshape(3, 4)
    toks[:, 0] = np.arange(3)
    res = pipe.serve(toks)
    assert res.answers.tolist() == ["s0", "s1", "s2"]
    assert cache._emb is None                   # nothing was inserted
    again = pipe.serve(toks)                    # repeats still miss
    assert again.cache_hits == 0
    assert again.answers.tolist() == ["s0", "s1", "s2"]


def test_pipeline_mixed_cache_hits_and_int_answers_densify():
    """Int cache hits merged with int cascade answers stay one dense
    integer array (no object fallout from the dtype-preserving merge)."""
    pipe = _toy_pipeline()
    toks = np.arange(8 * 4, dtype=np.int32).reshape(8, 4)
    toks[:, 0] = np.arange(8)
    pipe.serve(toks[:4])                        # warm: first 4 cached
    res = pipe.serve(toks)                      # 4 hits + 4 fresh
    assert res.cache_hits == 4 and res.cache_misses == 4
    assert np.issubdtype(res.answers.dtype, np.integer)
    easy = toks[:, 0] % 2 == 0
    assert (res.answers[easy] == 0).all() and (res.answers[~easy] == 1).all()


def test_pipeline_stage_latency_syncs_jax_embed():
    """The embed stage timer must charge async jax dispatch to the embed
    stage (block_until_ready at the boundary), not to a later stage."""
    import jax.numpy as jnp_

    def lazy_embed(tokens):
        e = np.zeros((len(tokens), 16), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 16] = 1.0
        return jnp_.asarray(e) * 1.0            # a real device array

    cheap = TierSpec("cheap", lambda t: np.zeros(len(t), np.int32),
                     ApiCost(10.0, 10.0, 0.0))
    pipe = ServingPipeline(tiers=[cheap], thresholds=[], scorer=None,
                           cache=CompletionCache(capacity=8, threshold=0.99),
                           embed=lazy_embed, full_prompt_tokens=10,
                           pad_token=-1)
    toks = np.arange(4 * 4, dtype=np.int32).reshape(4, 4)
    toks[:, 0] = np.arange(4)
    res = pipe.serve(toks)
    assert set(res.latency) == {"embed", "cache", "cascade", "insert",
                                "total"}
    assert res.cache_misses == 4
    again = pipe.serve(toks)
    assert again.cache_hits == 4                # jax embeddings round-trip


def test_pipeline_baseline_uses_marketplace_top_tier():
    """Savings baseline must come from the marketplace top tier even when
    the learned cascade (budget fallback) doesn't end there."""
    cheap_only = ServingPipeline(
        tiers=[TierSpec("cheap", lambda t: np.zeros(len(t), np.int32),
                        ApiCost(10.0, 10.0, 0.0))],
        thresholds=[], scorer=None, full_prompt_tokens=100, pad_token=-1,
        baseline_price=ApiCost(1000.0, 1000.0, 0.0))
    toks = np.zeros((5, 4), np.int32)
    res = cheap_only.serve(toks)
    assert res.baseline_cost == pytest.approx(5 * (4 + 100 + 1) * 1000 / 1e7)
    assert res.savings_frac > 0.9        # vs ~0 against the cheap tier


def test_run_online_accepts_ragged_queries():
    from repro.core.cascade import run_online

    queries = [[1, 2], [3, 4, 5], [6]]

    def api(qs):
        return [len(q) for q in qs], [0.1] * len(qs)

    res = run_online(Cascade((0,), ()), queries, [api], scorer=None)
    assert res["answers"] == [2, 3, 1]
    assert res["stopped_at"].tolist() == [0, 0, 0]


def test_pipeline_requires_embed_with_cache():
    with pytest.raises(ValueError, match="embed"):
        ServingPipeline(tiers=[], thresholds=[], scorer=None,
                        cache=CompletionCache())
