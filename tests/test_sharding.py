"""Sharding rules + HLO collective parser unit tests (1-device safe)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS
from repro.launch.hlo import collective_bytes, _shape_bytes
from repro.launch import specs as S


class FakeMesh:
    """Duck-typed mesh for spec rules (no devices touched)."""

    def __init__(self, shape_by_name):
        self._s = shape_by_name

    @property
    def axis_names(self):
        return tuple(self._s)

    @property
    def shape(self):
        return dict(self._s)

    @property
    def size(self):
        n = 1
        for v in self._s.values():
            n *= v
        return n


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_spec_rules():
    from repro.sharding.rules import param_spec
    assert param_spec("embed/tok", (49152, 1024), MESH) == P("model", None)
    assert param_spec("embed/unembed", (1024, 49152), MESH) == P(None, "model")
    # attention heads sharded when divisible
    assert param_spec("prefix/0/mixer/wq", (512, 32, 128), MESH) == \
        P(None, "model", None)
    # gemma3: 4 heads not divisible by 16 -> replicated
    assert param_spec("prefix/0/mixer/wq", (1152, 4, 256), MESH) == \
        P(None, None, None)
    # MoE experts on the model axis, with the stacked period dim prepended
    assert param_spec("period/sub0/ffn/up", (58, 256, 7168, 2048), MESH) == \
        P(None, "model", None, None)
    # mamba inner dim
    assert param_spec("period/sub0/mixer/x_proj", (48, 2048, 4096), MESH) == \
        P(None, None, "model")


def test_param_spec_fsdp_adds_data_axis():
    from repro.sharding.rules import param_spec
    sp = param_spec("prefix/0/ffn/up/w", (5120, 14336), MESH, fsdp=True)
    assert sp == P("data", "model")


def test_batch_spec():
    from repro.sharding.rules import batch_spec
    assert batch_spec((256, 4096), MESH) == P("data", None)
    assert batch_spec((256, 4096), MESH_MP) == P(("pod", "data"), None)
    assert batch_spec((1, 4096), MESH) == P(None, None)    # batch 1


def test_cache_spec_long_context_shards_sequence():
    from repro.sharding.rules import cache_spec
    cfg = ARCHS["jamba-v0.1-52b"]
    # batch==1, KV heads (8) can't fill the 16-wide model axis: the long
    # sequence spreads over BOTH axes (flash-decode context parallelism)
    sp = cache_spec("period/sub3/mixer/k", (4, 1, 524288, 8, 128), MESH, cfg)
    assert sp == P(None, None, ("data", "model"), None, None)
    # batched decode: batch over data, sequence over model
    sp = cache_spec("period/sub3/mixer/k", (4, 128, 32768, 8, 128), MESH, cfg)
    assert sp == P(None, "data", "model", None, None)
    # heads that DO fill the axis keep head sharding (moonshot kv=16)
    sp = cache_spec("period/sub0/mixer/k", (47, 128, 32768, 16, 128), MESH,
                    ARCHS["moonshot-v1-16b-a3b"])
    assert sp == P(None, "data", None, "model", None)


def test_input_specs_cover_all_archs():
    for name, cfg in ARCHS.items():
        for shape in ("train_4k", "prefill_32k"):
            sp = S.input_specs(cfg, shape)
            assert "params" in sp and "batch" in sp
        if cfg.causal:
            sp = S.input_specs(cfg, "decode_32k")
            assert sp["batch"]["tokens"].shape == (128, 1)
            assert "cache" in sp


def test_vlm_specs_include_vision_and_mrope():
    sp = S.input_specs(ARCHS["qwen2-vl-72b"], "prefill_32k")
    assert sp["batch"]["vision_embeds"].shape == (32, 1024, 8192)
    assert sp["batch"]["mrope_pos"].shape == (3, 32, 32768)


def test_audio_specs_use_frame_embeddings():
    sp = S.input_specs(ARCHS["hubert-xlarge"], "prefill_32k")
    assert "tokens" not in sp["batch"]
    assert sp["batch"]["embeds"].shape == (32, 32768, 1280)


# ---------------------------------------------------------------------------
# HLO parser
# ---------------------------------------------------------------------------


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]") == 128 * 256 * 2
    assert _shape_bytes("(f32[4,4], s32[8])") == 64 + 32


def test_collective_parser_counts_while_bodies():
    hlo = """
HloModule test

%body (p: (s32[], bf16[64])) -> (s32[], bf16[64]) {
  %ag = bf16[128] all-gather(bf16[64] %x), replica_groups={}
  ROOT %t = (s32[], bf16[64]) tuple(...)
}

%cond (p: (s32[], bf16[64])) -> pred[] {
  %c = s32[] constant(58)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main (a: bf16[64]) -> bf16[64] {
  %ar = bf16[64] all-reduce(bf16[64] %a), to_apply=%add
  %w = (s32[], bf16[64]) while((s32[], bf16[64]) %init), condition=%cond, body=%body
  ROOT %out = bf16[64] get-tuple-element(%w), index=1
}
"""
    res = collective_bytes(hlo)
    assert res["bytes"]["all-reduce"] == 128
    assert res["bytes"]["all-gather"] == 58 * 256   # body x trip count
    assert res["counts"]["all-gather"] == 58


def test_collective_parser_real_lowering():
    """All-reduce from an actual 1-device jit lowering parses (possibly 0
    collectives — just must not crash)."""
    import jax.numpy as jnp
    f = jax.jit(lambda x: x @ x.T)
    txt = f.lower(jnp.ones((8, 8))).compile().as_text()
    res = collective_bytes(txt)
    assert res["total"] >= 0
