import os
import sys

# Tests run on the single CPU device (the dry-run sets its own 512-device
# flag in a separate process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
