"""Kernel-integration tests: the model stack with Pallas kernels enabled
(interpret mode) must match the pure-jnp reference path."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import LayerSpec, ModelConfig, MoECfg, SSMCfg
from repro.kernels import enable_kernels
from repro.models import transformer as T

KEY = jax.random.PRNGKey(3)


@pytest.fixture(autouse=True)
def _reset_kernels():
    yield
    enable_kernels(False)


def _cfg_dense():
    return ModelConfig(
        name="ki-dense", arch_type="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        period=(LayerSpec("attn", "dense"),), n_periods=2, pos="rope",
        ffn_act="swiglu", max_seq=512, dtype="float32")


def _cfg_moe():
    return ModelConfig(
        name="ki-moe", arch_type="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=128, vocab=512,
        period=(LayerSpec("attn", "moe"),), n_periods=2,
        moe=MoECfg(n_experts=4, top_k=2, d_expert=128, capacity_factor=2.0),
        pos="rope", ffn_act="swiglu", max_seq=512, dtype="float32")


def _cfg_ssm():
    return ModelConfig(
        name="ki-ssm", arch_type="ssm", n_layers=2, d_model=128,
        d_ff=0, vocab=512, period=(LayerSpec("mamba", "none"),), n_periods=2,
        ssm=SSMCfg(d_state=16, head_dim=32, expand=2, d_conv=4, chunk=64),
        pos="none", ffn_act="swiglu", tie_embeddings=True, max_seq=512,
        dtype="float32")


@pytest.mark.parametrize("make_cfg", [_cfg_dense, _cfg_moe, _cfg_ssm])
def test_train_forward_matches_reference(make_cfg):
    cfg = make_cfg()
    params = T.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 128), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    loss_ref, _ = T.forward_train(params, batch, cfg, remat=False)
    enable_kernels(True)
    loss_k, _ = T.forward_train(params, batch, cfg, remat=False)
    enable_kernels(False)
    assert jnp.allclose(loss_ref, loss_k, rtol=2e-4, atol=2e-4), \
        (float(loss_ref), float(loss_k))


def test_decode_matches_reference():
    cfg = _cfg_dense()
    params = T.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 129), 0, cfg.vocab)
    _, cache = T.prefill(params, {"tokens": toks[:, :-1]}, cfg, max_len=256)
    lg_ref, _ = T.decode_step(params, cache, toks[:, -1:], jnp.int32(128), cfg)
    enable_kernels(True)
    lg_k, _ = T.decode_step(params, cache, toks[:, -1:], jnp.int32(128), cfg)
    enable_kernels(False)
    err = float(jnp.abs(lg_ref - lg_k).max() / (jnp.abs(lg_ref).max() + 1e-9))
    assert err < 1e-3, err
