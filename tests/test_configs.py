"""Config system: pattern coverage, published param counts, shape support."""
import pytest

from repro.configs.base import INPUT_SHAPES, active_param_count, param_count
from repro.configs.registry import ARCHS, all_pairs, get_arch

# published total parameter counts (billions), tolerance 15%
PUBLISHED_B = {
    "starcoder2-15b": 15.5, "hubert-xlarge": 0.96, "deepseek-v3-671b": 671.0,
    "granite-moe-1b-a400m": 1.3, "mamba2-1.3b": 1.3, "mistral-nemo-12b": 12.2,
    "qwen2-vl-72b": 72.0, "jamba-v0.1-52b": 52.0, "gemma3-1b": 1.0,
}


def test_all_archs_present():
    assert len(ARCHS) == 10
    kinds = {c.arch_type for c in ARCHS.values()}
    assert kinds == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}


@pytest.mark.parametrize("name", list(ARCHS))
def test_layer_pattern_covers_stack(name):
    cfg = ARCHS[name]
    assert len(cfg.layers) == cfg.n_layers


@pytest.mark.parametrize("name,target", PUBLISHED_B.items())
def test_param_count_matches_published(name, target):
    n = param_count(ARCHS[name]) / 1e9
    assert abs(n - target) / target < 0.15, f"{name}: {n:.2f}B vs {target}B"


def test_moe_active_params_far_below_total():
    c = ARCHS["deepseek-v3-671b"]
    assert active_param_count(c) < 0.1 * param_count(c)


def test_reduced_variants_are_small():
    for cfg in ARCHS.values():
        r = cfg.reduced()
        assert r.n_layers <= 2
        assert r.d_model <= 512
        if r.moe:
            assert r.moe.n_experts <= 4


def test_shape_support_matrix():
    pairs = all_pairs()
    ok = [(a.name, s.name) for a, s, o, _ in pairs if o]
    skip = [(a.name, s.name) for a, s, o, _ in pairs if not o]
    assert len(ok) == 33 and len(skip) == 7
    # encoder-only: no decode
    assert ("hubert-xlarge", "decode_32k") in skip
    assert ("hubert-xlarge", "long_500k") in skip
    # full-attention: no long_500k
    for a in ("deepseek-v3-671b", "granite-moe-1b-a400m", "mistral-nemo-12b",
              "moonshot-v1-16b-a3b", "qwen2-vl-72b"):
        assert (a, "long_500k") in skip
    # sub-quadratic archs run long_500k
    for a in ("mamba2-1.3b", "jamba-v0.1-52b", "gemma3-1b", "starcoder2-15b"):
        assert (a, "long_500k") in ok


def test_get_arch_raises():
    with pytest.raises(KeyError):
        get_arch("nope")


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
