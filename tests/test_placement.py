"""Per-tier device placement + on-device cascade compaction: the
machine-checked equivalence guarantee.

The contract (ISSUE 5/6 / ROADMAP "Per-tier devices", "Cascade executor
on-device", "Multi-host sharded tiers"): placement and compaction are
*performance* knobs — every combination of {host, device, pallas}
pending-set compaction x {shared device, pinned per-tier devices,
per-tier mesh slices} x {serve, serial stream, parallel scheduler}
returns bit-identical answers, costs, stopped_at and tier_counts. The
suite drives randomly generated marketplaces (random tier models as
real jitted projections, random thresholds, random arrival traces)
through the full matrix:

  * property-based (hypothesis) when available, a deterministic seeded
    sweep always;
  * placement/mesh-plan units (traffic-share sizing, round-robin
    fallback, slice contiguity) and the fused on-device accept mask's
    threshold-rounding rule;
  * subprocess legs on forced 4- and 8-device CPU hosts, where pinned
    placement genuinely lands tiers on distinct devices and mesh slices
    genuinely split batches across devices (CI runs the whole module
    both ways too — see .github/workflows/ci.yml).
"""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.approx import CompletionCache
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.serving.guarantee import GuaranteeConfig, GuaranteeController
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.resilience import BreakerConfig, RetryPolicy
from repro.serving.sched import SLOConfig
from repro.serving.strategy import ServingStrategy
from repro.sharding.placement import place_params, plan_placement
from repro.sharding.tier_mesh import (TierMeshPlan, batch_sharding,
                                      plan_tier_meshes, shard_params)

COMPACTS = ("host", "device", "pallas")
WIDTH = 8                      # token width of the generated streams


@jax.jit
def _proj(w, t):
    """The random tier model: argmax of a random projection — a real
    jitted computation, so a pinned ``w`` pins the tier's compute."""
    return jnp.argmax(t.astype(jnp.float32) @ w, -1)


def _marketplace(seed: int, n_tiers: int) -> dict:
    """A random marketplace: per-tier projection weights, random
    escalating prices, random thresholds, a row-wise hash scorer.
    Everything derives from ``seed`` so every pipeline variant sees the
    exact same marketplace."""
    rng = np.random.default_rng(seed)
    return {
        "ws": [rng.standard_normal((WIDTH, 5)).astype(np.float32)
               for _ in range(n_tiers)],
        "prices": [ApiCost(10.0 * 3 ** j * float(rng.uniform(0.5, 1.5)),
                           10.0 * 3 ** j, 0.0) for j in range(n_tiers)],
        "thresholds": [float(t) for t in
                       np.round(rng.uniform(0.2, 0.8, n_tiers - 1), 3)],
        "scorer_p": int(rng.integers(3, 89)),
    }


def _pipeline(mp: dict, compact: str, placement, with_cache: bool,
              batch_size: int = 8) -> ServingPipeline:
    n_tiers = len(mp["ws"])
    tiers = []
    for j in range(n_tiers):
        dev = mesh = None
        if isinstance(placement, TierMeshPlan):
            # sharded leg: w lives (replicated) on the tier's slice and
            # each chunk is device_put across the slice boundary, batch
            # split over "data" — the same hop the sharded engine makes
            mesh = placement.for_tier(j)
            w = shard_params(jnp.asarray(mp["ws"][j]), mesh)

            def fn(t, w=w, mesh=mesh):
                td = jax.device_put(t, batch_sharding(mesh, len(t)))
                return np.asarray(_proj(w, td)).astype(np.int32)
        else:
            dev = placement.for_tier(j) if placement is not None else None
            w = place_params(jnp.asarray(mp["ws"][j]), dev)

            def fn(t, w=w):
                return np.asarray(_proj(w, t)).astype(np.int32)
        tiers.append(TierSpec(
            f"t{j}", fn, mp["prices"][j],
            prompt=PromptSpec(tuple(range(j + 1)), 100, 40),
            device=dev, mesh=mesh))

    p = mp["scorer_p"]

    def scorer(t, a):              # row-wise deterministic hash in [0,1]
        return ((t[:, 0].astype(np.int64) * p + a.astype(np.int64))
                % 97) / 96.0

    def embed(tokens):             # distinct rows -> distinct embeddings
        e = np.zeros((len(tokens), 64), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 64] = 1.0
        return e

    return ServingPipeline(
        tiers=tiers, thresholds=mp["thresholds"], scorer=scorer,
        cache=CompletionCache(capacity=128, threshold=0.99)
        if with_cache else None,
        embed=embed if with_cache else None,
        full_prompt_tokens=840, pad_token=-1, batch_size=batch_size,
        compact=compact)


def _tokens(seed: int, n: int) -> np.ndarray:
    toks = np.random.default_rng(seed + 7).integers(
        0, 50, size=(n, WIDTH)).astype(np.int32)
    toks[:, 0] = np.arange(n)      # distinct rows: no accidental cache
    return toks                    # twins to diverge the stream paths


def _assert_same(ref, res, tag: str):
    assert np.array_equal(ref.answers, res.answers), tag
    assert ref.answers.dtype == res.answers.dtype, tag
    assert (ref.cost == res.cost).all(), tag           # bit-identical f64
    assert np.array_equal(ref.stopped_at, res.stopped_at), tag
    assert ref.tier_counts == res.tier_counts, tag
    assert (ref.cache_hits, ref.cache_misses) == \
        (res.cache_hits, res.cache_misses), tag


def _run_matrix(seed: int, n: int = 16, n_tiers: int = 3,
                with_cache: bool = True, spread: bool = True):
    """One random marketplace through the full equivalence matrix."""
    mp = _marketplace(seed, n_tiers)
    toks = _tokens(seed, n)
    arrivals = (np.linspace(0.0, 0.02, n) if spread
                else np.zeros(n))
    # pinned plan sized by a synthetic compaction profile (cheap tiers
    # see the most traffic, like a real cascade); the sharded plan sizes
    # mesh slices from the same signal (data-parallel slices: exact)
    counts = [n_tiers - j for j in range(n_tiers)]
    pinned = plan_placement(n_tiers, tier_counts=counts)
    sharded = plan_tier_meshes(n_tiers, tier_counts=counts)
    ref = _pipeline(mp, "host", None, with_cache).serve(toks)
    for pname, placement in (("shared", None), ("pinned", pinned),
                             ("sharded", sharded)):
        for compact in COMPACTS:
            tag = f"seed={seed} {pname}/{compact}"
            _assert_same(ref, _pipeline(mp, compact, placement,
                                        with_cache).serve(toks),
                         tag + "/serve")
            _assert_same(ref, _pipeline(mp, compact, placement,
                                        with_cache).serve_stream(
                             toks, arrivals, parallel=False),
                         tag + "/serial")
            _assert_same(ref, _pipeline(mp, compact, placement,
                                        with_cache).serve_stream(
                             toks, arrivals, parallel=True),
                         tag + "/sched")
        # speculative scheduler leg: idle tiers pre-invoke rows still
        # decoding upstream; commit/cancel must leave everything
        # bit-identical — speculation only moves wall-clock
        _assert_same(ref, _pipeline(mp, "host", placement,
                                    with_cache).serve_stream(
                         toks, arrivals, parallel=True,
                         slo=SLOConfig(speculate=True, spec_depth=2,
                                       spec_idle_frac=None)),
                     f"seed={seed} {pname}/speculate")
        # resilience-enabled leg, zero faults injected: retry + breaker
        # dials wired through both cascade paths but nothing ever fails
        # — the fault-tolerance machinery must be observably inert
        # (ISSUE 8: disabled-or-idle == bit-identical)
        rp, bc = RetryPolicy(), BreakerConfig()
        res_batch = _pipeline(mp, "host", placement, with_cache)
        res_batch.retry, res_batch.breaker = rp, bc
        _assert_same(ref, res_batch.serve(toks),
                     f"seed={seed} {pname}/resilient-serve")
        _assert_same(ref, _pipeline(mp, "host", placement,
                                    with_cache).serve_stream(
                         toks, arrivals, parallel=True,
                         slo=SLOConfig(retry=rp, breaker=bc)),
                     f"seed={seed} {pname}/resilient-sched")
        # accuracy-guarantee legs (ISSUE 10): a strategy carrying only
        # a guarantee controller shadow-audits every miss against the
        # reference tier, yet served answers/costs/stopped_at stay
        # bit-identical on both paths — shadow traffic is measurement,
        # charged to its own meter, never service
        g_cfg = GuaranteeConfig(sample_frac=1.0, window=10 ** 6,
                                retrain=False)
        g_pipe = _pipeline(mp, "host", placement, with_cache)
        g_pipe.strategy = ServingStrategy(
            guarantee=GuaranteeController(g_cfg))
        g_res = g_pipe.serve(toks)
        _assert_same(ref, g_res, f"seed={seed} {pname}/guarantee-serve")
        assert g_pipe.strategy.guarantee.n_shadow == ref.cache_misses
        g_sched = _pipeline(mp, "host", placement, with_cache)
        g_sched.strategy = ServingStrategy(
            guarantee=GuaranteeController(g_cfg))
        _assert_same(ref, g_sched.serve_stream(toks, arrivals,
                                               parallel=True),
                     f"seed={seed} {pname}/guarantee-sched")
        assert g_sched.strategy.guarantee.n_shadow == ref.cache_misses
    return ref


# ---------------------------------------------------------------------------
# the equivalence matrix: deterministic sweep (always) + hypothesis
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,n,n_tiers,with_cache", [
    (0, 16, 3, True),
    (1, 24, 2, True),
    (2, 16, 4, False),
    (3, 9, 3, False),          # non-pow2 request count
    (4, 1, 2, True),           # single request
])
def test_equivalence_matrix_deterministic(seed, n, n_tiers, with_cache):
    _run_matrix(seed, n=n, n_tiers=n_tiers, with_cache=with_cache)


def test_equivalence_matrix_burst_arrivals():
    """All-at-t0 bursts (one admission wave) through the same matrix."""
    _run_matrix(5, n=12, n_tiers=3, spread=False)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                               # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1),
           n=st.integers(2, 24),
           n_tiers=st.integers(2, 4),
           with_cache=st.booleans(),
           spread=st.booleans())
    def test_equivalence_matrix_property(seed, n, n_tiers, with_cache,
                                         spread):
        """Hypothesis-driven: random marketplaces, thresholds and
        arrival traces — answers/costs/stopped_at/tier_counts are
        bit-identical across the whole placement x compaction x path
        matrix."""
        _run_matrix(seed, n=n, n_tiers=n_tiers, with_cache=with_cache,
                    spread=spread)


# ---------------------------------------------------------------------------
# placement-plan units
# ---------------------------------------------------------------------------


def test_plan_round_robin_fallback():
    devs = jax.local_devices()
    p = plan_placement(4, devices=devs)
    assert len(p.devices) == 4 and p.shares is None
    assert [d.id for d in p.devices] == \
        [devs[j % len(devs)].id for j in range(4)]
    # zero traffic falls back to round-robin too
    p0 = plan_placement(3, devices=devs, tier_counts=[0, 0, 0])
    assert [d.id for d in p0.devices] == \
        [devs[j % len(devs)].id for j in range(3)]


def test_plan_traffic_share_balances_load():
    """Heaviest tier gets a device to itself; the light tail shares.
    Uses fake device handles — the plan is pure bookkeeping."""
    class Dev:
        def __init__(self, i):
            self.id, self.platform = i, "cpu"

    devs = [Dev(0), Dev(1)]
    p = plan_placement(3, devices=devs, tier_counts=[90, 8, 2])
    assert p.devices[0].id != p.devices[1].id      # heavy tier isolated
    assert p.devices[1].id == p.devices[2].id      # light tail shares
    assert p.shares == pytest.approx((0.9, 0.08, 0.02))
    assert p.n_distinct == 2
    assert "->" in p.describe(["a", "b", "c"])


def test_plan_validation():
    with pytest.raises(ValueError, match="n_tiers"):
        plan_placement(0)
    with pytest.raises(ValueError, match="tier_counts"):
        plan_placement(3, tier_counts=[1, 2])
    with pytest.raises(ValueError, match="devices"):
        plan_placement(2, devices=[])


def test_mesh_plan_units():
    """Slice sizing: contiguity, >=1 device per tier, heavy tiers get
    more rows, round-robin wrap with fewer rows than tiers. Fake device
    handles — the plan is pure bookkeeping."""
    class Dev:
        def __init__(self, i):
            self.id, self.platform = i, "cpu"

    devs = [Dev(i) for i in range(8)]
    p = plan_tier_meshes(3, devices=devs, tier_counts=[16, 9, 4])
    assert p.devices_per_tier == (4, 3, 1)     # D'Hondt by share
    ids = [tuple(int(d.id) for d in m.devices.flat) for m in p.slices]
    assert ids == [(0, 1, 2, 3), (4, 5, 6), (7,)]   # contiguous, in order
    assert p.n_distinct == 3 and p.grid == (8, 1)
    assert all(m.axis_names == ("data", "model") for m in p.slices)
    assert "->" in p.describe(["a", "b", "c"])
    # explicit 2-D grid: rows are C wide on the model axis
    p2 = plan_tier_meshes(2, devices=devs, mesh_shape=(4, 2),
                          tier_counts=[3, 1])
    assert p2.devices_per_tier == (6, 2)
    assert p2.slices[0].shape == {"data": 3, "model": 2}
    # fewer rows than tiers: wrap round-robin onto shared rows
    p3 = plan_tier_meshes(3, devices=devs[:2])
    assert p3.devices_per_tier == (1, 1, 1) and p3.n_distinct == 2
    assert ([tuple(d.id for d in m.devices.flat) for m in p3.slices]
            == [(0,), (1,), (0,)])


def test_mesh_plan_validation():
    with pytest.raises(ValueError, match="n_tiers"):
        plan_tier_meshes(0)
    with pytest.raises(ValueError, match="tier_counts"):
        plan_tier_meshes(3, tier_counts=[1, 2])
    with pytest.raises(ValueError, match="devices"):
        plan_tier_meshes(2, devices=[])
    with pytest.raises(ValueError, match="mesh_shape"):
        plan_tier_meshes(2, mesh_shape=(0, 1))
    with pytest.raises(ValueError, match="needs"):
        plan_tier_meshes(2, mesh_shape=(64, 64))


# ---------------------------------------------------------------------------
# the fused on-device accept mask (core.cascade device_masks)
# ---------------------------------------------------------------------------


def test_accept_threshold_matches_host_rule():
    """The f32 threshold is ceil-rounded so the on-device comparison
    agrees with the host float64 rule for EVERY f32 score — including
    thresholds like 0.7 that round *down* in f32, where the naive cast
    accepts scores the host rule rejects."""
    from repro.core.cascade import _accept_threshold
    assert np.float32(0.7) >= np.float32(0.7)          # the naive trap
    assert not (np.float64(np.float32(0.7)) >= 0.7)    # host says no
    rng = np.random.default_rng(0)
    for t in (0.1, 0.3, 0.5, 0.7, 1e-3, 0.9999999, *rng.uniform(0, 1, 20)):
        t32 = _accept_threshold(np.float32, float(t))
        xs = rng.uniform(0, 1, 4096).astype(np.float32)
        xs = np.concatenate([xs, [np.float32(t), t32,
                                  np.nextafter(t32, np.float32(0))]])
        host = xs.astype(np.float64) >= t
        assert ((xs >= t32) == host).all(), t
    # f64 scores (x64 hosts): the threshold passes through exactly
    assert _accept_threshold(np.float64, 0.7) == 0.7
    # NaN scores never accept on either rule
    assert not (np.float32(np.nan) >= _accept_threshold(np.float32, 0.5))


def test_tier_step_fuses_device_mask():
    """A jax-native scorer yields a device accept mask (appended to
    device_masks) whose host transfer IS the returned accept — and the
    on-device executor's compaction consumes it bit-identically."""
    from repro.core.cascade import CascadeTier, execute_cascade, tier_step
    tier = CascadeTier("t", lambda q: (q[:, 0], np.ones(len(q))))
    chunk = np.arange(24, dtype=np.int32).reshape(6, 4)

    def jax_scorer(q, a, j):
        return jnp.asarray(q[:, 0]).astype(jnp.float32) / 24.0

    masks: list = []
    _, _, s, accept = tier_step(tier, chunk, 0, scorer=jax_scorer,
                                threshold=0.5, last=False,
                                device_masks=masks)
    assert len(masks) == 1 and isinstance(masks[0], jax.Array)
    assert np.array_equal(accept, np.asarray(masks[0]))
    assert np.array_equal(accept, s >= 0.5)            # host rule agrees
    # numpy scorers keep the host path (no device mask)
    masks = []
    tier_step(tier, chunk, 0, scorer=lambda q, a, j: np.ones(len(q)),
              threshold=0.5, last=False, device_masks=masks)
    assert masks == []
    # end-to-end: jax scorer through every compact mode, bit-identical
    tiers = [CascadeTier(f"t{j}", lambda q, j=j: (q[:, 0] + j,
                                                  np.full(len(q), 1.0 + j)))
             for j in range(3)]
    qs = np.random.default_rng(3).integers(
        0, 50, size=(33, 8)).astype(np.int32)

    def scorer(q, a, j):
        return (jnp.asarray(q[:, 0]).astype(jnp.float32) * 0.37 + j) % 1.0

    ref = execute_cascade(tiers, [0.4, 0.7], scorer, qs, batch_size=8)
    for mode in ("device", "pallas"):
        r = execute_cascade(tiers, [0.4, 0.7], scorer, qs, batch_size=8,
                            compact=mode)
        assert np.array_equal(ref["answers"], r["answers"]), mode
        assert (ref["cost"] == r["cost"]).all(), mode
        assert np.array_equal(ref["stopped_at"], r["stopped_at"]), mode
        assert np.array_equal(ref["scores"], r["scores"],
                              equal_nan=True), mode
        assert ref["tier_counts"] == r["tier_counts"], mode


def test_pipeline_rejects_unknown_compact_mode():
    mp = _marketplace(0, 2)
    with pytest.raises(ValueError, match="compact"):
        _pipeline(mp, "gpu-magic", None, False)
    from repro.core.cascade import execute_cascade

    with pytest.raises(ValueError, match="compact"):
        execute_cascade([], [], None, np.zeros((0, 4)), compact="nope")


def test_engine_pool_keys_on_device():
    """Same weights pinned to a device are a distinct pooled engine."""
    from repro.configs.registry import ARCHS
    from repro.models import transformer as T
    from repro.serving.engine import EnginePool

    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    pool = EnginePool()
    dev = jax.local_devices()[0]
    e_shared = pool.get(cfg, params)
    e_pinned = pool.get(cfg, params, device=dev)
    assert e_shared is not e_pinned and len(pool) == 2
    assert pool.get(cfg, params, device=dev) is e_pinned
    toks = np.arange(12, dtype=np.int32).reshape(2, 6) + 1
    assert np.array_equal(e_shared.generate(toks, n_new=3),
                          e_pinned.generate(toks, n_new=3))


def test_scheduler_reports_tier_devices():
    mp = _marketplace(0, 2)
    pinned = plan_placement(2, tier_counts=[3, 1])
    res = _pipeline(mp, "host", pinned, False).serve_stream(_tokens(0, 8))
    devs = res.ingress["tier_devices"]
    assert len(devs) == 2 and all(d is not None for d in devs)
    res = _pipeline(mp, "host", None, False).serve_stream(_tokens(0, 8))
    assert res.ingress["tier_devices"] == [None, None]


# ---------------------------------------------------------------------------
# speculative execution: commit/cancel edge cases (ISSUE 7). The matrix
# legs above prove bit-identity when speculation engages incidentally;
# these tiers are slow enough (time.sleep in invoke) that downstream
# workers reliably catch rows mid-decode, so each edge case is exercised
# deterministically rather than by racing the toy tiers.
# ---------------------------------------------------------------------------


def _slow_pipeline(scorer, delay: float = 0.08, n_tiers: int = 3,
                   fail: tuple[int, int] | None = None,
                   strategy=None, batch_size: int = 8) -> ServingPipeline:
    """Tiers that sleep inside invoke (slow 'decode'); ``fail=(j, k)``
    makes tier j's k-th invoke raise — the mid-decode shutdown case."""
    calls: dict[int, int] = {}
    tiers = []
    for j in range(n_tiers):
        def fn(t, j=j):
            calls[j] = calls.get(j, 0) + 1
            if fail is not None and fail == (j, calls[j]):
                raise RuntimeError("tier exploded mid-stream")
            time.sleep(delay)
            return t[:, 0].astype(np.int64) * 10 + j
        tiers.append(TierSpec(
            f"t{j}", fn, ApiCost(10.0 * 3 ** j, 10.0 * 3 ** j, 0.0),
            prompt=PromptSpec(tuple(range(j + 1)), 100, 40)))

    def embed(tokens):
        e = np.zeros((len(tokens), 8), np.float32)
        e[:, 0] = tokens[:, 0].astype(np.float32)
        return e

    return ServingPipeline(
        tiers=tiers, thresholds=[0.5] * (n_tiers - 1), scorer=scorer,
        strategy=strategy, embed=embed if strategy is not None else None,
        full_prompt_tokens=840, pad_token=-1, batch_size=batch_size)


def _spec_slo(**kw) -> SLOConfig:
    return SLOConfig(max_holdback_s=0.005, speculate=True, spec_depth=2,
                     spec_idle_frac=None, **kw)


def test_speculation_all_reject_commits():
    """Every row escalates to the last tier, so every speculative
    pre-invoke is eventually consumed: committed == issued > 0, nothing
    cancelled — and the stream is bit-identical to the non-speculative
    one (cost charged only on commit, through the same tier_step)."""
    def scorer(t, a):
        return np.zeros(len(t))

    toks = _tokens(11, 8)
    ref = _slow_pipeline(scorer).serve_stream(toks, parallel=True)
    res = _slow_pipeline(scorer).serve_stream(toks, parallel=True,
                                              slo=_spec_slo())
    _assert_same(ref, res, "all-reject")
    spec = res.ingress["speculation"]
    assert spec["issued"] > 0
    assert spec["committed"] == spec["issued"]
    assert spec["cancelled"] == 0
    assert spec["wasted_s"] == 0.0
    for key in ("spec_busy_s", "spec_chunks", "overlap_frac"):
        assert len(spec[key]) == 3, spec
    assert all(f == 0.0 for f in spec["overlap_frac"][:1])  # tier 0 never
    assert any(f > 0.0 for f in spec["overlap_frac"][1:])   # speculates
    # the summary surfaces the commit/cancel telemetry
    assert "speculation:" in res.summary()
    # the non-speculative stream reports no speculation block at all
    assert ref.ingress["speculation"] is None


def test_speculation_all_accept_cancels():
    """Every row is accepted at tier 0, so every speculative pre-invoke
    is wasted: committed == 0, cancelled == issued, wasted seconds
    accounted — and the stream is still bit-identical (cancelled work
    never charges cost or leaks answers)."""
    def scorer(t, a):
        return np.ones(len(t))

    toks = _tokens(12, 8)
    ref = _slow_pipeline(scorer).serve_stream(toks, parallel=True)
    res = _slow_pipeline(scorer).serve_stream(toks, parallel=True,
                                              slo=_spec_slo())
    _assert_same(ref, res, "all-accept")
    spec = res.ingress["speculation"]
    assert spec["issued"] > 0
    assert spec["committed"] == 0
    assert spec["cancelled"] == spec["issued"]
    assert spec["wasted_s"] > 0.0
    assert (res.stopped_at == 0).all()


def test_speculation_mid_decode_shutdown():
    """A tier crashing while downstream speculations are in flight must
    tear the scheduler down promptly (error surfaced, threads joined) —
    parked speculative state must not wedge shutdown."""
    def scorer(t, a):
        return np.zeros(len(t))

    toks = _tokens(13, 16)
    pipe = _slow_pipeline(scorer, fail=(0, 2))  # 2nd tier-0 chunk raises
    with pytest.raises(RuntimeError, match="exploded"):
        pipe.serve_stream(toks, max_chunk=8, parallel=True,
                          slo=_spec_slo())


def test_speculation_router_floor_and_cold_fallback():
    """With no router the candidate filter falls back to every decoding
    row (cold start must not disable speculation); with a router that
    predicts accept everywhere, the ``spec_bar`` probability floor
    suppresses all speculative work. Both streams stay bit-identical."""
    class _ConfidentRouter:
        # duck-typed ServingStrategy: predicts accept-at-entry for every
        # row, so no row ever qualifies under the probability floor
        governor = None
        router = object()            # scheduler only checks `is not None`

        def route(self, emb):
            n = len(emb)
            return np.zeros(n, np.int64), np.ones((n, 3), np.float64)

        def thresholds(self, base):
            return base

        def degrade_entry(self, probs, m):
            return 0

        def observe_request(self, cost, **kw):
            pass

        def snapshot(self, m):
            return None

    def scorer(t, a):
        return np.zeros(len(t))

    toks = _tokens(14, 8)
    ref = _slow_pipeline(scorer).serve_stream(toks, parallel=True)
    # cold: probs is None -> speculation_candidate fallback admits rows
    cold = _slow_pipeline(scorer).serve_stream(toks, parallel=True,
                                               slo=_spec_slo())
    _assert_same(ref, cold, "cold-router")
    assert cold.ingress["speculation"]["committed"] > 0
    # routed, all predicted-accept: the floor keeps workers from
    # speculating at all — same answers, zero speculative traffic
    routed = _slow_pipeline(scorer, strategy=_ConfidentRouter())
    res = routed.serve_stream(toks, parallel=True, slo=_spec_slo())
    _assert_same(ref, res, "confident-router")
    assert res.ingress["speculation"]["issued"] == 0


# ---------------------------------------------------------------------------
# the multi-device leg: forced 4-device CPU host (subprocess, like
# tests/test_shard_map_ops.py — this process keeps its single device)
# ---------------------------------------------------------------------------


def test_equivalence_on_forced_4_device_host():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
assert len(jax.devices()) == 4, jax.devices()
import test_placement as tp
from repro.sharding.placement import plan_placement
p = plan_placement(3, tier_counts=[16, 9, 4])
assert p.n_distinct == 3           # every tier on its own device
for seed in (0, 1):
    tp._run_matrix(seed, n=12, n_tiers=3)
print("PLACEMENT-4DEV-OK")
"""
    _run_forced_device_subprocess(code, "PLACEMENT-4DEV-OK")


def _run_forced_device_subprocess(code: str, sentinel: str):
    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), here]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert sentinel in out.stdout, out.stderr[-3000:]


def test_sharded_equivalence_on_forced_8_device_host():
    """The full {shared, pinned, sharded} x {host, device, pallas} x
    {serve, serial, sched} matrix on a forced 8-device host, where the
    sharded slices genuinely span multiple devices and pow2 chunks are
    genuinely batch-split over their "data" axes."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
assert len(jax.devices()) == 8, jax.devices()
import test_placement as tp
from repro.sharding.tier_mesh import plan_tier_meshes
p = plan_tier_meshes(3, tier_counts=[16, 9, 4])
assert p.devices_per_tier == (4, 3, 1)   # heavy tiers get wide slices
assert p.n_distinct == 3
for seed in (0, 1):
    tp._run_matrix(seed, n=16, n_tiers=3)
print("PLACEMENT-8DEV-OK")
"""
    _run_forced_device_subprocess(code, "PLACEMENT-8DEV-OK")
