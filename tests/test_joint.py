"""Joint prompt + LLM selection (paper §3 Compositions)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.joint import joint_prompt_cascade, reprice_for_prompt
from repro.core.router import RouterConfig
from repro.core.simulate import simulate_market, simulate_scores


@pytest.fixture(scope="module")
def market():
    data = simulate_market("HEADLINES", n=1500, seed=0)
    scores = simulate_scores(data, seed=1)
    return data, scores


def test_reprice_shorter_prompt_is_cheaper(market):
    data, _ = market
    d0 = reprice_for_prompt(data, "HEADLINES", 0)
    d8 = reprice_for_prompt(data, "HEADLINES", 8)
    assert float(d0.cost.mean()) < float(d8.cost.mean())
    # full prompt == original costs
    assert np.allclose(np.asarray(d8.cost), np.asarray(data.cost), rtol=1e-5)


def test_reprice_fewer_shots_hurts_accuracy(market):
    data, _ = market
    d0 = reprice_for_prompt(data, "HEADLINES", 0, seed=3)
    assert float(d0.correct.mean()) < float(data.correct.mean())


def test_joint_beats_fixed_full_prompt_at_tight_budget(market):
    data, scores = market
    g4 = data.names.index("GPT-4")
    budget = float(data.cost[:, g4].mean()) / 10
    cfg = RouterConfig(top_lists=8, sample=256)
    best, rows = joint_prompt_cascade(data, scores, "HEADLINES", budget,
                                      cfg=cfg, prompt_sizes=[0, 4, 8])
    full = [r for r in rows if r["n_examples"] == 8][0]
    assert best["acc"] >= full["acc"] - 1e-9     # joint can only help
    # the paper's optimizer enforces the budget on a training SUBSAMPLE
    # ("approximates the objective by interpolating it within a few
    # samples"); full-set cost can exceed it by the sampling error
    assert all(r["avg_cost"] <= budget * 1.3 for r in rows)
