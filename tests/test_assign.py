"""Window assignment (repro.serving.assign): the budgeted assignment
solver against a brute-force oracle, jit stability across window sizes,
the window meta-model's expected-cost/utility chain, window buffering
semantics, the assigner's budget/caps policy, and pipeline + scheduler
integration of the third routing mode (structurally absent when off)."""
import itertools
import math

import numpy as np
import pytest

from repro.core.cost import ApiCost
from repro.serving.assign import (AssignConfig, SolverConfig, WindowAssigner,
                                  WindowBuffer, correctness_labels,
                                  solve_assignment, train_window_meta)
from repro.serving.assign.solver import TRACE_COUNT, pow2_rows
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.strategy import BudgetGovernor, ServingStrategy

D = 8          # toy embedding width


# ---------------------------------------------------------------------------
# solver units
# ---------------------------------------------------------------------------


def _oracle(u, c, caps, budget):
    """Brute force: best total utility over every feasible assignment
    (None when no assignment satisfies caps + budget)."""
    n, m = u.shape
    best = None
    for a in itertools.product(range(m), repeat=n):
        if caps is not None:
            counts = np.bincount(a, minlength=m)
            if (counts > caps).any():
                continue
        if c[np.arange(n), a].sum() > budget + 1e-12:
            continue
        val = u[np.arange(n), a].sum()
        if best is None or val > best:
            best = val
    return best


def _random_instance(rng):
    n = int(rng.integers(2, 8))
    m = int(rng.integers(2, 5))
    u = rng.random((n, m))
    # cheap tiers less useful on average, like the real marketplace
    u.sort(axis=1)
    c = np.cumsum(rng.random((n, m)) * 1e-4, axis=1)   # increasing in tier
    caps = None
    if rng.random() < 0.6:
        caps = rng.integers(1, n + 1, size=m).astype(float)
        while caps.sum() < n:                           # keep it satisfiable
            caps[rng.integers(m)] += 1
    budget = float(rng.uniform(0.3, 1.2) * c[:, -1].sum())
    return u, c, caps, budget


def _check_against_oracle(u, c, caps, budget):
    n, m = u.shape
    res = solve_assignment(u, c, caps, budget)
    a = res["assignment"]
    assert a.shape == (n,) and ((0 <= a) & (a < m)).all()
    if caps is not None:
        assert (np.bincount(a, minlength=m) <= caps + 1e-9).all()
    realized = c[np.arange(n), a].sum()
    assert res["predicted_cost"] == pytest.approx(realized, abs=1e-12)
    best = _oracle(u, c, caps, budget)
    if res["feasible"]:
        assert realized <= budget * (1 + 1e-6) + 1e-12
        assert best is not None, "solver claims feasible, oracle disagrees"
        got = u[np.arange(n), a].sum()
        assert got >= best - 1e-6, (got, best)
    else:
        assert best is None, "oracle found a feasible point solver missed"


def test_solver_matches_bruteforce_oracle_seeded_sweep():
    rng = np.random.default_rng(0)
    for _ in range(40):
        _check_against_oracle(*_random_instance(rng))


def test_solver_unconstrained_is_rowwise_argmax():
    rng = np.random.default_rng(1)
    u = rng.random((12, 4))
    c = rng.random((12, 4)) * 1e-5
    res = solve_assignment(u, c, None, math.inf)
    assert res["feasible"]
    assert np.array_equal(res["assignment"], u.argmax(1))


def test_solver_budget_squeezes_toward_cheap_tiers():
    rng = np.random.default_rng(2)
    n, m = 16, 3
    u = np.tile([0.3, 0.6, 0.9], (n, 1)) + 0.01 * rng.random((n, m))
    c = np.tile([1e-5, 1e-4, 1e-3], (n, 1))
    rich = solve_assignment(u, c, None, math.inf)
    poor = solve_assignment(u, c, None, n * 3e-5)
    assert (rich["assignment"] == 2).all()
    assert poor["feasible"]
    assert poor["predicted_cost"] <= n * 3e-5 * (1 + 1e-6)
    assert poor["predicted_utility"] < rich["predicted_utility"]


def test_solver_relaxes_insufficient_caps():
    u = np.array([[0.2, 0.9]] * 4)
    c = np.full((4, 2), 1e-5)
    res = solve_assignment(u, c, np.array([1.0, 1.0]), math.inf)
    a = res["assignment"]                 # caps sum < n: scaled up to fit
    assert len(a) == 4
    counts = np.bincount(a, minlength=2)
    assert counts.sum() == 4 and counts.max() <= 2


def test_solver_validation_and_empty_window():
    u = np.zeros((3, 2))
    with pytest.raises(ValueError):
        solve_assignment(u, np.zeros((2, 2)), None, 1.0)
    with pytest.raises(ValueError):
        solve_assignment(u, np.zeros((3, 2)), np.zeros(3), 1.0)
    res = solve_assignment(np.zeros((0, 2)), np.zeros((0, 2)), None, 1.0)
    assert len(res["assignment"]) == 0 and res["feasible"]


def test_solver_jit_stable_across_pow2_padded_sizes():
    """One trace per (padded size, tier count, config) — ragged window
    sizes that pad to the same pow2 must NOT retrace."""
    cfg = SolverConfig(repair_iters=32, swap_iters=16)
    rng = np.random.default_rng(3)

    def solve(n):
        u = rng.random((n, 3))
        c = rng.random((n, 3)) * 1e-5
        solve_assignment(u, c, None, float(n) * 5e-6, cfg)

    solve(8)                                   # warm the (8, 3) trace
    base = TRACE_COUNT[0]
    for n in (5, 6, 7, 8, 3, 4, 8):            # all pad to 4 or 8
        assert pow2_rows(n) in (4, 8)
        solve(n)
    assert TRACE_COUNT[0] == base + 1          # exactly the (4, 3) trace


try:                                           # property-based variant of
    import hypothesis                          # the oracle sweep, when the
except ImportError:                            # container has hypothesis
    hypothesis = None


@pytest.mark.skipif(hypothesis is None, reason="hypothesis not installed")
def test_solver_oracle_property():
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000))
    def prop(seed):
        _check_against_oracle(
            *_random_instance(np.random.default_rng(seed)))

    prop()


# ---------------------------------------------------------------------------
# meta-model units
# ---------------------------------------------------------------------------


def _toy_meta(n_tiers=2, seed=0, steps=200):
    """Meta trained on separable features: emb[0] > 0 => tier 0 accepts
    and answers correctly; the last tier always accepts."""
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(600, D)).astype(np.float32)
    acc = np.zeros((600, n_tiers), np.float32)
    acc[:, 0] = emb[:, 0] > 0
    acc[:, 1:] = 1.0
    return train_window_meta(emb, acc, acc.copy(), steps=steps, seed=seed)


def test_correctness_labels_gather():
    correct = np.array([[1.0, 0.0, 1.0], [0.0, 1.0, 0.0]])
    y = correctness_labels(correct, apis=(2, 0))
    assert y.tolist() == [[1.0, 1.0], [0.0, 0.0]]


def test_meta_learns_separable_accept():
    meta = _toy_meta()
    rng = np.random.default_rng(1)
    emb = rng.normal(size=(300, D)).astype(np.float32)
    pa = meta.accept_probs(emb)
    assert pa.shape == (300, 2)
    assert (((pa[:, 0] > 0.5) == (emb[:, 0] > 0)).mean()) > 0.9


def test_meta_chain_scores_closed_form():
    """utility/exp_cost must compose the accept/correct heads exactly as
    the cascade stops: reach_k = prod_{j<k}(1 - p_acc_j)."""
    meta = _toy_meta(n_tiers=3, steps=60)
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(50, D)).astype(np.float32)
    prices = np.cumsum(rng.random((50, 3)) * 1e-4, axis=1)
    util, cost = meta.scores(emb, prices)
    pa, pc = meta.predict(emb)
    for k in range(50):
        reach, eu, ec = 1.0, 0.0, 0.0
        for j in range(3):
            ec += reach * prices[k, j]
            stop = reach if j == 2 else reach * pa[k, j]
            eu += stop * pc[k, j]
            reach *= 1.0 - pa[k, j]
        # entry = tier 0 column of the (n, m) matrices
        assert cost[k, 0] == pytest.approx(ec, rel=2e-3, abs=1e-9)
        assert util[k, 0] == pytest.approx(eu, rel=2e-3, abs=1e-6)


# ---------------------------------------------------------------------------
# window buffering + assigner policy
# ---------------------------------------------------------------------------


def test_assign_config_validation():
    with pytest.raises(ValueError, match="window_size"):
        AssignConfig(window_size=0)
    with pytest.raises(ValueError, match="window_budget"):
        AssignConfig(window_budget=0.0)
    with pytest.raises(ValueError, match="capacity_frac"):
        AssignConfig(capacity_frac=1.5)


def test_window_buffer_due_and_partial_drain():
    buf = WindowBuffer(AssignConfig(window_size=4, max_wait_s=0.1))
    assert not buf.due(0.0) and buf.next_due() == math.inf
    for i in range(3):
        buf.add(i, now=0.01 * i)
    assert not buf.due(0.05)                   # not full, not aged
    assert buf.due(0.11)                       # oldest aged out
    buf.add(3, now=0.05)
    assert buf.due(0.06)                       # full
    assert buf.drain(2) == [0, 1]              # oldest first
    assert len(buf) == 2
    assert buf.next_due() == pytest.approx(0.02 + 0.1)
    assert buf.drain() == [2, 3] and len(buf) == 0


def test_window_buffer_deadline_pressure():
    buf = WindowBuffer(AssignConfig(window_size=8, max_wait_s=10.0))
    buf.add("a", now=0.0, deadline=1.0)
    assert not buf.due(0.5)
    assert buf.due(0.5, pressure_s=0.6)        # solving would overshoot
    assert buf.next_due() == 1.0


def test_assigner_budget_prorated_and_governor_squeeze():
    meta = _toy_meta(steps=40)
    asg = WindowAssigner(meta=meta, cfg=AssignConfig(
        window_size=8, window_budget=8e-4))
    assert asg.budget_for(8) == pytest.approx(8e-4)
    assert asg.budget_for(2) == pytest.approx(2e-4)   # pro-rated to fill
    gov = BudgetGovernor(1e-4, (0.5,), window=4)
    free = WindowAssigner(meta=meta, cfg=AssignConfig(window_size=8))
    assert free.budget_for(8, gov) == pytest.approx(8e-4)
    for _ in range(8):
        gov.observe(1.0)                       # way over budget
    assert gov.shift > 0
    assert free.budget_for(8, gov) < 8e-4      # hot stream: leaner windows
    assert free.budget_for(8) == math.inf      # no budget source at all


def test_assigner_caps_derated_by_utilization():
    meta = _toy_meta(steps=40)
    asg = WindowAssigner(meta=meta, cfg=AssignConfig(
        window_size=8, capacity_frac=0.5))
    caps = asg.caps_for(8, 2)
    assert caps.tolist() == [4.0, 4.0]
    derated = asg.caps_for(8, 2, utilization=[0.9, 0.0])
    assert derated[0] == 1.0                   # floored, never fully fenced
    assert derated[1] == 4.0
    none_cfg = WindowAssigner(meta=meta, cfg=AssignConfig())
    assert none_cfg.caps_for(8, 2) is None


def test_assigner_assign_and_telemetry_roundtrip():
    meta = _toy_meta(steps=120)
    asg = WindowAssigner(meta=meta, cfg=AssignConfig(
        window_size=8, window_budget=1e-3))
    rng = np.random.default_rng(4)
    emb = rng.normal(size=(8, D)).astype(np.float32)
    prices = np.cumsum(rng.random((8, 2)) * 1e-4, axis=1)
    res = asg.assign(emb, prices)
    assert res["assignment"].shape == (8,)
    assert res["budget"] == pytest.approx(1e-3)
    asg.observe(prices[np.arange(8), res["assignment"]], np.ones(8))
    snap = asg.snapshot()
    assert snap["n_windows"] == 1 and snap["n_assigned"] == 8
    assert snap["window_fill"] == pytest.approx(1.0)
    assert sum(snap["entry_hist"].values()) == 8
    assert snap["realized_accept_rate"] == pytest.approx(1.0)
    assert snap["solver_secs_per_window"] > 0


# ---------------------------------------------------------------------------
# strategy + pipeline + scheduler integration
# ---------------------------------------------------------------------------


def _feature_embed(tokens):
    return np.asarray(tokens[:, :D], np.float32)


def _feature_tokens(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, D)).astype(np.float32)


def _assign_pipeline(asg=None, governor=None, n_tiers=2, **pipe_kw):
    prices = [ApiCost(10.0 * 10 ** j, 10.0 * 10 ** j, 0.0)
              for j in range(n_tiers)]
    tiers = [TierSpec(f"t{j}", (lambda t, j=j: np.full(len(t), j, np.int32)),
                      prices[j]) for j in range(n_tiers)]
    strategy = None
    if asg is not None:
        strategy = ServingStrategy(mode="assign", assigner=asg,
                                   governor=governor)
    return ServingPipeline(
        tiers=tiers, thresholds=[0.5] * (n_tiers - 1),
        scorer=lambda t, a: np.where(t[:, 0] > 0, 0.9, 0.1),
        embed=_feature_embed, full_prompt_tokens=100, pad_token=-1,
        batch_size=8, strategy=strategy, **pipe_kw)


def test_strategy_mode_validation():
    meta = _toy_meta(steps=20)
    asg = WindowAssigner(meta=meta)
    with pytest.raises(ValueError, match="mode"):
        ServingStrategy(mode="windowed")
    with pytest.raises(ValueError, match="assigner"):
        ServingStrategy(mode="assign")
    s = ServingStrategy(mode="assign", assigner=asg)
    snap = s.snapshot(2)
    assert snap["mode"] == "assign" and snap["assign"] is not None


def test_strategy_assign_structurally_absent_when_off():
    """mode != "assign": no assign key content, no assigner, and the
    default-constructed strategy still behaves exactly as before."""
    gov = BudgetGovernor(1.0, (0.5,), window=8)
    s = ServingStrategy(governor=gov)
    assert s.mode == "entry" and s.assigner is None
    snap = s.snapshot(2)
    assert snap["mode"] == "entry" and snap["assign"] is None


def test_pipeline_serve_assign_mode_end_to_end():
    # toy economics: tier 0 ~1.1e-4/q, tier 1 ~1.1e-3/q; entering a HARD
    # row at 0 costs MORE in expectation (escalation pays both tiers)
    # than entering it at 1. 9.5e-4/q clears every window's least-cost
    # assignment (hard-heavy windows need ~8.6e-4) but binds below the
    # unconstrained utility argmax (~1.1e-3), so the budget both holds
    # and actually constrains
    meta = _toy_meta(steps=200)
    asg = WindowAssigner(meta=meta, cfg=AssignConfig(
        window_size=16, window_budget=16 * 9.5e-4))
    pipe = _assign_pipeline(asg)
    toks = _feature_tokens(48, seed=5)
    res = pipe.serve(toks)
    assert res.strategy is not None and res.strategy["mode"] == "assign"
    snap = res.strategy["assign"]
    assert snap["n_windows"] == 3              # 48 misses / 16
    assert snap["n_assigned"] == 48
    assert snap["realized_cost_per_q"] > 0     # realized $ folded back
    # entering a hard row at 0 is strictly dominated (costlier AND less
    # useful than entering at 1) — the solver must never do it
    hard = toks[:, 0] < -0.5
    assert (res.stopped_at[hard] == 1).all()
    # ... and the budget binds: not every row can afford tier 1, so a
    # chunk of the (cheap-to-serve) easy rows stays at tier 0
    assert snap["entry_hist"].get(0, 0) > 0
    # budget respected in expectation per window
    assert snap["n_infeasible"] == 0
    assert snap["predicted_cost_per_q"] <= 9.5e-4 * (1 + 1e-6)
    assert "assign" in res.latency


def test_pipeline_assign_requires_embed():
    meta = _toy_meta(steps=20)
    asg = WindowAssigner(meta=meta)
    donor = _assign_pipeline(asg)
    with pytest.raises(ValueError, match="embed"):
        ServingPipeline(
            tiers=donor.tiers, thresholds=donor.thresholds,
            scorer=donor.scorer, embed=None, strategy=donor.strategy)


def test_scheduler_assign_mode_windows_stream():
    from repro.serving.sched import SLOConfig
    meta = _toy_meta(steps=200)
    asg = WindowAssigner(meta=meta, cfg=AssignConfig(
        window_size=8, max_wait_s=0.02))
    pipe = _assign_pipeline(asg)
    toks = _feature_tokens(32, seed=6)
    res = pipe.serve_stream(toks, np.linspace(0, 0.05, 32),
                            max_chunk=8, slo=SLOConfig(deadline_s=30.0))
    assert (res.stopped_at >= 0).all()
    snap = res.strategy["assign"]
    assert snap["n_assigned"] == 32
    assert snap["n_windows"] >= 4              # never lumped into one
    assert snap["realized_cost_per_q"] > 0     # realized telemetry folded
    assert res.ingress["deadline_hit_rate"] == pytest.approx(1.0)


def test_scheduler_entry_mode_untouched_by_assign_plumbing():
    """A strategy-free stream run has no window buffer in its path and
    produces no assign telemetry."""
    pipe = _assign_pipeline(None)
    toks = _feature_tokens(16, seed=7)
    res = pipe.serve_stream(toks, max_chunk=8)
    assert res.strategy is None
    assert "assign" not in res.latency
    assert (res.stopped_at >= 0).all()
