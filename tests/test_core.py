"""FrugalGPT core: cascade invariants (hypothesis), router, simulation."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cascade import Cascade, evaluate_offline, run_online
from repro.core.cost import TABLE1, ApiCost
from repro.core.router import RouterConfig, learn_cascade, frontier
from repro.core.simulate import (DATASETS, MarketData, mpi_matrix,
                                 simulate_market, simulate_scores)


def _tiny_market(n=200, k=4, seed=0):
    rng = np.random.default_rng(seed)
    correct = (rng.uniform(size=(n, k)) < np.linspace(0.5, 0.9, k)).astype(
        np.float32)
    cost = np.exp(np.linspace(0.0, 3.0, k))[None, :] * np.ones((n, 1),
                                                               np.float32)
    return MarketData([f"api{i}" for i in range(k)], jnp.asarray(correct),
                      jnp.asarray(cost), jnp.ones(n, jnp.int32),
                      jnp.ones(n, jnp.int32), jnp.zeros(n))


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_table1_prices():
    assert len(TABLE1) == 12
    # 2 orders of magnitude spread (paper Table 1)
    in_costs = [a.per_10m_input for a in TABLE1.values()
                if a.per_10m_input > 0]
    assert max(in_costs) / min(in_costs) >= 100
    # the example from Table 1: 10M input tokens
    assert float(TABLE1["GPT-4"].query_cost(1e7, 0)) == pytest.approx(30.0)
    assert float(TABLE1["GPT-J"].query_cost(1e7, 0)) == pytest.approx(0.2)


@given(n_in=st.integers(0, 10_000), n_out=st.integers(0, 2_000))
def test_cost_model_linearity(n_in, n_out):
    api = ApiCost(10.0, 20.0, 0.001)
    c = float(api.query_cost(n_in, n_out))
    assert c == pytest.approx(1e-6 * n_in + 2e-6 * n_out + 0.001, rel=1e-5)


# ---------------------------------------------------------------------------
# cascade invariants (property-based)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(t1=st.floats(0, 1), t2=st.floats(0, 1),
       seed=st.integers(0, 10))
def test_cascade_cost_between_first_and_sum(t1, t2, seed):
    """Cascade cost >= first API cost and <= sum of all API costs."""
    data = _tiny_market(seed=seed)
    scores = simulate_scores(data, seed=seed)
    cas = Cascade((0, 1, 2), (t1, t2))
    m = evaluate_offline(cas, data, scores)
    lo = float(data.cost[:, 0].mean())
    hi = float(data.cost[:, [0, 1, 2]].sum(1).mean())
    assert lo - 1e-6 <= m["avg_cost"] <= hi + 1e-6


@settings(max_examples=30, deadline=None)
@given(t1=st.floats(0, 1), seed=st.integers(0, 10))
def test_cascade_thresholds_monotone_cost(t1, seed):
    """Raising a threshold can only push more queries downstream =>
    cost is non-decreasing in tau."""
    data = _tiny_market(seed=seed)
    scores = simulate_scores(data, seed=seed)
    lo = evaluate_offline(Cascade((0, 3), (t1 * 0.5,)), data, scores)
    hi = evaluate_offline(Cascade((0, 3), (min(1.0, t1 * 0.5 + 0.25),)),
                          data, scores)
    assert hi["avg_cost"] >= lo["avg_cost"] - 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 20))
def test_cascade_stop_fracs_sum_to_one(seed):
    data = _tiny_market(seed=seed)
    scores = simulate_scores(data, seed=seed)
    m = evaluate_offline(Cascade((1, 2, 3), (0.5, 0.5)), data, scores)
    assert sum(m["stop_fracs"]) == pytest.approx(1.0, abs=1e-5)


def test_cascade_threshold_zero_equals_first_api():
    data = _tiny_market()
    scores = simulate_scores(data)
    m = evaluate_offline(Cascade((2, 0), (0.0,)), data, scores)
    assert m["acc"] == pytest.approx(float(data.correct[:, 2].mean()))
    assert m["avg_cost"] == pytest.approx(float(data.cost[:, 2].mean()),
                                          rel=1e-5)


def test_online_matches_offline():
    """run_online with callable APIs reproduces the offline evaluation."""
    data = _tiny_market()
    scores = np.asarray(simulate_scores(data))
    correct = np.asarray(data.correct)
    cost = np.asarray(data.cost)
    n = data.n
    queries = list(range(n))

    def make_api(k):
        def api(qs):
            idx = np.array(qs)
            return correct[idx, k], cost[idx, k]
        return api

    apis = [make_api(k) for k in range(data.k)]

    def scorer(qs, ans, k):
        return scores[np.array(qs), k]

    cas = Cascade((0, 1, 3), (0.6, 0.4))
    res = run_online(cas, queries, apis, scorer)
    off = evaluate_offline(cas, data, jnp.asarray(scores))
    acc_online = float(np.mean([res["answers"][i] for i in range(n)]))
    assert acc_online == pytest.approx(off["acc"], abs=1e-6)
    assert res["cost"].mean() == pytest.approx(off["avg_cost"], rel=1e-5)


# ---------------------------------------------------------------------------
# router / optimizer
# ---------------------------------------------------------------------------


def test_learned_cascade_respects_budget_and_beats_cheapest():
    data = simulate_market("HEADLINES", n=1500, seed=3)
    scores = simulate_scores(data, seed=4)
    budget = float(data.cost.mean())  # mid-range budget
    cas, m = learn_cascade(data, scores, budget,
                           RouterConfig(top_lists=20, sample=256))
    assert m["avg_cost"] <= budget * 1.05
    accs = np.asarray(data.accuracy())
    cheapest = int(np.asarray(data.cost.mean(0)).argmin())
    assert m["acc"] >= accs[cheapest]


def test_tiny_budget_falls_back_to_cheapest():
    data = _tiny_market()
    scores = simulate_scores(data)
    cas, m = learn_cascade(data, scores, 1e-9)
    assert len(cas.apis) == 1


def test_frontier_is_monotone_in_budget():
    data = simulate_market("OVERRULING", n=1200, seed=5)
    scores = simulate_scores(data, seed=6)
    budgets = np.linspace(float(data.cost.min(1).mean()) * 1.2,
                          float(data.cost.max(1).mean()), 5)
    pts = frontier(data, scores, budgets, RouterConfig(top_lists=15,
                                                       sample=256))
    accs = [p["acc"] for p in pts]
    # allow small non-monotonic noise from the sampled threshold search
    assert accs[-1] >= accs[0] - 0.02


# ---------------------------------------------------------------------------
# simulation calibration
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ds", list(DATASETS))
def test_simulated_accuracies_match_targets(ds):
    data = simulate_market(ds, seed=11)
    target = DATASETS[ds]["acc"]
    for name, a in zip(data.names, np.asarray(data.accuracy())):
        assert abs(a - target[name]) < 0.03, (ds, name, a, target[name])


def test_mpi_matrix_properties():
    data = simulate_market("HEADLINES", n=4000, seed=12)
    mpi = np.asarray(mpi_matrix(data.correct))
    assert np.allclose(np.diag(mpi), 0.0)        # no self-improvement
    assert (mpi >= 0).all() and (mpi <= 1).all()
    # complementarity exists: someone fixes >=3% of GPT-4's errors
    g4 = data.names.index("GPT-4")
    assert mpi[g4].max() > 0.03
