"""Fast end-to-end neural path: tiny tier models -> offline collection ->
scorer -> router -> online serving. (The full-size version is
examples/cascade_serving.py.)"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import neural_market as NM
from repro.core import scorer as SC
from repro.core.distill import distill
from repro.core.router import RouterConfig, learn_cascade
from repro.core.cascade import evaluate_offline
from repro.data import synthetic
from repro.serving.engine import CascadeServer, Tier


@pytest.fixture(scope="module")
def tiny_market():
    tiers = {
        "GPT-J": dict(n_layers=1, d_model=32, steps=30, price="GPT-J"),
        "GPT-4": dict(n_layers=2, d_model=64, steps=120, price="GPT-4"),
    }
    old = NM.TIERS
    NM.TIERS = tiers
    try:
        apis = NM.train_marketplace("overruling", seq_len=32, seed=0)
    finally:
        NM.TIERS = old
    test = synthetic.sample("overruling", 300, seq_len=32, seed=42)
    data, answers = NM.collect_market_data(apis, test.tokens, test.labels)
    return apis, test, data, answers


def test_tiers_are_heterogeneous(tiny_market):
    _, _, data, _ = tiny_market
    accs = np.asarray(data.accuracy())
    assert accs[-1] > accs[0] - 0.05      # big tier >= small tier (roughly)
    assert accs[-1] > 0.6                 # big tier learned the task


def test_scorer_learns_correctness(tiny_market):
    apis, test, data, answers = tiny_market
    k = len(apis)
    sp = SC.train_scorer(np.repeat(test.tokens, k, axis=0),
                         answers.reshape(-1),
                         np.asarray(data.correct).reshape(-1), steps=120)
    s = np.stack([SC.score(sp, test.tokens, answers[:, j])
                  for j in range(k)], axis=1)
    auc = SC.auc(s.reshape(-1), np.asarray(data.correct).reshape(-1))
    assert auc > 0.6, auc


def test_cascade_learned_and_served_online(tiny_market):
    apis, test, data, answers = tiny_market
    scores = jnp.asarray(
        0.7 * np.asarray(data.correct) +
        0.3 * np.random.default_rng(0).uniform(size=data.correct.shape))
    budget = float(data.cost[:, -1].mean()) * 0.5
    cas, m = learn_cascade(data, scores, budget,
                           RouterConfig(m=2, top_lists=4, sample=128))
    assert m["avg_cost"] <= budget * 1.05
    off = evaluate_offline(cas, data, scores)
    assert off["acc"] >= float(np.asarray(data.accuracy())[0]) - 0.05

    snp = np.asarray(scores)
    idx_of = {a: i for i, a in enumerate(cas.apis)}
    tok_row = {t: i for i, t in enumerate(map(tuple, test.tokens.tolist()))}

    def scorer_fn(toks, ans):
        rows = np.array([tok_row[tuple(t)] for t in toks.tolist()])
        return snp[rows, cas.apis[0]]

    tiers = [Tier(apis[i].name, apis[i].answer, apis[i].query_cost)
             for i in cas.apis]
    srv = CascadeServer(tiers, cas.thresholds, scorer_fn)
    res = srv.serve(test.tokens)
    assert res["cost"].mean() > 0
    assert len(res["answers"]) == test.tokens.shape[0]


def test_distillation_produces_cheaper_api(tiny_market):
    apis, test, _, _ = tiny_market
    teacher = apis[-1]
    student = distill(teacher, "overruling", n_unlabeled=256, seq_len=32,
                      steps=60, student_layers=1, student_d=32)
    s_cost = student.query_cost(test.tokens).mean()
    t_cost = teacher.query_cost(test.tokens).mean()
    assert s_cost < t_cost
    s_acc = (student.answer(test.tokens) == test.labels).mean()
    assert s_acc > 0.4                    # learned something from teacher
