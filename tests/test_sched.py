"""SLO-aware parallel tier scheduler (repro.serving.sched): policy and
estimator units, equivalence with the batch path, concurrent tier
decoding, adaptive deadline-driven holdback, bounded-queue backpressure
(reject/degrade), and the stream edge cases the scheduler must preserve
(drain ordering, arrival-at-close, duplicate queries racing in-flight
twins)."""
import asyncio
import threading
import time

import numpy as np
import pytest

from repro.core.approx import CompletionCache
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.serving.ingress import IngressQueue
from repro.serving.pipeline import ServingPipeline, TierSpec
from repro.serving.sched import (SLOConfig, TierEstimator, TierScheduler,
                                 admit_decision, holdback_timeout)
from repro.serving.sched.estimator import Ewma


def _toy_pipeline(with_cache=True, batch_size=8, tier_sleep=0.0):
    """Same 2-tier toy marketplace as tests/test_ingress.py: even
    leading token accepts at tier 0, odd escalates."""

    def mk_answer(v):
        def answer(t):
            if tier_sleep:
                time.sleep(tier_sleep)
            return np.full(len(t), v, np.int32)
        return answer

    cheap = TierSpec("cheap", mk_answer(0), ApiCost(10.0, 10.0, 0.0),
                     prompt=PromptSpec((0,), 100, 40))
    pricey = TierSpec("pricey", mk_answer(1), ApiCost(100.0, 100.0, 0.0),
                      prompt=PromptSpec((0, 1), 100, 40))

    def scorer(t, ans):
        return np.where(t[:, 0] % 2 == 0, 0.9, 0.1)

    def embed(tokens):
        e = np.zeros((len(tokens), 64), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 64] = 1.0
        return e

    cache = CompletionCache(capacity=64, threshold=0.99) if with_cache \
        else None
    return ServingPipeline(
        tiers=[cheap, pricey], thresholds=[0.5], scorer=scorer,
        cache=cache, embed=embed if with_cache else None,
        full_prompt_tokens=840, pad_token=-1, batch_size=batch_size)


def _tokens(n):
    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)          # distinct, half even / half odd
    return toks


# ---------------------------------------------------------------------------
# policy + estimator units (no threads)
# ---------------------------------------------------------------------------


def test_ewma_seeds_and_tracks():
    e = Ewma(alpha=0.5)
    assert e.value == 0.0 and e.n == 0
    assert e.update(4.0) == 4.0                  # first sample seeds
    assert e.update(0.0) == pytest.approx(2.0)
    assert e.n == 2
    with pytest.raises(ValueError, match="alpha"):
        Ewma(alpha=0.0)


def test_tier_estimator_counters():
    est = TierEstimator()
    assert est.predicted_service(default=0.5) == 0.5     # cold default
    est.observe_chunk(0.1, rows=4)
    est.observe_chunk(0.1, rows=2)
    assert est.predicted_service() == pytest.approx(0.1)
    assert est.chunks == 2 and est.rows == 6
    assert est.utilization(1.0) == pytest.approx(0.2)
    assert est.utilization(0.0) == 0.0
    snap = est.snapshot()
    assert snap["busy_s"] == pytest.approx(0.2)


def test_slo_config_validation():
    with pytest.raises(ValueError, match="overload"):
        SLOConfig(overload="panic")
    with pytest.raises(ValueError, match="queue_cap"):
        SLOConfig(queue_cap=0)
    with pytest.raises(ValueError, match="deadline_s"):
        SLOConfig(deadline_s=-1.0)
    with pytest.raises(ValueError, match="max_holdback_s"):
        SLOConfig(max_holdback_s=-0.1)
    with pytest.raises(ValueError, match="queue_cap"):
        SLOConfig(overload="degrade")       # inert without a bound
    slo = SLOConfig(deadline_s=0.5)
    assert slo.deadline_for(1.0) == pytest.approx(1.5)
    assert slo.deadline_for(1.0, explicit=1.2) == pytest.approx(1.2)
    assert SLOConfig().deadline_for(1.0) is None


def test_holdback_timeout_deadline_pressure():
    """Without a deadline the fixed cap rules; with one, the predicted
    completion (EWMA service x safety) pulls the dispatch earlier."""
    from repro.serving.ingress import RequestState

    est = TierEstimator()
    slo = SLOConfig(max_holdback_s=10.0, service_safety=1.0)
    r = RequestState(rid=0, tokens=np.zeros(4), arrival=0.0)
    r.t_enqueued = 0.0
    assert holdback_timeout(r, est, now=1.0, slo=slo) == pytest.approx(9.0)
    r.deadline = 2.0
    est.observe_chunk(0.5, rows=1)           # EWMA service = 0.5s
    # may hold until deadline - service = 1.5; at now=1.0 that's 0.5s
    assert holdback_timeout(r, est, now=1.0, slo=slo) == pytest.approx(0.5)
    # past the pressure point: ship now
    assert holdback_timeout(r, est, now=1.6, slo=slo) < 0


def test_admit_decision_ladder():
    assert admit_decision(5, SLOConfig()) == "admit"          # unbounded
    slo = SLOConfig(queue_cap=4, overload="reject")
    assert admit_decision(3, slo) == "admit"
    assert admit_decision(4, slo) == "shed"
    slo = SLOConfig(queue_cap=4, overload="degrade")
    assert admit_decision(4, slo) == "degrade"
    assert admit_decision(7, slo) == "degrade"
    assert admit_decision(8, slo) == "shed"                   # hard 2x cap


# ---------------------------------------------------------------------------
# equivalence with ServingPipeline.serve (the acceptance guarantee)
# ---------------------------------------------------------------------------


def test_scheduler_bit_identical_to_serve():
    toks = _tokens(32)
    a = _toy_pipeline().serve(toks)
    b = TierScheduler(_toy_pipeline(), max_chunk=8).run_trace(toks)
    assert np.array_equal(a.answers, b.answers)
    assert a.answers.dtype == b.answers.dtype
    assert (a.cost == b.cost).all()            # bit-identical float64
    assert np.array_equal(a.stopped_at, b.stopped_at)
    assert a.tier_counts == b.tier_counts
    assert (a.cache_hits, a.cache_misses) == (b.cache_hits, b.cache_misses)
    assert a.prompt_tokens_saved == b.prompt_tokens_saved
    assert a.baseline_cost == b.baseline_cost


def test_scheduler_equivalent_with_slow_tiers_and_arrivals():
    """Concurrency and arrival timing must not leak into results."""
    toks = _tokens(24)
    a = _toy_pipeline(with_cache=False).serve(toks)
    sched = TierScheduler(_toy_pipeline(with_cache=False, tier_sleep=0.002),
                          max_chunk=4)
    b = sched.run_trace(toks, np.linspace(0.0, 0.03, 24))
    assert np.array_equal(a.answers, b.answers)
    assert (a.cost == b.cost).all()
    assert np.array_equal(a.stopped_at, b.stopped_at)
    # both tiers really ran work concurrently tracked per tier
    assert b.ingress["chunks_per_tier"][0] >= 1
    assert b.ingress["tier_utilization"][0] > 0


def test_scheduler_telemetry_shape():
    res = TierScheduler(_toy_pipeline(), max_chunk=4).run_trace(_tokens(12))
    ing = res.ingress
    assert len(ing["request_latency"]) == 12
    assert (ing["request_latency"] >= 0).all()
    assert ing["n_chunks"] == sum(ing["chunks_per_tier"])
    assert len(ing["tier_utilization"]) == 2
    assert len(ing["service_ewma_s"]) == 2
    assert ing["deadline_hit_rate"] is None        # no SLO configured
    assert ing["shed"] == 0 and ing["degraded"] == 0
    assert set(res.latency) == {"embed", "cache", "cascade", "insert",
                                "total"}
    # utilization is busy/wall per tier, so each entry is a fraction
    assert all(0 <= u <= 1.0 + 1e-9 for u in ing["tier_utilization"])


def test_serve_stream_rejects_holdback_plus_slo():
    """An SLOConfig carries its own max_holdback_s; a separately-passed
    window must fail loudly instead of being silently dropped."""
    pipe = _toy_pipeline(with_cache=False)
    with pytest.raises(ValueError, match="not both"):
        pipe.serve_stream(_tokens(4), holdback=0.1, slo=SLOConfig())


def test_scheduler_rejects_reuse_and_bad_chunk():
    with pytest.raises(ValueError, match="max_chunk"):
        TierScheduler(_toy_pipeline(), max_chunk=0)
    s = TierScheduler(_toy_pipeline(), max_chunk=4)
    s.run_trace(_tokens(4))
    with pytest.raises(RuntimeError, match="fresh"):
        s.run_trace(_tokens(4))


def test_scheduler_propagates_worker_errors():
    """A tier blowing up surfaces as the original exception, not a hang
    or a half-folded result."""
    def boom(t):
        raise RuntimeError("tier exploded")

    pipe = ServingPipeline(
        tiers=[TierSpec("bad", boom, ApiCost(1.0, 1.0, 0.0))],
        thresholds=[], scorer=None, full_prompt_tokens=10, pad_token=-1)
    with pytest.raises(RuntimeError, match="tier exploded"):
        TierScheduler(pipe, max_chunk=4).run_trace(_tokens(4))


# ---------------------------------------------------------------------------
# concurrent tier decoding
# ---------------------------------------------------------------------------


def test_tiers_decode_concurrently():
    """With sleepy tiers, overlapping chunk windows prove one worker per
    tier (the serial batcher can never overlap them)."""
    windows = {0: [], 1: []}
    lock = threading.Lock()

    def mk_answer(v, sleep):
        def answer(t):
            t0 = time.perf_counter()
            time.sleep(sleep)
            with lock:
                windows[v].append((t0, time.perf_counter()))
            return np.full(len(t), v, np.int32)
        return answer

    pipe = ServingPipeline(
        tiers=[TierSpec("cheap", mk_answer(0, 0.03), ApiCost(10., 10., 0.)),
               TierSpec("pricey", mk_answer(1, 0.03),
                        ApiCost(100., 100., 0.))],
        thresholds=[0.5],
        scorer=lambda t, a: np.where(t[:, 0] % 2 == 0, 0.9, 0.1),
        full_prompt_tokens=840, pad_token=-1, batch_size=4)
    # small chunks + zero holdback => tier 0 starts chunk k+1 while
    # tier 1 decodes the escalations of chunk k
    res = TierScheduler(pipe, max_chunk=4,
                        slo=SLOConfig(max_holdback_s=0.0)).run_trace(
        _tokens(24))
    assert res.n == 24 and (res.stopped_at >= 0).all()
    overlaps = sum(1 for a0, a1 in windows[0] for b0, b1 in windows[1]
                   if a0 < b1 and b0 < a1)
    assert overlaps > 0, "tier workers never overlapped"


# ---------------------------------------------------------------------------
# adaptive (deadline-driven) holdback
# ---------------------------------------------------------------------------


def test_deadline_ships_partial_chunks_early():
    """A partial chunk that the fixed window would hold for 10s ships
    the moment the head-of-line request's predicted completion would
    miss its deadline.

    Time is an injected fake clock the test advances by hand: while it
    reads 0.0 the 4-row partial MUST hold (pressure point ~24ms away,
    queue still open so drain can't ship it), and the moment it jumps
    past the deadline the partial MUST ship — deterministic on any
    host, where the old wall-clock arrival trickle could coalesce into
    one chunk if the process stalled longer than the deadline."""
    toks = _tokens(8)

    async def go():
        t = {"now": 0.0}
        pipe = _toy_pipeline(with_cache=False)
        # huge holdback: only deadline pressure can ship a partial
        sched = TierScheduler(pipe, max_chunk=8, slo=SLOConfig(
            max_holdback_s=10.0, deadline_s=0.03, init_service_s=0.005))
        queue = IngressQueue()
        task = asyncio.ensure_future(
            sched.serve_async(queue, clock=lambda: t["now"]))
        first = queue.submit_burst(toks[:4], with_future=True)
        await asyncio.sleep(0.1)             # let the workers look
        with sched._cv:                      # frozen at 0.0: held back
            assert sched.chunks_per_tier[0] == 0
        t["now"] = 0.05                      # past the pressure point:
        await asyncio.wait_for(              # the partial ships now
            asyncio.gather(*(r.future for r in first)), timeout=10.0)
        queue.submit_burst(toks[4:])
        queue.close()
        return await asyncio.wait_for(task, timeout=10.0)

    res = asyncio.run(go())
    assert res.ingress["chunks_per_tier"][0] == 2    # did NOT coalesce
    assert res.ingress["deadline_total"] == 8
    # answers still exactly the batch path's
    a = _toy_pipeline(with_cache=False).serve(toks)
    assert np.array_equal(a.answers, res.answers)
    assert (a.cost == res.cost).all()


def test_deadline_hit_rate_accounting():
    """Loose deadlines: everything hits, and the telemetry says so.
    Runs on an injected FROZEN clock — every request finishes at t=0
    against a 30s deadline by construction, so the accounting is exact
    even on an arbitrarily loaded CI host (on a wall clock a long
    enough stall could make this flake)."""
    async def go():
        sched = TierScheduler(_toy_pipeline(with_cache=False), max_chunk=8,
                              slo=SLOConfig(deadline_s=30.0))
        queue = IngressQueue()
        queue.submit_burst(_tokens(16))
        queue.close()
        return await asyncio.wait_for(
            sched.serve_async(queue, clock=lambda: 0.0), timeout=30.0)

    res = asyncio.run(go())
    assert res.ingress["deadline_total"] == 16
    assert res.ingress["deadline_hit_rate"] == 1.0


def test_per_request_deadline_wins_over_default():
    async def go():
        pipe = _toy_pipeline(with_cache=False)
        sched = TierScheduler(pipe, max_chunk=4,
                              slo=SLOConfig(deadline_s=5.0))
        queue = IngressQueue()
        toks = _tokens(2)
        queue.submit(toks[0], arrival=0.0)                  # default SLO
        queue.submit(toks[1], arrival=0.0, deadline=9.0)    # explicit
        queue.close()
        await sched.serve_async(queue)
        by_rid = sorted(sched._requests, key=lambda r: r.rid)
        assert by_rid[0].deadline == pytest.approx(5.0)
        assert by_rid[1].deadline == pytest.approx(9.0)
    asyncio.run(go())


# ---------------------------------------------------------------------------
# bounded queues, backpressure, overload policies
# ---------------------------------------------------------------------------


def test_overload_reject_sheds_and_accounts():
    """A burst far beyond a tiny queue cap sheds the excess: bounded
    queues, every request accounted, telemetry consistent."""
    pipe = _toy_pipeline(with_cache=False, tier_sleep=0.01, batch_size=4)
    slo = SLOConfig(queue_cap=4, overload="reject", max_holdback_s=0.0)
    res = TierScheduler(pipe, max_chunk=4, slo=slo).run_trace(_tokens(32))
    shed = res.stopped_at == -2
    assert res.ingress["shed"] == int(shed.sum()) > 0
    assert res.n == 32                                  # all accounted
    assert all(res.answers[i] is None for i in np.flatnonzero(shed))
    assert (res.cost[shed] == 0).all()
    served = ~shed
    assert (res.stopped_at[served] >= 0).all()
    assert res.ingress["queue_peak"][0] <= 4
    # shed requests are excluded from the latency telemetry
    assert len(res.ingress["request_latency"]) == int(served.sum())


def test_overload_degrade_answers_from_cheapest_tier():
    """Degraded requests take tier 0's answer even where the scorer
    would escalate them — and never reach tier 1."""
    pipe = _toy_pipeline(with_cache=False, tier_sleep=0.01, batch_size=4)
    slo = SLOConfig(queue_cap=4, overload="degrade", max_holdback_s=0.0)
    sched = TierScheduler(pipe, max_chunk=4, slo=slo)
    res = sched.run_trace(_tokens(32))
    assert res.ingress["degraded"] > 0
    degraded = [r for r in sched._requests if r.degraded]
    assert all(r.stopped_at == 0 for r in degraded)
    odd_degraded = [r for r in degraded if r.tokens[0] % 2 == 1]
    assert odd_degraded, "burst should degrade some odd (hard) queries"
    assert all(r.answer == 0 for r in odd_degraded)     # cheap answer
    # hard 2x bound holds even while the worker escalates under load
    assert res.ingress["queue_peak"][0] <= 2 * 4


def test_degraded_answers_never_poison_the_cache():
    """A forced (scorer-rejected) degraded answer must not be cached:
    once the overload passes, a near-duplicate query goes back through
    the tiers and gets the real answer — not the degraded one."""
    pipe = _toy_pipeline(with_cache=True, tier_sleep=0.01, batch_size=4)
    slo = SLOConfig(queue_cap=4, overload="degrade", max_holdback_s=0.0)
    sched = TierScheduler(pipe, max_chunk=4, slo=slo)
    toks = _tokens(32)
    res = sched.run_trace(toks)
    forced = [r for r in sched._requests
              if r.degraded and r.tokens[0] % 2 == 1 and not r.shed]
    assert forced, "burst should force-degrade some odd queries"
    # calm re-serve of those queries: they MISS the cache (never
    # inserted) and escalate to the pricey tier's real answer
    res2 = TierScheduler(pipe, max_chunk=4).run_trace(
        np.stack([r.tokens for r in forced]))
    assert (res2.answers == 1).all()          # pricey tier's real answer
    assert (res2.stopped_at == 1).all()       # not a cache hit


def test_escalation_blocks_on_bounded_downstream_queue():
    """With everything escalating into a slow bounded tier 1, the tier-0
    worker must wait for space instead of dumping its chunks downstream:
    the tier-1 queue stays within the cap, the stream still completes
    (forward-only blocking cannot deadlock), and every request is
    accounted — served through tier 1 or shed at admission once the
    backpressure reaches tier 0."""
    def slow_pricey(t):
        time.sleep(0.02)
        return np.full(len(t), 1, np.int32)

    pipe = ServingPipeline(
        tiers=[TierSpec("cheap", lambda t: np.zeros(len(t), np.int32),
                        ApiCost(10.0, 10.0, 0.0)),
               TierSpec("pricey", slow_pricey, ApiCost(100.0, 100.0, 0.0))],
        thresholds=[0.5],
        scorer=lambda t, a: np.zeros(len(t)),        # escalate EVERYTHING
        full_prompt_tokens=840, pad_token=-1, batch_size=8)
    slo = SLOConfig(queue_cap=3, max_holdback_s=0.0)
    res = TierScheduler(pipe, max_chunk=8, slo=slo).run_trace(
        _tokens(16), np.linspace(0.0, 0.08, 16))
    shed = res.stopped_at == -2
    assert (res.stopped_at[~shed] == 1).all()        # served == via tier 1
    assert int((~shed).sum()) > 0
    assert res.ingress["queue_peak"][1] <= 3         # bounded downstream
    assert res.ingress["queue_peak"][0] <= 3         # and at admission
    assert res.ingress["shed"] == int(shed.sum())    # all accounted


# ---------------------------------------------------------------------------
# stream edge cases the scheduler must preserve
# ---------------------------------------------------------------------------


def test_drain_mode_dispatch_ordering():
    """A closed queue drains FIFO per tier: the trailing partial chunk
    ships immediately (no holdback stall) and rids stay in order.

    Runs on an injected FROZEN clock: the 10s holdback window can never
    expire on it, so *finishing at all* proves drain dispatch ignores
    the window — no wall-clock `elapsed < N` threshold left to flake on
    a loaded CI host (a regression hangs and trips the wait_for bound
    instead)."""
    async def go(sched):
        queue = IngressQueue()
        queue.submit_burst(_tokens(10))      # 4 + 4 + 2 at tier 0
        queue.close()
        return await asyncio.wait_for(
            sched.serve_async(queue, clock=lambda: 0.0), timeout=30.0)

    sched = TierScheduler(_toy_pipeline(with_cache=False), max_chunk=4,
                          slo=SLOConfig(max_holdback_s=10.0))
    res = asyncio.run(go(sched))
    assert res.ingress["chunks_per_tier"][0] == 3
    # FIFO within the tier: each request's first chunk index is ordered
    by_rid = sorted(sched._requests, key=lambda r: r.rid)
    assert [r.rid for r in by_rid] == list(range(10))
    a = _toy_pipeline(with_cache=False).serve(_tokens(10))
    assert np.array_equal(a.answers, res.answers)
    assert (a.cost == res.cost).all()


def test_request_arriving_exactly_at_close():
    """close() immediately after a submit must not lose the request —
    including one whose arrival offset is still in the future."""
    async def go():
        pipe = _toy_pipeline(with_cache=False)
        sched = TierScheduler(pipe, max_chunk=4)
        queue = IngressQueue()
        toks = _tokens(3)
        queue.submit_burst(toks[:2])
        late = queue.submit(toks[2], arrival=0.05)   # due after close
        queue.close()                                # closes NOW
        res = await sched.serve_async(queue)
        assert res.n == 3 and (res.stopped_at >= 0).all()
        assert late.done and late.answer is not None
        return res
    res = asyncio.run(go())
    a = _toy_pipeline(with_cache=False).serve(_tokens(3))
    assert np.array_equal(a.answers, res.answers)


def test_duplicate_queries_race_inflight_twins():
    """Duplicates admitted together both miss (the twin is in flight,
    not cached) yet get identical answers; a duplicate arriving after
    its twin finished hits the cache instead."""
    pipe = _toy_pipeline()
    sched = TierScheduler(pipe, max_chunk=8)
    base = _tokens(4)
    toks = np.concatenate([base, base])              # 4 in-flight twins
    res = sched.run_trace(toks)                      # all at t=0
    assert res.cache_hits == 0 and res.cache_misses == 8
    assert (res.answers[:4] == res.answers[4:]).all()
    assert (res.cost[:4] == res.cost[4:]).all()
    # second stream: twins completed => pure cache traffic, no tier work
    sched2 = TierScheduler(pipe, max_chunk=8)
    res2 = sched2.run_trace(base)
    assert res2.cache_hits == 4
    assert (res2.stopped_at == -1).all()
    assert res2.cost.sum() == 0.0


def test_futures_resolve_while_stream_open():
    """Per-request futures resolve as answers land, before close()."""
    async def go():
        pipe = _toy_pipeline(with_cache=False)
        sched = TierScheduler(pipe, max_chunk=4,
                              slo=SLOConfig(max_holdback_s=0.0))
        queue = IngressQueue()
        toks = _tokens(8)
        task = asyncio.ensure_future(sched.serve_async(queue))
        first = queue.submit_burst(toks[:4], with_future=True)
        r0 = await asyncio.wait_for(first[0].future, timeout=10.0)
        assert r0.answer == 0 and r0.stopped_at == 0
        second = queue.submit_burst(toks[4:], with_future=True)
        queue.close()
        res = await asyncio.wait_for(task, timeout=10.0)
        assert all(r.future.done() for r in first + second)
        assert res.n == 8
        return res
    res = asyncio.run(go())
    assert (res.answers[::2] == 0).all() and (res.answers[1::2] == 1).all()
