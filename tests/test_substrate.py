"""Training substrate: optimizer, checkpointing, data, scorer, approx,
prompt adaptation, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import approx, prompt
from repro.core.cost import ApiCost
from repro.data import synthetic
from repro.models.classifier import encoder_config, init_classifier
from repro.training import checkpoint
from repro.training.optim import (OptConfig, adamw_update, global_norm,
                                  init_opt_state, schedule)
from repro.training.train_loop import eval_classifier, train_classifier


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    opt = OptConfig(lr=0.1, warmup=1, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(opt, params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clipping():
    opt = OptConfig(lr=1e-3, clip_norm=1.0, warmup=1, total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw_update(opt, params, g, state)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_decay():
    opt = OptConfig(lr=1.0, warmup=10, total_steps=100)
    assert float(schedule(opt, 0)) < 0.2
    assert float(schedule(opt, 10)) == pytest.approx(1.0, rel=0.1)
    assert float(schedule(opt, 99)) <= 0.2


@given(scale=st.floats(0.1, 10.0))
@settings(max_examples=10, deadline=None)
def test_global_norm_homogeneous(scale):
    t = {"a": jnp.ones(4), "b": jnp.ones((2, 2))}
    n1 = float(global_norm(t))
    n2 = float(global_norm(jax.tree.map(lambda x: x * scale, t)))
    assert n2 == pytest.approx(scale * n1, rel=1e-4)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones(4)}, "lst": [jnp.zeros(2), jnp.ones(1)]}
    path = os.path.join(tmp_path, "ckpt")
    checkpoint.save(path, tree, meta={"step": 7})
    loaded = checkpoint.load(path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        assert jnp.allclose(x, y)


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("task", ["headlines", "overruling", "qa"])
def test_synthetic_tasks_learnable(task):
    """A small classifier beats chance comfortably on each task."""
    cfg = encoder_config("t", n_layers=2, d_model=64, n_heads=2, d_ff=128,
                         max_seq=68)
    n_classes = synthetic.N_CLASSES[task]
    params, hist = train_classifier(cfg, n_classes, task=task, steps=150,
                                    seed=1)
    test = synthetic.sample(task, 400, seed=999)
    acc, _ = eval_classifier(params, cfg, test.tokens, test.labels)
    # beats chance: wide-label tasks (qa, 64-way) need only a multiple of
    # chance at this tiny train budget; few-class tasks a margin
    bar = 2.0 / n_classes if n_classes > 8 else 1.0 / n_classes + 0.1
    assert acc > bar, (task, acc)


def test_synthetic_difficulty_is_harder():
    b = synthetic.sample("headlines", 2000, seed=3)
    assert b.tokens.shape == (2000, 64)
    assert set(np.unique(b.labels)) <= {0, 1, 2, 3}


def test_append_answer_shape():
    b = synthetic.sample("overruling", 10, seed=0)
    pairs = synthetic.append_answer(b.tokens, b.labels)
    assert pairs.shape == (10, 66)


# ---------------------------------------------------------------------------
# completion cache
# ---------------------------------------------------------------------------


def test_completion_cache_hit_and_miss():
    cache = approx.CompletionCache(capacity=16, threshold=0.95)
    emb = np.eye(4, 8, dtype=np.float32)
    cache.insert(emb[:2], np.array([5, 6]))
    hit, ans = cache.lookup(emb)
    assert hit[:2].all() and not hit[2:].any()
    assert ans[0] == 5 and ans[1] == 6


def test_serve_with_cache_saves_cost():
    cache = approx.CompletionCache(capacity=64, threshold=0.99)
    rng = np.random.default_rng(0)
    base = rng.normal(size=(8, 16)).astype(np.float32)
    base /= np.linalg.norm(base, axis=1, keepdims=True)
    emb = np.tile(base, (4, 1))                 # repeated queries over time
    toks = np.tile(np.arange(8)[:, None], (4, 4)).astype(np.int32)
    calls = {"n": 0}

    def api_answer(t):
        calls["n"] += len(t)
        return t[:, 0]

    def api_cost(t):
        return np.ones(len(t))

    total = 0.0
    answers = []
    for i in range(0, 32, 8):                   # four arrival waves
        ans, cost, hit = approx.serve_with_cache(
            cache, emb[i:i + 8], toks[i:i + 8], api_answer, api_cost)
        total += cost.sum()
        answers.append(ans)
    assert calls["n"] == 8                      # only the first wave hits API
    assert total == pytest.approx(8.0)
    assert (np.concatenate(answers) == toks[:, 0]).all()
    assert cache.hit_rate == pytest.approx(24 / 32)


# ---------------------------------------------------------------------------
# prompt adaptation
# ---------------------------------------------------------------------------


def test_concat_cost_amortizes_prompt():
    api = ApiCost(10.0, 10.0, 0.0)
    c1 = prompt.concat_cost(api, 1000, 50, 10, 1)
    c8 = prompt.concat_cost(api, 1000, 50, 10, 8)
    assert c8 < c1
    # prompt share fully amortized: per-query floor = query+gen cost
    floor = float(api.query_cost(50, 10))
    assert c8 >= floor
    sav = prompt.concat_savings(api, 1000, 50, 10)
    assert sav[16] > sav[2] > sav[1] == 0.0


def test_greedy_prompt_selection():
    # accuracy rises with examples but saturates; greedy should stop early
    def evaluate(ids):
        return min(0.9, 0.5 + 0.15 * len(ids))

    spec, hist = prompt.select_prompt(list(range(8)), evaluate,
                                      tokens_per_example=30, base_tokens=100,
                                      min_gain=0.05)
    assert len(spec.example_ids) == 3           # 0.95 gain stops at 0.9 cap
    assert spec.n_tokens == 100 + 3 * 30
