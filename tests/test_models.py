"""Per-arch smoke tests (reduced variants) + decode-consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(cfg, key=KEY, seq=S):
    b = {}
    if cfg.embed_inputs:
        b["tokens"] = jax.random.randint(key, (B, seq), 0, cfg.vocab)
        b["labels"] = jax.random.randint(key, (B, seq), 0, cfg.vocab)
        if cfg.vision_tokens:
            b["vision_embeds"] = jax.random.normal(
                key, (B, cfg.vision_tokens, cfg.d_model))
    else:
        b["embeds"] = jax.random.normal(key, (B, seq, cfg.d_model))
        b["labels"] = jax.random.randint(key, (B, seq), 0, cfg.vocab)
    return b


@pytest.mark.parametrize("name", list(ARCHS))
def test_smoke_train_step(name):
    """Reduced variant: one forward/train step, output shapes + no NaNs."""
    cfg = ARCHS[name].reduced()
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: T.forward_train(p, b, cfg))(
        params, batch)
    assert jnp.isfinite(loss), (name, metrics)
    assert loss.shape == ()
    # grads flow
    g = jax.grad(lambda p: T.forward_train(p, batch, cfg)[0])(params)
    gn = sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(g))
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", list(ARCHS))
def test_smoke_prefill_shapes(name):
    cfg = ARCHS[name].reduced()
    params = T.init_params(KEY, cfg)
    logits, cache = jax.jit(lambda p, b: T.prefill(p, b, cfg))(
        params, _batch(cfg))
    if cfg.causal:
        assert logits.shape == (B, 1, cfg.vocab)
        assert cache is not None
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if ARCHS[n].causal])
def test_decode_matches_prefill(name):
    """Incremental decode == full-sequence forward (capacity drops
    disabled via a large MoE capacity factor)."""
    cfg = ARCHS[name].reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_params(KEY, cfg)
    seq = S + 1
    toks = jax.random.randint(KEY, (B, seq), 0, cfg.vocab)
    bf = {"tokens": toks}
    bp = {"tokens": toks[:, :-1]}
    if cfg.vision_tokens:
        v = jax.random.normal(KEY, (B, cfg.vision_tokens, cfg.d_model))
        bf["vision_embeds"] = v
        bp["vision_embeds"] = v
    lg_full, _ = T.prefill(params, bf, cfg)
    _, cache = T.prefill(params, bp, cfg, max_len=seq)
    lg_inc, _ = T.decode_step(params, cache, toks[:, -1:], jnp.int32(seq - 1),
                              cfg)
    err = float(jnp.abs(lg_full - lg_inc).max()
                / (jnp.abs(lg_full).max() + 1e-9))
    assert err < 2e-3, f"{name}: rel err {err}"


def test_multistep_decode_ring_buffer_wraparound():
    """Sliding-window arch: decode far past the window; every step must
    match a fresh prefill of the same prefix."""
    cfg = ARCHS["gemma3-1b"].reduced()          # window 64
    params = T.init_params(KEY, cfg)
    total = cfg.window + 24
    toks = jax.random.randint(KEY, (B, total), 0, cfg.vocab)
    prefix = 16
    _, cache = T.prefill(params, {"tokens": toks[:, :prefix]}, cfg,
                         max_len=total)
    for t in range(prefix, total):
        lg_inc, cache = T.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t), cfg)
    lg_full, _ = T.prefill(params, {"tokens": toks}, cfg)
    err = float(jnp.abs(lg_full - lg_inc).max()
                / (jnp.abs(lg_full).max() + 1e-9))
    assert err < 2e-3, err


def test_mamba_multistep_decode():
    cfg = ARCHS["mamba2-1.3b"].reduced()
    params = T.init_params(KEY, cfg)
    total = 48
    toks = jax.random.randint(KEY, (B, total), 0, cfg.vocab)
    _, cache = T.prefill(params, {"tokens": toks[:, :8]}, cfg, max_len=total)
    for t in range(8, total):
        lg_inc, cache = T.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t), cfg)
    lg_full, _ = T.prefill(params, {"tokens": toks}, cfg)
    err = float(jnp.abs(lg_full - lg_inc).max()
                / (jnp.abs(lg_full).max() + 1e-9))
    assert err < 2e-3, err


def test_encoder_only_has_no_decode():
    cfg = ARCHS["hubert-xlarge"].reduced()
    assert not cfg.decode_supported


def test_mtp_loss_present_for_deepseek():
    cfg = ARCHS["deepseek-v3-671b"].reduced()
    cfg = dataclasses.replace(cfg, mtp=True)
    params = T.init_params(KEY, cfg)
    loss, metrics = T.forward_train(params, _batch(cfg), cfg)
    assert "mtp" in metrics and jnp.isfinite(metrics["mtp"])
