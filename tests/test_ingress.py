"""Continuous-batching ingress: equivalence with the batch path
(bit-identical answers/costs under greedy decoding), the shared
``tier_step`` compaction step, admission-during-decode, per-request
futures, and stream telemetry."""
import asyncio
import time

import numpy as np
import pytest

from repro.core.approx import CompletionCache
from repro.core.cascade import CascadeTier, execute_cascade, tier_step
from repro.core.cost import ApiCost
from repro.core.prompt import PromptSpec
from repro.serving.ingress import (ContinuousBatcher, IngressQueue,
                                   RequestState)
from repro.serving.pipeline import ServingPipeline, TierSpec


def _toy_pipeline(with_cache=True, batch_size=8, tier_sleep=0.0):
    """2-tier toy marketplace with row-wise tiers/scorer/embeds: even
    leading token accepts at tier 0, odd escalates (mirrors
    tests/test_pipeline.py so serve-vs-stream comparisons line up)."""

    def mk_answer(v):
        def answer(t):
            if tier_sleep:
                time.sleep(tier_sleep)
            return np.full(len(t), v, np.int32)
        return answer

    cheap = TierSpec("cheap", mk_answer(0), ApiCost(10.0, 10.0, 0.0),
                     prompt=PromptSpec((0,), 100, 40))
    pricey = TierSpec("pricey", mk_answer(1), ApiCost(100.0, 100.0, 0.0),
                      prompt=PromptSpec((0, 1), 100, 40))

    def scorer(t, ans):
        return np.where(t[:, 0] % 2 == 0, 0.9, 0.1)

    def embed(tokens):
        e = np.zeros((len(tokens), 64), np.float32)
        e[np.arange(len(tokens)), tokens[:, 0] % 64] = 1.0
        return e

    cache = CompletionCache(capacity=64, threshold=0.99) if with_cache \
        else None
    return ServingPipeline(
        tiers=[cheap, pricey], thresholds=[0.5], scorer=scorer,
        cache=cache, embed=embed if with_cache else None,
        full_prompt_tokens=840, pad_token=-1, batch_size=batch_size)


def _tokens(n):
    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)          # distinct, half even / half odd
    return toks


def _assert_equivalent(a, b):
    """Bit-identical ServeResults (the tentpole guarantee)."""
    assert np.array_equal(a.answers, b.answers)
    assert a.answers.dtype == b.answers.dtype
    assert (a.cost == b.cost).all()            # bit-identical float64
    assert np.array_equal(a.stopped_at, b.stopped_at)
    assert a.tier_counts == b.tier_counts
    assert (a.cache_hits, a.cache_misses) == (b.cache_hits, b.cache_misses)
    assert a.prompt_tokens_saved == b.prompt_tokens_saved
    assert a.baseline_cost == b.baseline_cost


# ---------------------------------------------------------------------------
# the shared per-tier chunk step
# ---------------------------------------------------------------------------


def test_tier_step_matches_executor():
    """Chunk-by-chunk tier_step reproduces execute_cascade exactly —
    one compaction implementation, three drivers."""
    n, bs = 20, 8
    tier = CascadeTier("t", lambda q: (q % 3, np.full(len(q), 2.0)))

    def scorer(q, a, j):
        return (q % 2 == 0).astype(float)

    queries = np.arange(n)
    res = execute_cascade([tier, tier], [0.5], scorer, queries,
                          batch_size=bs)
    ans, cost, sco, acc = [], [], [], []
    for i in range(0, n, bs):
        a, c, s, m = tier_step(tier, queries[i:i + bs], 0, scorer=scorer,
                               threshold=0.5, last=False)
        ans.append(a), cost.append(c), sco.append(s), acc.append(m)
    acc = np.concatenate(acc)
    assert (np.concatenate(ans)[acc]
            == np.asarray(res["answers"])[res["stopped_at"] == 0]).all()
    assert acc.sum() == res["accepted_counts"][0]
    # accept-time scores surface in both drivers (cache-floor consumers)
    assert (np.concatenate(sco)[acc]
            == res["scores"][res["stopped_at"] == 0]).all()
    # last tier accepts everything regardless of threshold, unscored
    _, _, s, m = tier_step(tier, queries[:4], 1, scorer=scorer,
                           threshold=None, last=True)
    assert m.all() and np.isnan(s).all()
    assert np.isnan(res["scores"][res["stopped_at"] == 1]).all()


def test_tier_step_scorer_lock_serializes():
    """A shared scorer lock is honoured around the scorer call."""
    import threading

    lock = threading.Lock()
    seen = []

    def scorer(q, a, j):
        seen.append(lock.locked())       # held while scoring
        return np.ones(len(q))

    tier = CascadeTier("t", lambda q: (q, np.ones(len(q))))
    tier_step(tier, np.arange(4), 0, scorer=scorer, threshold=0.5,
              last=False, scorer_lock=lock)
    assert seen == [True] and not lock.locked()


# ---------------------------------------------------------------------------
# equivalence with ServingPipeline.serve
# ---------------------------------------------------------------------------


# both stream backends must uphold the guarantee: the serial batcher
# (parallel=False) and the SLO tier scheduler (parallel=True, default)
_BACKENDS = [False, True]


@pytest.mark.parametrize("parallel", _BACKENDS)
def test_stream_equivalent_to_serve_no_cache(parallel):
    toks = _tokens(24)
    a = _toy_pipeline(with_cache=False).serve(toks)
    b = _toy_pipeline(with_cache=False).serve_stream(toks,
                                                     parallel=parallel)
    _assert_equivalent(a, b)


@pytest.mark.parametrize("parallel", _BACKENDS)
def test_stream_equivalent_to_serve_with_cache(parallel):
    toks = _tokens(24)
    pipe_a, pipe_b = _toy_pipeline(), _toy_pipeline()
    _assert_equivalent(pipe_a.serve(toks),
                       pipe_b.serve_stream(toks, parallel=parallel))
    # the stream populated the cache exactly like serve: a second pass
    # through EITHER path is all hits
    again = pipe_b.serve_stream(toks, parallel=parallel)
    assert again.cache_hits == 24 and again.cost.sum() == 0.0
    assert (again.stopped_at == -1).all()


@pytest.mark.parametrize("parallel", _BACKENDS)
def test_stream_equivalent_under_staggered_arrivals(parallel):
    """Arrival pattern must not change what is answered or billed."""
    toks = _tokens(30)
    a = _toy_pipeline().serve(toks)
    b = _toy_pipeline().serve_stream(
        toks, np.linspace(0.0, 0.05, 30), max_chunk=4, parallel=parallel)
    _assert_equivalent(a, b)


@pytest.mark.parametrize("parallel", _BACKENDS)
def test_aserve_equivalent_to_serve(parallel):
    toks = _tokens(16)
    a = _toy_pipeline().serve(toks)
    b = asyncio.run(_toy_pipeline().aserve(toks, parallel=parallel))
    _assert_equivalent(a, b)
    assert b.ingress is not None
    assert len(b.ingress["request_latency"]) == 16


@pytest.mark.parametrize("parallel", _BACKENDS)
def test_stream_preserves_answer_dtype(parallel):
    """Generation-style string answers survive the stream paths too."""
    tier = TierSpec("gen", lambda t: np.array([f"a{x}" for x in t[:, 0]]),
                    ApiCost(1.0, 1.0, 0.0))
    mk = lambda: ServingPipeline(tiers=[tier], thresholds=[], scorer=None,
                                 full_prompt_tokens=10, pad_token=-1)
    toks = _tokens(6)
    a = mk().serve(toks)
    b = mk().serve_stream(toks, parallel=parallel)
    assert a.answers.tolist() == [f"a{i}" for i in range(6)]
    assert np.array_equal(a.answers, b.answers)
    assert a.answers.dtype == b.answers.dtype


# ---------------------------------------------------------------------------
# continuous-batching semantics
# ---------------------------------------------------------------------------


def test_ingress_queue_ordering_and_close():
    async def go():
        q = IngressQueue()
        toks = _tokens(3)
        q.submit(toks[0], arrival=0.5)
        q.submit(toks[1], arrival=0.0)
        q.submit(toks[2], arrival=0.0)
        assert len(q) == 3 and q.next_arrival() == 0.0
        due = q.due(0.1)
        assert [r.rid for r in due] == [1, 2]      # ties pop in rid order
        assert q.due(0.4) == []
        assert [r.rid for r in q.due(1.0)] == [0]
        q.close()
        with pytest.raises(RuntimeError, match="closed"):
            q.submit(toks[0])
    asyncio.run(go())


def test_late_duplicate_hits_cache_populated_mid_stream():
    """The one deliberate divergence from serve: a duplicate arriving
    after its twin completed is answered from the cache."""
    pipe = _toy_pipeline()
    batcher = ContinuousBatcher(pipe, max_chunk=8)
    toks = _tokens(8)
    queue = IngressQueue()
    queue.submit_burst(toks)
    # drain wave 1 manually (deterministic: no wall-clock involved)
    batcher.admit(queue.due(0.0), 0.0)
    while batcher.has_work():
        batcher.step(batcher._pick_tier(0.0, drain=True), lambda: 0.0)
    assert batcher.cache_hits == 0
    # wave 2: same queries again -> all cache hits, no new tier traffic
    counts_before = list(batcher.tier_counts)
    batcher.admit([RequestState(rid=8 + i, tokens=t)
                   for i, t in enumerate(toks)], 1.0)
    assert batcher.cache_hits == 8
    assert batcher.tier_counts == counts_before
    res = batcher.result(1.0)
    assert (res.answers[:8] == res.answers[8:]).all()


def test_admission_during_decode_packs_later_arrivals():
    """Requests that arrive while an earlier chunk is decoding join the
    tier's next chunk instead of waiting for a closed batch. Driven on
    an injected fake clock with explicit admission waves — the old
    wall-clock version raced a 5ms arrival against a 30ms decode sleep
    and could flake whenever a loaded CI host stalled past the gap."""
    pipe = _toy_pipeline(with_cache=False)
    batcher = ContinuousBatcher(pipe, max_chunk=8, holdback=0.0)
    toks = _tokens(8)
    queue = IngressQueue()
    queue.submit_burst(toks, np.array([0.0] * 4 + [0.005] * 4))
    # t=0: only the first wave is due; it dispatches as a 4-row chunk
    batcher.admit(queue.due(0.0), 0.0)
    batcher.step(batcher._pick_tier(0.0, drain=False), lambda: 0.0)
    assert batcher.chunks_per_tier[0] == 1
    # the second wave "arrives while chunk 1 decodes": admitted at
    # t=0.01, it packs into tier 0's NEXT chunk, not a closed batch
    batcher.admit(queue.due(0.01), 0.01)
    while batcher.has_work():
        batcher.step(batcher._pick_tier(0.01, drain=True), lambda: 0.01)
    assert batcher.chunks_per_tier[0] == 2             # 4-row, then 4-row
    res = batcher.result(0.02)
    assert res.n == 8 and (res.stopped_at >= 0).all()
    a = _toy_pipeline(with_cache=False).serve(toks)
    assert np.array_equal(a.answers, res.answers)
    assert (a.cost == res.cost).all()


def test_holdback_fills_partial_chunks():
    """With a holdback window, trickling arrivals coalesce into fuller
    chunks instead of dispatching one chunk per arrival."""
    pipe = _toy_pipeline(with_cache=False)
    toks = _tokens(8)
    arrivals = np.linspace(0.0, 0.02, 8)     # 8 single-request arrivals
    res = ContinuousBatcher(pipe, max_chunk=8, holdback=10.0).run_trace(
        toks, arrivals)
    # everything coalesced: one chunk per tier, full occupancy at tier 0
    assert res.ingress["chunks_per_tier"] == [1, 1]
    a = _toy_pipeline(with_cache=False).serve(toks)
    assert np.array_equal(a.answers, res.answers)


def test_aserve_futures_resolve_per_request():
    """Live producer/consumer: per-request futures resolve as answers
    land, before the stream as a whole is done."""

    async def go():
        pipe = _toy_pipeline(with_cache=False)
        toks = _tokens(8)
        queue = IngressQueue()
        batcher = ContinuousBatcher(pipe, max_chunk=4, holdback=0.0)
        task = asyncio.ensure_future(batcher.serve_async(queue))
        first = queue.submit_burst(toks[:4], with_future=True)
        r0 = await asyncio.wait_for(first[0].future, timeout=5.0)
        assert r0.answer == 0 and r0.stopped_at == 0
        # stream still open: submit a second wave, then close to drain
        second = queue.submit_burst(toks[4:], with_future=True)
        queue.close()
        res = await asyncio.wait_for(task, timeout=5.0)
        assert all(r.future.done() for r in first + second)
        assert res.n == 8
        return res

    res = asyncio.run(go())
    assert (res.answers[:: 2] == 0).all() and (res.answers[1:: 2] == 1).all()


def test_stream_telemetry_and_result_guard():
    pipe = _toy_pipeline(with_cache=False)
    toks = _tokens(12)
    batcher = ContinuousBatcher(pipe, max_chunk=4)
    res = batcher.run_trace(toks, np.linspace(0.0, 0.01, 12))
    ing = res.ingress
    assert len(ing["request_latency"]) == 12
    assert (ing["request_latency"] >= 0).all()
    assert (ing["queue_wait"] >= 0).all()
    assert 0 < ing["chunk_occupancy"] <= 1.0
    assert ing["n_chunks"] == sum(ing["chunks_per_tier"])
    assert set(res.latency) == {"embed", "cache", "cascade", "insert",
                                "total"}
    # result() refuses to fold a stream with requests still in flight
    b2 = ContinuousBatcher(pipe, max_chunk=4)
    b2.admit([RequestState(rid=0, tokens=toks[0])], 0.0)
    with pytest.raises(RuntimeError, match="in flight"):
        b2.result(0.0)


def test_batcher_rejects_bad_max_chunk():
    with pytest.raises(ValueError, match="max_chunk"):
        ContinuousBatcher(_toy_pipeline(with_cache=False), max_chunk=0)


def test_poisson_arrivals_validates_inputs():
    """rate <= 0 used to div-by-zero (or yield inf gaps) and n < 0
    silently returned an empty trace; both now fail loudly."""
    from repro.serving.ingress import poisson_arrivals

    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(10, 0.0)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(10, -5.0)
    with pytest.raises(ValueError, match="n must be"):
        poisson_arrivals(-1, 100.0)
    assert len(poisson_arrivals(0, 100.0)) == 0      # empty trace is fine
    arr = poisson_arrivals(50, 100.0, seed=3)
    assert len(arr) == 50 and (np.diff(arr) >= 0).all()


def test_submit_burst_rejects_mismatched_arrivals():
    q = IngressQueue()
    with pytest.raises(ValueError, match="arrival times"):
        q.submit_burst(_tokens(4), np.zeros(3))


def test_submit_rejects_mixed_token_widths():
    """One stream = one token width (chunks are stacked); a clear error
    beats a ValueError from np.stack deep inside the batcher."""
    q = IngressQueue()
    q.submit(np.arange(5))
    with pytest.raises(ValueError, match="width"):
        q.submit(np.arange(7))


def test_stream_pads_embed_and_tier_shapes_to_pow2():
    """Arbitrary burst/chunk sizes must reach jitted embed/scorer/tier
    callables padded to power-of-two row counts (otherwise every
    distinct stream size costs an XLA recompile mid-stream)."""
    pipe = _toy_pipeline()
    seen = {"embed": set(), "tier": set()}
    inner_embed, inner_answer = pipe.embed, pipe.tiers[0].answer
    pipe.embed = lambda t: (seen["embed"].add(len(t)),
                            inner_embed(t))[1]
    pipe.tiers[0].answer = lambda t: (seen["tier"].add(len(t)),
                                      inner_answer(t))[1]
    toks = _tokens(23)                 # odd sizes at every level
    # admissions of 1..4 rows, chunks of whatever accumulated
    res = ContinuousBatcher(pipe, max_chunk=8, holdback=0.0).run_trace(
        toks, np.linspace(0.0, 0.01, 23))
    assert res.n == 23
    pow2 = {1, 2, 4, 8, 16, 32}
    assert seen["embed"] <= pow2 and seen["tier"] <= pow2
    # and the padding stayed invisible: same results as serve
    a = _toy_pipeline().serve(toks)
    assert np.array_equal(a.answers, res.answers)
    assert (a.cost == res.cost).all()
