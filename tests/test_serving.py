"""Serving engine: generation correctness (incl. bucketed prefill
exactness), engine pool sharing, cascade server accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.cost import ApiCost
from repro.models import transformer as T
from repro.serving.engine import (CascadeServer, EnginePool,
                                  GenerationEngine, Tier, bucket_size,
                                  generation_tier)


def test_generation_engine_greedy_matches_manual():
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                         cfg.vocab))
    out = eng.generate(toks, n_new=4)
    assert out.shape == (2, 4)
    # manual greedy: prefill then argmax chain
    lg, cache = T.prefill(params, {"tokens": jnp.asarray(toks)}, cfg,
                          max_len=20)
    nxt = jnp.argmax(lg[:, -1], -1)
    assert (np.asarray(nxt) == out[:, 0]).all()


def test_bucket_size():
    assert bucket_size(1, 8) == 8
    assert bucket_size(8, 8) == 8
    assert bucket_size(9, 8) == 16
    assert bucket_size(100, 16) == 128


def test_bucketed_prefill_exact_and_reuses_compilation():
    """Odd batch/seq shapes pad into buckets, stay bit-exact vs the
    manual unpadded chain, and shape changes inside a bucket don't
    recompile."""
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (3, 13), 0,
                                         cfg.vocab))
    out = eng.generate(toks, n_new=5)
    assert out.shape == (3, 5)

    lg, cache = T.prefill(params, {"tokens": jnp.asarray(toks)}, cfg,
                          max_len=18)
    nxt = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
    ref = [np.asarray(nxt)]
    for i in range(4):
        logits, cache = T.decode_step(params, cache, nxt, jnp.int32(13 + i),
                                      cfg)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        ref.append(np.asarray(nxt))
    assert (np.concatenate(ref, axis=1) == out).all()

    assert eng.compile_stats["prefill_compiles"] == 1
    # different (batch, seq) inside the same buckets: reuse, no recompile
    eng.generate(toks[:2, :11], n_new=5)
    eng.generate(toks[:1, :16], n_new=4)
    assert eng.compile_stats["prefill_compiles"] == 1
    assert eng.compile_stats["prefill_calls"] == 3


def test_generate_n_new_zero_and_none():
    """Regression: ``n_new or max_new_tokens`` turned an explicit 0 into
    a full max_new_tokens generation; 0 must mean 0."""
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params, max_new_tokens=4)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(6), (3, 12), 0,
                                         cfg.vocab))
    out = eng.generate(toks, n_new=0)
    assert out.shape == (3, 0) and out.dtype == np.int32
    assert eng.compile_stats["prefill_calls"] == 0     # no model work
    assert eng.generate(toks).shape == (3, 4)          # None -> default
    assert eng.generate(toks, n_new=2).shape == (3, 2)


def test_sampled_first_token_uses_keyed_categorical():
    """Regression: with temperature > 0 the post-prefill token was
    always argmax; it must be sampled from the prefill logits with the
    same keyed path as later tokens (seed-reproducible)."""
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params, temperature=1.0)
    b, s, seed = 2, 16, 7
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (b, s), 0,
                                         cfg.vocab))
    out = eng.generate(toks, n_new=2, seed=seed)
    # manual reference on the engine's padded bucket shapes (batch 8,
    # seq 16, cache 32): prefill logits -> keyed categorical
    toks_p = np.concatenate([toks, np.repeat(toks[-1:], 8 - b, 0)])
    lg, _ = T.prefill(params, {"tokens": jnp.asarray(toks_p)}, cfg,
                      max_len=32, last_index=jnp.int32(s - 1))
    _, sub = jax.random.split(jax.random.PRNGKey(seed))
    first = np.asarray(jax.random.categorical(sub, lg[:, -1]))[:b]
    assert (out[:, 0] == first).all()
    # same seed reproduces; greedy engines are untouched by the fix
    assert (eng.generate(toks, n_new=2, seed=seed) == out).all()
    greedy = GenerationEngine(cfg, params)
    ref = np.asarray(jnp.argmax(lg[:, -1], -1))[:b]
    assert (greedy.generate(toks, n_new=1)[:, 0] == ref).all()


def test_engine_pool_shares_engines_and_stats():
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    pool = EnginePool(max_new_tokens=4)
    e1 = pool.get(cfg, params)
    e2 = pool.get(cfg, params)
    assert e1 is e2 and len(pool) == 1
    # same arch, different trained weights -> must NOT share an engine
    params_b = T.init_params(jax.random.PRNGKey(9), cfg)
    e3 = pool.get(cfg, params_b)
    assert e3 is not e1 and len(pool) == 2
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                                         cfg.vocab))
    e1.generate(toks)
    assert pool.compile_stats["prefill_calls"] == 1


def test_generation_tier_answer_and_cost():
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params)
    tier = generation_tier("gen", eng, ApiCost(10.0, 20.0, 0.0),
                           decode_answer=lambda g: g[:, 0] % 7, n_new=2)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (3, 12), 0,
                                         cfg.vocab))
    ans = tier.answer(toks)
    assert ans.shape == (3,) and (ans < 7).all()
    cost = tier.cost(toks)
    assert cost == pytest.approx(np.full(3, (12 * 1.0 + 2 * 2.0) / 1e6))


def test_pipeline_with_pooled_generation_tier():
    """The unified pipeline driving a generation-backed tier from the
    shared engine pool (cascade escalation path ends on a real model)."""
    from repro.serving.pipeline import ServingPipeline, TierSpec

    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    pool = EnginePool(max_new_tokens=2)
    gen = generation_tier("gen-top", pool.get(cfg, params),
                          ApiCost(100.0, 100.0, 0.0),
                          decode_answer=lambda g: g[:, 0] % 3, n_new=2)
    cheap = TierSpec("cheap", lambda t: np.zeros(len(t), np.int32),
                     ApiCost(1.0, 1.0, 0.0))
    top = TierSpec(gen.name, gen.answer, ApiCost(100.0, 100.0, 0.0), n_out=2)
    pipe = ServingPipeline(
        tiers=[cheap, top], thresholds=[0.5],
        scorer=lambda t, a: np.where(np.arange(len(t)) % 2 == 0, 0.9, 0.1),
        batch_size=4)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (6, 12), 0,
                                         cfg.vocab))
    res = pipe.serve(toks)
    assert res.tier_counts[0] == 6 and res.tier_counts[1] > 0
    assert (res.answers[res.stopped_at == 1] < 3).all()
    assert pool.compile_stats["prefill_calls"] > 0


def test_cascade_server_routing_and_cost():
    n = 60
    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)      # row i leads with i => half odd/even

    easy = toks[:, 0] % 2 == 0     # half the queries are 'easy'

    t1 = Tier("cheap", lambda t: np.zeros(len(t), np.int32),
              lambda t: np.full(len(t), 1.0))
    t2 = Tier("pricey", lambda t: np.ones(len(t), np.int32),
              lambda t: np.full(len(t), 10.0))

    def scorer(t, ans):
        return np.where(t[:, 0] % 2 == 0, 0.9, 0.1)

    srv = CascadeServer([t1, t2], [0.5], scorer)
    res = srv.serve(toks)
    # easy queries stop at tier 0 with answer 0; hard reach tier 1
    assert (res["stopped_at"][easy] == 0).all()
    assert (res["stopped_at"][~easy] == 1).all()
    assert (res["answers"][easy] == 0).all()
    assert (res["answers"][~easy] == 1).all()
    # cost: easy pay 1, hard pay 11
    assert res["cost"][easy].mean() == pytest.approx(1.0)
    assert res["cost"][~easy].mean() == pytest.approx(11.0)
    assert res["tier_counts"] == [n, n // 2]


def test_cascade_server_all_accepted_never_calls_tier2():
    n = 8
    toks = np.zeros((n, 4), np.int32)
    calls = {"t2": 0}
    t1 = Tier("a", lambda t: np.zeros(len(t), np.int32),
              lambda t: np.ones(len(t)))

    def t2_answer(t):
        calls["t2"] += 1
        return np.zeros(len(t), np.int32)

    t2 = Tier("b", t2_answer, lambda t: np.ones(len(t)))
    srv = CascadeServer([t1, t2], [0.0], lambda t, a: np.ones(len(t)))
    srv.serve(toks)
    assert calls["t2"] == 0
