"""Serving engine: generation correctness + cascade server accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import transformer as T
from repro.serving.engine import CascadeServer, GenerationEngine, Tier


def test_generation_engine_greedy_matches_manual():
    cfg = ARCHS["gemma3-1b"].reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(cfg, params)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                         cfg.vocab))
    out = eng.generate(toks, n_new=4)
    assert out.shape == (2, 4)
    # manual greedy: prefill then argmax chain
    lg, cache = T.prefill(params, {"tokens": jnp.asarray(toks)}, cfg,
                          max_len=20)
    nxt = jnp.argmax(lg[:, -1], -1)
    assert (np.asarray(nxt) == out[:, 0]).all()


def test_cascade_server_routing_and_cost():
    n = 60
    toks = np.arange(n * 4, dtype=np.int32).reshape(n, 4)
    toks[:, 0] = np.arange(n)      # row i leads with i => half odd/even

    easy = toks[:, 0] % 2 == 0     # half the queries are 'easy'

    t1 = Tier("cheap", lambda t: np.zeros(len(t), np.int32),
              lambda t: np.full(len(t), 1.0))
    t2 = Tier("pricey", lambda t: np.ones(len(t), np.int32),
              lambda t: np.full(len(t), 10.0))

    def scorer(t, ans):
        return np.where(t[:, 0] % 2 == 0, 0.9, 0.1)

    srv = CascadeServer([t1, t2], [0.5], scorer)
    res = srv.serve(toks)
    # easy queries stop at tier 0 with answer 0; hard reach tier 1
    assert (res["stopped_at"][easy] == 0).all()
    assert (res["stopped_at"][~easy] == 1).all()
    assert (res["answers"][easy] == 0).all()
    assert (res["answers"][~easy] == 1).all()
    # cost: easy pay 1, hard pay 11
    assert res["cost"][easy].mean() == pytest.approx(1.0)
    assert res["cost"][~easy].mean() == pytest.approx(11.0)
    assert res["tier_counts"] == [n, n // 2]


def test_cascade_server_all_accepted_never_calls_tier2():
    n = 8
    toks = np.zeros((n, 4), np.int32)
    calls = {"t2": 0}
    t1 = Tier("a", lambda t: np.zeros(len(t), np.int32),
              lambda t: np.ones(len(t)))

    def t2_answer(t):
        calls["t2"] += 1
        return np.zeros(len(t), np.int32)

    t2 = Tier("b", t2_answer, lambda t: np.ones(len(t)))
    srv = CascadeServer([t1, t2], [0.0], lambda t, a: np.ones(len(t)))
    srv.serve(toks)
    assert calls["t2"] == 0
