"""Per-tier mesh slices: sharded engines, scan-folding, sharded init.

The contract (ISSUE 6 / ROADMAP "Multi-host sharded tiers"):

  * folding homogeneous prefix/suffix blocks into the scanned stack
    (``models.transformer.fold_stack``) never changes the computation —
    generation is bit-identical — and makes compile count O(1) in depth;
  * a ``GenerationEngine`` sharded over a mesh slice (data axis) is
    bit-identical to the unsharded engine;
  * ``init_params_sharded`` materialises params sharded from birth, and
    the values are independent of the mesh shape (threefry is
    counter-based/elementwise) — the multi-shape leg runs in a forced
    8-device subprocess, like tests/test_placement.py's.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import transformer as T
from repro.serving.engine import GenerationEngine
from repro.sharding import tier_mesh


def _cfg(n_periods: int = 2, *, prefix: int = 1, suffix: int = 1,
         d_model: int = 64, d_ff: int = 128) -> ModelConfig:
    spec = LayerSpec("attn", "dense")
    return ModelConfig(
        name=f"fold-test-{prefix}p{n_periods}x{suffix}", arch_type="dense",
        n_layers=prefix + n_periods + suffix, d_model=d_model, d_ff=d_ff,
        vocab=256, n_heads=4, n_kv_heads=2, head_dim=16,
        prefix=(spec,) * prefix, period=(spec,), n_periods=n_periods,
        suffix=(spec,) * suffix, max_seq=512, dtype="float32")


def _tokens(b: int = 4, s: int = 6, seed: int = 0) -> np.ndarray:
    return (np.random.default_rng(seed)
            .integers(1, 200, size=(b, s)).astype(np.int32))


# ---------------------------------------------------------------------------
# scan-over-layers folding
# ---------------------------------------------------------------------------


def test_fold_config_absorbs_matching_prefix_suffix():
    cfg = _cfg(2, prefix=1, suffix=1)
    f = T.fold_config(cfg)
    assert f.prefix == () and f.suffix == () and f.n_periods == 4
    assert f.layers == cfg.layers          # same flattened computation
    # homogeneous prefix with no period at all becomes the stack
    spec = LayerSpec("attn", "dense")
    cfg2 = ModelConfig(name="pfx", arch_type="dense", n_layers=3,
                       d_model=64, d_ff=128, vocab=256, n_heads=4,
                       n_kv_heads=2, head_dim=16, prefix=(spec,) * 3,
                       max_seq=512, dtype="float32")
    f2 = T.fold_config(cfg2)
    assert f2.n_periods == 3 and f2.period == (spec,) and f2.prefix == ()
    assert f2.layers == cfg2.layers


def test_fold_config_noop_when_specs_differ():
    spec, other = LayerSpec("attn", "dense"), LayerSpec("attn_sliding",
                                                        "dense")
    cfg = ModelConfig(name="het", arch_type="dense", n_layers=3,
                      d_model=64, d_ff=128, vocab=256, n_heads=4,
                      n_kv_heads=2, head_dim=16, prefix=(other,),
                      period=(spec,), n_periods=2, window=64,
                      max_seq=512, dtype="float32")
    assert T.fold_config(cfg) is cfg
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    fcfg, fparams = T.fold_stack(cfg, params)
    assert fcfg is cfg and fparams is params


def test_fold_stack_generation_bit_identical():
    cfg = _cfg(2, prefix=1, suffix=1)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    fcfg, fparams = T.fold_stack(cfg, params)
    assert fcfg.n_periods == 4
    # the period stack is ONE stacked leaf per weight, depth-major
    assert fparams["prefix"] == [] and fparams["suffix"] == []
    stack = fparams["period"]["sub0"]["mixer"]["wq"]
    assert stack.shape[0] == 4
    assert np.array_equal(np.asarray(stack[0]),
                          np.asarray(params["prefix"][0]["mixer"]["wq"]))
    assert np.array_equal(np.asarray(stack[-1]),
                          np.asarray(params["suffix"][0]["mixer"]["wq"]))
    toks = _tokens()
    out_ref = GenerationEngine(cfg, params).generate(toks, n_new=4)
    out_fold = GenerationEngine(fcfg, fparams).generate(toks, n_new=4)
    assert np.array_equal(out_ref, out_fold)


# ---------------------------------------------------------------------------
# sharded engine (single-device slice; multi-device legs in the
# subprocess test below)
# ---------------------------------------------------------------------------


def test_sharded_engine_bit_identical_and_compile_o1_in_depth():
    mesh = tier_mesh.plan_tier_meshes(1).for_tier(0)
    toks = _tokens()
    stats = []
    for n_periods in (2, 6):               # 4- and 8-layer stacks
        cfg = _cfg(n_periods, prefix=1, suffix=1)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        ref = GenerationEngine(cfg, params).generate(toks, n_new=4)
        eng = GenerationEngine(cfg, params, mesh=mesh)
        assert eng.cfg.prefix == () and eng.cfg.suffix == ()  # auto-fold
        assert np.array_equal(eng.generate(toks, n_new=4), ref)
        stats.append(dict(eng.compile_stats))
    # compile count O(1) in depth: the deep stack compiled exactly as
    # many prefill variants as the shallow one (the scan hides depth)
    assert stats[0] == stats[1]
    assert stats[0]["prefill_compiles"] == 1


def test_mesh_decode_out_shardings_pinned():
    """Mesh engines pin the decode pjit's in/out shardings to the
    prefill's committed layout (tokens over "data", KV cache per
    ``sharding.rules``), recorded per (batch, cache-length) bucket in
    ``decode_shardings`` — so the KV layout cannot drift across decode
    steps or the prefill->decode handoff. The split entry points
    (``prefill_async`` + ``decode_from``) are the halves of ``generate``
    and stay bit-identical on the mesh."""
    cfg = _cfg(2, prefix=1, suffix=1)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = _tokens()
    plain = GenerationEngine(cfg, params)
    ref = plain.generate(toks, n_new=4)
    assert plain.decode_shardings == {}    # single device: shared jit,
    mesh = tier_mesh.plan_tier_meshes(1).for_tier(0)  # nothing pinned
    eng = GenerationEngine(cfg, params, mesh=mesh)
    assert eng.decode_shardings == {}      # nothing decoded yet
    out = eng.decode_from(eng.prefill_async(toks, n_new=4))
    assert np.array_equal(out, ref)        # split call == one-shot call
    assert len(eng.decode_shardings) == 1
    ((b_b, max_len), (tok_sh, cache_sh)), = eng.decode_shardings.items()
    assert b_b >= len(toks)                # pow2 batch bucket covers B
    assert tok_sh == tier_mesh.batch_sharding(mesh, b_b)
    for sh in jax.tree_util.tree_leaves(
            cache_sh, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)):
        assert isinstance(sh, jax.sharding.NamedSharding)
        assert sh.mesh.devices.size == mesh.devices.size
    # the pinned pjit variant exists for exactly the recorded buckets
    assert set(eng._decode_fns) == set(eng.decode_shardings)
    # one-shot generate reuses the same pinned bucket (no new entries)
    assert np.array_equal(eng.generate(toks, n_new=4), ref)
    assert len(eng.decode_shardings) == 1


def test_engine_rejects_device_and_mesh_together():
    cfg = _cfg(2)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    mesh = tier_mesh.plan_tier_meshes(1).for_tier(0)
    with pytest.raises(ValueError, match="not both"):
        GenerationEngine(cfg, params, device=jax.local_devices()[0],
                         mesh=mesh)


def test_init_params_sharded_shapes_and_determinism():
    cfg = _cfg(2, prefix=1, suffix=1)
    mesh = tier_mesh.plan_tier_meshes(1).for_tier(0)
    fcfg, p1 = tier_mesh.init_params_sharded(jax.random.PRNGKey(7), cfg,
                                             mesh)
    _, p2 = tier_mesh.init_params_sharded(jax.random.PRNGKey(7), cfg, mesh)
    assert fcfg.n_periods == 4             # folded before init
    same = jax.tree.map(lambda a, b: bool((a == b).all()), p1, p2)
    assert all(jax.tree_util.tree_leaves(same))
    # folded init shapes match eagerly-folded init shapes
    eager = T.fold_stack(cfg, T.init_params(jax.random.PRNGKey(7), cfg))[1]
    shapes = jax.tree.map(lambda a, b: a.shape == b.shape, p1, eager)
    assert all(jax.tree_util.tree_leaves(shapes))


# ---------------------------------------------------------------------------
# the multi-device leg: forced 8-device CPU host (subprocess)
# ---------------------------------------------------------------------------


def test_sharded_tiers_on_forced_8_device_host():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
assert len(jax.devices()) == 8, jax.devices()
import numpy as np
import test_tier_mesh as tm
from repro.models import transformer as T
from repro.serving.engine import GenerationEngine
from repro.sharding import tier_mesh

# 1. sharded-init determinism: identical params on EVERY mesh shape
cfg = tm._cfg(2, prefix=1, suffix=1, d_model=64, d_ff=128)
key = jax.random.PRNGKey(7)
shapes = [(1, 1), (2, 1), (4, 1), (8, 1), (2, 2)]
inits = []
for r, c in shapes:
    mesh = tier_mesh.plan_tier_meshes(
        1, mesh_shape=(r, c), devices=jax.devices()[:r * c]).for_tier(0)
    inits.append(tier_mesh.init_params_sharded(key, cfg, mesh)[1])
ref = inits[0]
for (r, c), p in zip(shapes[1:], inits[1:]):
    same = jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()), ref, p)
    assert all(jax.tree_util.tree_leaves(same)), (r, c)

# 2. FSDP actually splits the stacked params across the slice: with
# d_ff=2048 (>= 1024 and divisible), each device holds 1/data_size
big = tm._cfg(2, prefix=1, suffix=1, d_model=128, d_ff=2048)
mesh4 = tier_mesh.plan_tier_meshes(
    1, mesh_shape=(4, 1), devices=jax.devices()[:4]).for_tier(0)
_, bp = tier_mesh.init_params_sharded(key, big, mesh4)
up = bp["period"]["sub0"]["ffn"]["up"]["w"]
shard = up.addressable_shards[0].data
assert shard.size == up.size // 4, (shard.shape, up.shape)

# 3. a 2-way data-sharded engine is bit-identical to the unsharded one
cfg = tm._cfg(3, prefix=1, suffix=1, d_model=64, d_ff=128)
params = T.init_params(jax.random.PRNGKey(0), cfg)
toks = tm._tokens(b=8, s=6)
ref_out = GenerationEngine(cfg, params).generate(toks, n_new=4)
mesh2 = tier_mesh.plan_tier_meshes(
    1, mesh_shape=(2, 1), devices=jax.devices()[:2]).for_tier(0)
eng = GenerationEngine(cfg, params, mesh=mesh2)
out = eng.generate(toks, n_new=4)
assert np.array_equal(ref_out, out)
# and the padded batch genuinely lives split over the two devices
assert eng.params["embed"]["tok"].sharding.mesh.devices.size == 2
# 4. the decode pjit is pinned to the 2-device layout (tokens over
# "data", cache per sharding.rules) and the split prefill/decode
# entry points hand the sharded KV cache off bit-identically
assert len(eng.decode_shardings) == 1
(tok_sh, cache_sh), = eng.decode_shardings.values()
assert tok_sh.mesh.devices.size == 2
for sh in jax.tree_util.tree_leaves(
        cache_sh, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)):
    assert sh.mesh.devices.size == 2
out_split = eng.decode_from(eng.prefill_async(toks, n_new=4))
assert np.array_equal(ref_out, out_split)
print("TIER-MESH-8DEV-OK")
"""
    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), here]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "TIER-MESH-8DEV-OK" in out.stdout, out.stderr[-3000:]
