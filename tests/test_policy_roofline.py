"""Sharding policy, roofline analysis, and an end-to-end small-mesh
dry-run smoke (subprocess: the 512-device flag must not leak here)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.launch.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, analyze,
                                   model_flops, to_markdown)
from repro.sharding import policy


def test_constrain_noop_without_policy():
    x = jnp.ones((4, 8))
    assert policy.constrain(x, "dp", "model") is x


def test_constrain_under_single_device_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with policy.policy(mesh):
        x = policy.constrain(jnp.ones((4, 8)), "dp", "model")
        assert x.shape == (4, 8)


def test_constrain_priority_resolution():
    """Heads claim 'model' when divisible; sequence takes it otherwise."""
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}
        size = 16

    policy._ACTIVE_MESH = FakeMesh()
    try:
        import repro.sharding.policy as P

        # emulate spec computation only (with_sharding_constraint would
        # need real devices; we monkeypatch it to capture the spec)
        captured = {}

        def fake_wsc(x, sharding):
            captured["spec"] = sharding.spec
            return x

        orig = P.jax.lax.with_sharding_constraint
        orig_ns = P.NamedSharding
        P.NamedSharding = lambda mesh, spec: type(
            "NS", (), {"spec": spec})()
        P.jax.lax.with_sharding_constraint = fake_wsc
        try:
            # KVH=4 divisible -> heads get "model", seq gets nothing
            policy.constrain(jnp.ones((8, 16, 4, 8)), "dp", ("model",),
                             "model", None, priority=(0, 2, 1))
            assert captured["spec"][2] == "model"
            assert captured["spec"][1] is None
            # KVH=3 not divisible -> seq takes "model"
            policy.constrain(jnp.ones((8, 16, 3, 8)), "dp", ("model",),
                             "model", None, priority=(0, 2, 1))
            assert captured["spec"][1] == "model"
            assert captured["spec"][2] is None
        finally:
            P.jax.lax.with_sharding_constraint = orig
            P.NamedSharding = orig_ns
    finally:
        policy._ACTIVE_MESH = None


def test_model_flops_train_vs_decode():
    t = model_flops("gemma3-1b", "train_4k")
    d = model_flops("gemma3-1b", "decode_32k")
    assert t > d * 1000          # train step >> one decode token step


def test_roofline_analyze_real_results():
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("dryrun_results.json not generated yet")
    rows = analyze(path)
    if len(rows) < 20:
        pytest.skip("dry-run sweep still in progress")
    for r in rows:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["compute_s"] >= 0 and r["memory_s"] >= 0
        assert 0 <= r["useful_ratio"] <= 1.5
    md = to_markdown(rows)
    assert md.count("|") > 100


def test_dryrun_small_mesh_subprocess():
    """Full dryrun machinery on an 8-device host mesh in a subprocess."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import repro.launch.mesh as M
M.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 2, 2) if multi_pod else (2, 4),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
import repro.configs.registry as REG
from repro.configs.registry import get_arch
cfg = get_arch("gemma3-1b").reduced()
REG.ARCHS["gemma3-1b"] = cfg
from repro.launch.dryrun import dryrun_one
r = dryrun_one("gemma3-1b", "train_4k", verbose=False)
assert r["status"] == "ok", r
r2 = dryrun_one("gemma3-1b", "decode_32k", verbose=False, multi_pod=True)
assert r2["status"] == "ok", r2
print("SMALL-MESH-DRYRUN-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SMALL-MESH-DRYRUN-OK" in out.stdout, out.stderr[-3000:]
